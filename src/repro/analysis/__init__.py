"""Serving-invariant static analysis + runtime sanitizers.

Nine PRs of serving work accumulated cross-cutting invariants that were
enforced only by convention: the injectable-clock discipline, ``self.obs``
telemetry guards, BlockAllocator refcount/CoW rules, the bounded-queue
staged-sync worker protocol, and Pallas-kernel/oracle pairing.  This
package machine-checks them at three layers:

* :mod:`repro.analysis.lint` — project-specific AST lint pass
  (``python -m repro.analysis.lint src/``), one module per rule under
  :mod:`repro.analysis.rules`, with ``# lint: allow-<rule>`` suppressions.
* :mod:`repro.analysis.sanitize` — opt-in runtime sanitizers
  (``LicensedGateway(..., sanitize=True)`` or ``REPRO_SANITIZE=1``): a
  shadow-model block sanitizer mirroring BlockAllocator/PagedCachePool
  state, and a retracing sentinel bounding jit specialization counts.
* :mod:`repro.analysis.lockstep` — a seeded deterministic lockstep
  scheduler serializing the staged-sync fetch worker against the serving
  thread at annotated yield points, asserting ``guarded-by`` field
  ownership dynamically across explored interleavings.

This module deliberately imports nothing at package-import time: serving
modules import :mod:`repro.analysis.lockstep` hooks, and a package-level
import of the lint/metrics machinery would create an import cycle back
into ``repro.serving``.

See ``docs/ANALYSIS.md`` for the rule catalog and annotation grammar.
"""
from __future__ import annotations

__all__ = [
    "lint", "lockstep", "metrics", "rules", "sanitize",
]
