"""Deterministic lockstep scheduler for the staged-sync thread pair.

The update stager runs one background fetch worker against the serving
thread, sharing cursor state under a single-writer ownership protocol
(the ``# guarded-by: owner(...)`` annotations checked statically by
RULE-GUARDED-BY).  This module validates the *dynamic* half: under a
:class:`LockstepScheduler`, annotated code paths call
:func:`checkpoint` with the fields they are about to touch, and the
scheduler

* asserts the calling thread's role currently owns every touched field
  (ownership moves with :func:`transfer_ownership`, placed exactly where
  the real protocol moves it: worker spawn and post-join), raising
  :class:`LockstepViolation` at the first wrong-thread touch;
* *perturbs* the interleaving deterministically — per (checkpoint,
  visit#) it decides by seeded hash whether to pause the caller until
  another thread reaches a checkpoint, the same decision scheme
  ChaosTransport uses per (op, call#), so a failing seed replays.

Pauses are bounded (``max_pause_s``) and waiting never holds a lock the
other thread needs, so the harness cannot deadlock the bounded fetch
queue — a pause expires into a plain resume.  Outside a ``with
LockstepScheduler(...)`` block every hook is a no-op costing one global
read, so instrumented production code pays nothing.

This module is intentionally import-free of the rest of ``repro`` so
low-level serving modules can instrument themselves without cycles.
"""
from __future__ import annotations

import threading
import zlib
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = ["LockstepViolation", "LockstepScheduler", "checkpoint",
           "transfer_ownership", "active"]

_ACTIVE: Optional["LockstepScheduler"] = None

_WORKER_PREFIX = "update-stager"


class LockstepViolation(RuntimeError):
    """A thread touched a field whose ownership it does not hold."""


def active() -> Optional["LockstepScheduler"]:
    return _ACTIVE


def checkpoint(name: str, touches: Iterable[str] = ()) -> None:
    """Annotated yield point: declare the fields this code path is about
    to touch, and give the lockstep scheduler (when one is active) a
    place to check ownership and perturb the interleaving."""
    sched = _ACTIVE
    if sched is not None:
        sched._visit(name, tuple(touches))


def transfer_ownership(fields: Iterable[str], role: str) -> None:
    """Record that ``fields`` are now owned by ``role`` ("serve" or
    "worker").  Placed at the protocol's real handoff points: before the
    fetch worker starts, and after the serving thread joins it."""
    sched = _ACTIVE
    if sched is not None:
        sched._transfer(tuple(fields), role)


def _role() -> str:
    name = threading.current_thread().name
    return "worker" if name.startswith(_WORKER_PREFIX) else "serve"


class LockstepScheduler:
    """Context manager arming the checkpoints (one active at a time)."""

    def __init__(self, seed: int = 0, switch_rate: float = 0.5,
                 max_pause_s: float = 0.02) -> None:
        self.seed = int(seed)
        self.switch_rate = float(switch_rate)
        self.max_pause_s = float(max_pause_s)
        self._cond = threading.Condition()
        self._counts: Dict[str, int] = {}
        self._gen = 0
        self.visits: Dict[str, int] = {}
        self.pauses = 0
        self.violations: List[str] = []
        self._owners: Dict[str, str] = {}
        self.transfers: List[Tuple[str, Tuple[str, ...]]] = []

    # ------------------------------------------------------------ hooks
    def _transfer(self, fields: Tuple[str, ...], role: str) -> None:
        with self._cond:
            for f in fields:
                self._owners[f] = role
            self.transfers.append((role, fields))

    def _visit(self, name: str, touches: Tuple[str, ...]) -> None:
        role = _role()
        with self._cond:
            for f in touches:
                owner = self._owners.get(f)
                if owner is not None and owner != role:
                    msg = (f"checkpoint {name!r}: thread role {role!r} "
                           f"touches {f!r} owned by {owner!r}")
                    self.violations.append(msg)
                    self._gen += 1
                    self._cond.notify_all()
                    raise LockstepViolation(msg)
            n = self._counts.get(name, 0)
            self._counts[name] = n + 1
            self.visits[name] = self.visits.get(name, 0) + 1
            # the ChaosTransport decision scheme: one hash per
            # (checkpoint, visit#) — same seed, same schedule pressure
            h = zlib.crc32(f"{self.seed}:{name}:{n}".encode())
            pause = (h % 1000) / 1000.0 < self.switch_rate
            self._gen += 1
            self._cond.notify_all()
            if pause:
                self.pauses += 1
                gen = self._gen
                # bounded: resumes when any other thread checkpoints, or
                # on timeout — never deadlocks the bounded fetch queue
                self._cond.wait_for(lambda: self._gen != gen,
                                    timeout=self.max_pause_s)

    # ---------------------------------------------------------- context
    def __enter__(self) -> "LockstepScheduler":
        global _ACTIVE
        if _ACTIVE is not None:
            raise RuntimeError("a LockstepScheduler is already active")
        _ACTIVE = self
        return self

    def __exit__(self, *exc) -> None:
        global _ACTIVE
        _ACTIVE = None
