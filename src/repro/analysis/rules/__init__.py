"""One module per lint rule; ``ALL_RULES`` is the registry the driver
runs.  Every rule subclasses :class:`Rule` and reports through
``module.diag`` so ``# lint: allow-<rule>`` suppressions apply
uniformly."""
from __future__ import annotations

from typing import Iterable, List

from repro.analysis.lint import Diagnostic, ModuleInfo


class Rule:
    """Base class: ``name`` is the kebab-case id used in diagnostics and
    ``allow-<name>`` suppressions."""

    name: str = "?"

    def check_modules(self, modules: List[ModuleInfo],
                      ) -> Iterable[Diagnostic]:
        """Default driver: per-module ``check``.  Cross-module rules
        (metrics) override this."""
        out: List[Diagnostic] = []
        for m in modules:
            out.extend(self.check(m))
        return out

    def check(self, module: ModuleInfo) -> Iterable[Diagnostic]:
        return ()


def _attr_chain(node) -> List[str]:
    """``self.pool.allocator.alloc`` -> ["self", "pool", "allocator",
    "alloc"]; empty when the expression is not a plain name/attr chain."""
    import ast

    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return list(reversed(parts))
    return []


from repro.analysis.rules.clock import ClockRule            # noqa: E402
from repro.analysis.rules.obs import ObsRule                # noqa: E402
from repro.analysis.rules.guarded_by import GuardedByRule   # noqa: E402
from repro.analysis.rules.hot_path import HotPathRule       # noqa: E402
from repro.analysis.rules.kernel import KernelRule          # noqa: E402
from repro.analysis.rules.metrics import MetricsRule        # noqa: E402

ALL_RULES = [
    ClockRule(),
    ObsRule(),
    GuardedByRule(),
    HotPathRule(),
    KernelRule(),
    MetricsRule(),
]

__all__ = ["Rule", "ALL_RULES", "ClockRule", "ObsRule", "GuardedByRule",
           "HotPathRule", "KernelRule", "MetricsRule"]
