"""RULE-CLOCK: no bare wall-clock *calls* in serving/lease/wait math.

Every serving component takes an injectable ``clock`` (and the retry
policy an injectable ``sleep``), which is what makes frozen-clock tests
and deterministic chaos schedules possible.  A stray
``time.monotonic()`` / ``time.perf_counter()`` / ``time.time()`` /
``time.sleep()`` *call* inside the serving tree bypasses that seam.

Bare *references* stay legal — ``clock: Callable = time.perf_counter``
as a default parameter value or ``self.clock = clock or time.monotonic``
IS the injection point, so the rule only fires on call expressions.
This is what keeps the merged tree at zero suppressions: the sanctioned
sites never call, they pass the function along.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.lint import Diagnostic, ModuleInfo
from repro.analysis.rules import Rule

_CLOCK_FNS = {"monotonic", "perf_counter", "time", "monotonic_ns",
              "perf_counter_ns", "time_ns", "sleep"}

# serving/lease/wait code where wall-clock calls must flow through the
# injectable clock; offline tooling (training/, launch/) is exempt
_SCOPED_DIRS = {"serving"}
_SCOPED_FILES = {"transport.py", "protocol.py", "licensing.py"}


def _time_aliases(tree: ast.AST) -> set:
    names = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "time":
                    names.add(a.asname or "time")
    return names


class ClockRule(Rule):
    name = "clock"

    def applies(self, module: ModuleInfo) -> bool:
        return (any(p in _SCOPED_DIRS for p in module.parts)
                or module.name in _SCOPED_FILES)

    def check(self, module: ModuleInfo) -> Iterable[Diagnostic]:
        if not self.applies(module):
            return []
        aliases = _time_aliases(module.tree)
        if not aliases:
            return []
        out: List[Diagnostic] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            if (isinstance(fn, ast.Attribute)
                    and isinstance(fn.value, ast.Name)
                    and fn.value.id in aliases
                    and fn.attr in _CLOCK_FNS):
                d = module.diag(
                    node, self.name,
                    f"bare time.{fn.attr}() call in serving/wait math; "
                    f"route it through the injectable clock/sleep "
                    f"(e.g. self.clock())")
                if d:
                    out.append(d)
        return out
