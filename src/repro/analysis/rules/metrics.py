"""RULE-METRICS: one metrics namespace, declared and documented.

Three schema-drift guards, promoted from the inline lint that used to
live in ``tests/test_telemetry.py`` (the runtime half — ``metrics()``
dicts vs the declared key tuples — now lives in
:mod:`repro.analysis.metrics`; this rule is the *static* half over
source and docs):

* every Prometheus series name registered in ``serving/`` (string
  literals ``serving_*`` / ``fleet_*`` / ``tenant_*`` passed to
  counter/gauge/histogram registration or collector yields) must appear
  in ``docs/OBSERVABILITY.md`` — and every name the doc promises must
  exist in code, so dashboards built from the doc never query a dead
  series.  Doc names use brace groups
  (``serving_requests_{admitted,rejected}_total``) which are expanded
  before matching.
* the ``*_METRICS_KEYS`` declaration tuples in ``telemetry.py`` must be
  duplicate-free — a pasted duplicate silently weakens the
  set-difference checks built on them.
* in the counter-export table that maps ``stats()`` keys to Prometheus
  names (tuples whose second element is a series name), the source key
  must be covered by ``GATEWAY_METRICS_KEYS`` — exporting an
  undeclared key means the runtime lint can't see it.

Audit event names (``audit.record("tenant_reject", ...)``) share the
``tenant_`` prefix but are not series; ``.record`` arguments and
docstrings are excluded from collection.
"""
from __future__ import annotations

import ast
import os
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.lint import Diagnostic, ModuleInfo
from repro.analysis.rules import Rule

_NAME_RE = re.compile(r"(serving|fleet|tenant)_[a-z0-9_]+")
_DOC_TOKEN_RE = re.compile(r"`([^`]+)`")
_DOC_NAME_RE = re.compile(r"(serving|fleet|tenant)_[a-z0-9_{},]+")
_DOCS_NAME = "OBSERVABILITY.md"


def _expand_braces(name: str) -> List[str]:
    m = re.search(r"\{([^{}]*)\}", name)
    if not m:
        return [name]
    out: List[str] = []
    for opt in m.group(1).split(","):
        out.extend(_expand_braces(name[:m.start()] + opt.strip()
                                  + name[m.end():]))
    return out


def _find_docs(roots: Iterable[Path]) -> Optional[Path]:
    for root in roots:
        base = root if root.is_dir() else root.parent
        for up in (base, base.parent, base.parent.parent):
            for cand in (up / "docs" / _DOCS_NAME, up / _DOCS_NAME):
                if cand.is_file():
                    return cand
    return None


def _declared_match(path: str, declared: Iterable[str]) -> bool:
    for d in declared:
        if d.endswith(".*"):
            if path == d[:-2] or path.startswith(d[:-1]):
                return True
        elif path == d:
            return True
    return False


def _code_series(module: ModuleInfo) -> Tuple[Dict[str, int], Set[str]]:
    """(series name -> first registration line, audit event names).

    Audit events share the ``tenant_`` prefix with real series; they are
    returned separately so the docs cross-check can document them in
    backticks without being flagged as dead series."""
    names: Dict[str, int] = {}
    events: Set[str] = set()
    for node in ast.walk(module.tree):
        if not (isinstance(node, ast.Constant)
                and isinstance(node.value, str)
                and _NAME_RE.fullmatch(node.value)):
            continue
        parent = getattr(node, "_lint_parent", None)
        if isinstance(parent, ast.Expr):
            continue                              # docstring
        if isinstance(parent, ast.Call) \
                and isinstance(parent.func, ast.Attribute) \
                and parent.func.attr == "record":
            events.add(node.value)                # audit event, not a series
            continue
        names.setdefault(node.value, node.lineno)
    return names, events


def _declared_tuples(module: ModuleInfo) -> Dict[str, Tuple[int, List[str]]]:
    """``*_METRICS_KEYS``-style tuple declarations: name -> (line, keys)."""
    out: Dict[str, Tuple[int, List[str]]] = {}
    for node in module.tree.body:
        if not (isinstance(node, (ast.Assign, ast.AnnAssign))):
            continue
        targets = node.targets if isinstance(node, ast.Assign) else [node.target]
        value = node.value
        for t in targets:
            if isinstance(t, ast.Name) and t.id.endswith("_KEYS") \
                    and isinstance(value, ast.Tuple):
                keys = [e.value for e in value.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, str)]
                out[t.id] = (node.lineno, keys)
    return out


class MetricsRule(Rule):
    name = "metrics"

    def applies(self, module: ModuleInfo) -> bool:
        return "serving" in module.parts

    def check(self, module: ModuleInfo) -> Iterable[Diagnostic]:
        return []          # cross-module rule; see check_modules

    def check_modules(self, modules: List[ModuleInfo]) -> Iterable[Diagnostic]:
        serving = [m for m in modules if self.applies(m)]
        if not serving:
            return []
        out: List[Diagnostic] = []

        # ------------------------------------------------ declared tuples
        declared: Set[str] = set()
        for m in serving:
            if m.name != "telemetry.py":
                continue
            for tup_name, (line, keys) in _declared_tuples(m).items():
                declared.update(keys)
                seen: Set[str] = set()
                for k in keys:
                    if k in seen:
                        d = m.diag(line, self.name,
                                   f"duplicate key {k!r} in {tup_name}")
                        if d:
                            out.append(d)
                    seen.add(k)

        # ------------------------------------------------- series vs docs
        code: Dict[str, Tuple[ModuleInfo, int]] = {}
        audit_events: Set[str] = set()
        for m in serving:
            names, events = _code_series(m)
            audit_events.update(events)
            for name, line in names.items():
                code.setdefault(name, (m, line))

        docs = _find_docs({Path(m.root) for m in serving})
        if docs is not None:
            doc_names: Dict[str, int] = {}
            for i, text in enumerate(docs.read_text().splitlines(), start=1):
                for token in _DOC_TOKEN_RE.findall(text):
                    if _DOC_NAME_RE.fullmatch(token):
                        for name in _expand_braces(token):
                            doc_names.setdefault(name, i)
            docs_rel = os.path.relpath(docs)
            for name, (m, line) in sorted(code.items()):
                if name not in doc_names:
                    d = m.diag(line, self.name,
                               f"Prometheus series `{name}` is not "
                               f"documented in {docs.name}")
                    if d:
                        out.append(d)
            for name, line in sorted(doc_names.items()):
                if name not in code and name not in audit_events:
                    out.append(Diagnostic(
                        path=docs_rel, line=line, rule=self.name,
                        message=f"documented series `{name}` is not "
                                f"registered anywhere in serving/"))

        # ------------------------------- export table keys are declared
        if declared:
            for m in serving:
                for node in ast.walk(m.tree):
                    if not (isinstance(node, ast.Tuple)
                            and len(node.elts) >= 2):
                        continue
                    k, prom = node.elts[0], node.elts[1]
                    if not (isinstance(k, ast.Constant)
                            and isinstance(k.value, str)
                            and isinstance(prom, ast.Constant)
                            and isinstance(prom.value, str)
                            and _NAME_RE.fullmatch(prom.value)):
                        continue
                    if not _declared_match(k.value, declared):
                        d = m.diag(node, self.name,
                                   f"stats key {k.value!r} exported as "
                                   f"`{prom.value}` is not declared in any "
                                   f"*_METRICS_KEYS tuple")
                        if d:
                            out.append(d)
        return out
