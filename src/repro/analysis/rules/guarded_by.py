"""RULE-GUARDED-BY: annotated cross-thread fields obey their guard.

The staged-sync worker (``updates.py``), the chaos transport
(``transport.py``), and the license-lease machine (``fleet.py``) share
mutable state across threads under two disciplines:

* a real lock — every touch happens inside ``with self.<lock>:``;
* single-writer ownership handed off through the bounded fetch queue
  and thread join — the field is only ever written by a known set of
  methods, and cross-thread visibility rides the queue/join barrier
  (dynamically validated by :mod:`repro.analysis.lockstep`).

Fields declare which discipline protects them with a trailing comment
on their declaring assignment::

    self._counts = {}          # guarded-by: _lock
    self._cursor = None        # guarded-by: owner(begin, _reopen, abort)

Grammar: ``# guarded-by: <attr>`` names a lock attribute on the same
object — every *write* to the field elsewhere in the module must be
lexically inside ``with <obj>.<attr>:``.  ``# guarded-by:
owner(f1, f2, ...)`` lists the only functions (including any lexically
enclosing nested function) allowed to write the field.  The declaring
line itself is exempt.  The static rule checks writes; read-side safety
of owner-guarded fields is the lockstep checker's job.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Tuple

from repro.analysis.lint import Diagnostic, ModuleInfo, ancestors
from repro.analysis.rules import Rule, _attr_chain

_ANNOT_RE = re.compile(r"#\s*guarded-by:\s*([^#]+?)\s*$")
_OWNER_RE = re.compile(r"owner\(([^)]*)\)")

_SCOPED_FILES = {"updates.py", "transport.py", "fleet.py"}


def _parse_annotations(module: ModuleInfo) -> Dict[str, Tuple[str, object,
                                                              int]]:
    """field name -> ("lock", lock_attr, declaring line) or
    ("owner", frozenset(names), declaring line)."""
    guards: Dict[str, Tuple[str, object, int]] = {}
    annotated: Dict[int, str] = {}
    for i, text in enumerate(module.lines, start=1):
        m = _ANNOT_RE.search(text)
        if m:
            annotated[i] = m.group(1).strip()
    if not annotated:
        return guards
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Assign):
            targets = node.targets
        elif isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            targets = [node.target]
        else:
            continue
        spec = annotated.get(node.lineno)
        if spec is None:
            continue
        for t in targets:
            if isinstance(t, ast.Attribute):
                om = _OWNER_RE.fullmatch(spec)
                if om:
                    owners = frozenset(
                        s.strip() for s in om.group(1).split(",") if s.strip())
                    guards[t.attr] = ("owner", owners, node.lineno)
                else:
                    guards[t.attr] = ("lock", spec.lstrip("self").lstrip("."),
                                      node.lineno)
    return guards


def _store_fields(node: ast.AST) -> List[ast.Attribute]:
    """Attribute stores in an assignment target (handles tuple targets)."""
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and isinstance(n.ctx, ast.Store):
            out.append(n)
    return out


def _under_lock(node: ast.AST, lock: str) -> bool:
    for parent in ancestors(node):
        if isinstance(parent, ast.With):
            for item in parent.items:
                chain = _attr_chain(item.context_expr)
                if chain and chain[-1] == lock:
                    return True
    return False


def _enclosing_functions(node: ast.AST) -> List[str]:
    return [p.name for p in ancestors(node)
            if isinstance(p, (ast.FunctionDef, ast.AsyncFunctionDef))]


class GuardedByRule(Rule):
    name = "guarded-by"

    def applies(self, module: ModuleInfo) -> bool:
        return (module.name in _SCOPED_FILES
                or any("guarded-by:" in ln for ln in module.lines))

    def check(self, module: ModuleInfo) -> Iterable[Diagnostic]:
        if not self.applies(module):
            return []
        guards = _parse_annotations(module)
        if not guards:
            return []
        out: List[Diagnostic] = []
        for node in ast.walk(module.tree):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                stores = _store_fields(node)
            else:
                continue
            for attr in stores:
                guard = guards.get(attr.attr)
                if guard is None:
                    continue
                kind, spec, decl_line = guard
                if node.lineno == decl_line:
                    continue                    # the declaration itself
                if kind == "lock":
                    if _under_lock(node, spec):
                        continue
                    d = module.diag(
                        node, self.name,
                        f"write to `{attr.attr}` (guarded-by: {spec}) "
                        f"outside `with ...{spec}:`")
                else:
                    encl = _enclosing_functions(node)
                    if any(fn in spec for fn in encl):
                        continue
                    where = encl[0] if encl else "<module>"
                    d = module.diag(
                        node, self.name,
                        f"write to `{attr.attr}` in `{where}` but its "
                        f"guarded-by owner set is {sorted(spec)}")
                if d:
                    out.append(d)
        return out
