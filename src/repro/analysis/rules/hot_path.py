"""RULE-HOT-PATH: no host<->device sync inside scheduler/allocator loops.

The serving loop's latency contract (one bounded host transfer per
scheduler step, at the step boundary) dies quietly when a per-lane loop
body forces a device sync: ``.block_until_ready()``,
``jax.device_get(...)``, or ``float()/int()/np.asarray()`` applied to a
traced/device value all stall the dispatch pipeline once per iteration
instead of once per step.

Checks, over the serving step-loop modules (scheduler, paging, gateway,
fleet, engine):

* any ``.block_until_ready`` use — benchmarks are the only sanctioned
  callers and they live outside ``src/repro`` (flagged anywhere in the
  module, loops or not);
* ``jax.device_get(...)`` calls (same scope: the serving path transfers
  via one ``np.asarray`` per step at the boundary, never device_get);
* inside ``for``/``while`` bodies only: ``float(...)``, ``int(...)``,
  ``np.asarray(...)``, ``np.array(...)`` whose argument expression
  references ``jnp``/``jax`` — the textual device-value heuristic that
  catches per-lane materialization while leaving the sanctioned
  once-per-step ``outs = np.asarray(outs)`` (outside any loop) alone.
  Host->device staging (``jnp.asarray(host_list)``) is not a sync and
  stays legal.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.lint import Diagnostic, ModuleInfo, ancestors
from repro.analysis.rules import Rule, _attr_chain

_SCOPED_FILES = {"scheduler.py", "paging.py", "gateway.py", "fleet.py",
                 "engine.py"}
_CASTS = {"float", "int"}


def _mentions_device(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Name) and n.id in ("jnp", "jax"):
            return True
    return False


def _in_loop(node: ast.AST) -> bool:
    child: ast.AST = node
    for parent in ancestors(node):
        if isinstance(parent, (ast.For, ast.While)) \
                and child is not getattr(parent, "iter", None) \
                and child is not getattr(parent, "test", None):
            return True
        if isinstance(parent, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.Lambda)):
            return False          # nested fn bodies judged on their own
        child = parent
    return False


class HotPathRule(Rule):
    name = "hot-path"

    def applies(self, module: ModuleInfo) -> bool:
        return "serving" in module.parts and module.name in _SCOPED_FILES

    def check(self, module: ModuleInfo) -> Iterable[Diagnostic]:
        if not self.applies(module):
            return []
        out: List[Diagnostic] = []
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Attribute) \
                    and node.attr == "block_until_ready":
                d = module.diag(
                    node, self.name,
                    "`.block_until_ready` in the serving path forces a "
                    "device sync; only benchmarks may fence explicitly")
                if d:
                    out.append(d)
                continue
            if not isinstance(node, ast.Call):
                continue
            chain = _attr_chain(node.func)
            if chain == ["jax", "device_get"]:
                d = module.diag(
                    node, self.name,
                    "`jax.device_get` in the serving path; transfer once "
                    "per step via np.asarray at the step boundary")
                if d:
                    out.append(d)
                continue
            is_cast = (isinstance(node.func, ast.Name)
                       and node.func.id in _CASTS)
            is_np_mat = chain in (["np", "asarray"], ["np", "array"],
                                  ["numpy", "asarray"], ["numpy", "array"])
            if (is_cast or is_np_mat) and node.args \
                    and _mentions_device(node.args[0]) and _in_loop(node):
                what = (node.func.id if is_cast else ".".join(chain))
                d = module.diag(
                    node, self.name,
                    f"`{what}(...)` on a device value inside a step loop "
                    f"syncs per iteration; hoist the transfer to the "
                    f"step boundary")
                if d:
                    out.append(d)
        return out
