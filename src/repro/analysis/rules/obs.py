"""RULE-OBS: telemetry/trace/audit record sites stay behind ``self.obs``.

The observability layer's <3% overhead gate (docs/OBSERVABILITY.md)
holds because every *record* call in the serving hot path — span
begin/end/instant/complete/counter on a tracer, ``audit.record``, and
histogram ``observe`` — is guarded by one pre-computed ``obs`` bool, so
a ``telemetry=False`` gateway never builds attribute dicts or touches
the tape.  This rule flags any record site in ``serving/`` that is not
lexically under an ``obs`` guard.

Read-side exports (``chrome_trace``, ``span_names``, ``events``,
``render_*``) are not record sites, and the instrument *implementations*
(``telemetry.py`` / ``tracing.py``) are exempt — the guard lives at the
call site, not inside the instrument.

Recognized guards: an enclosing ``if <...>.obs:`` (or ``and``-compound)
statement/ternary with the site on the true branch, or an early
``if not <...>.obs: return`` at the top level of the enclosing function.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from repro.analysis.lint import Diagnostic, ModuleInfo, ancestors
from repro.analysis.rules import Rule, _attr_chain

_TRACER_METHODS = {"begin", "end", "instant", "complete", "counter"}
_EXEMPT_FILES = {"telemetry.py", "tracing.py"}
_OBS_ONLY = frozenset({"obs"})
_OBS_AND_AUDIT = frozenset({"obs", "audit"})


def _mentions(test: ast.AST, names: frozenset) -> bool:
    for n in ast.walk(test):
        if isinstance(n, ast.Attribute) and n.attr in names:
            return True
        if isinstance(n, ast.Name) and n.id in names:
            return True
    return False


def _in_true_branch(parent: ast.AST, child: ast.AST) -> bool:
    if isinstance(parent, ast.If):
        return child in parent.body or child is parent.test
    if isinstance(parent, ast.IfExp):
        return child is parent.body or child is parent.test
    return False


def _guarded(node: ast.AST, guard_names: frozenset) -> bool:
    child: ast.AST = node
    func = None
    for parent in ancestors(node):
        if isinstance(parent, (ast.If, ast.IfExp)) \
                and _mentions(parent.test, guard_names) \
                and _in_true_branch(parent, child):
            return True
        if func is None and isinstance(
                parent, (ast.FunctionDef, ast.AsyncFunctionDef)):
            func = parent
            # early-out guard: ``if not self.obs: return`` before the site
            for stmt in func.body:
                if stmt.lineno >= node.lineno:
                    break
                if (isinstance(stmt, ast.If) and not stmt.orelse
                        and isinstance(stmt.test, ast.UnaryOp)
                        and isinstance(stmt.test.op, ast.Not)
                        and _mentions(stmt.test.operand, guard_names)
                        and all(isinstance(s, (ast.Return, ast.Raise))
                                for s in stmt.body)):
                    return True
        child = parent
    return False


def _is_record_site(node: ast.Call) -> str:
    fn = node.func
    if not isinstance(fn, ast.Attribute):
        return ""
    if fn.attr == "observe":
        return "histogram observe"
    chain = _attr_chain(fn)
    if fn.attr in _TRACER_METHODS and "tracer" in chain[:-1]:
        return f"tracer.{fn.attr}"
    if fn.attr == "record" and "audit" in chain[:-1]:
        return "audit.record"
    return ""


class ObsRule(Rule):
    name = "obs"

    def applies(self, module: ModuleInfo) -> bool:
        return ("serving" in module.parts
                and module.name not in _EXEMPT_FILES)

    def check(self, module: ModuleInfo) -> Iterable[Diagnostic]:
        if not self.applies(module):
            return []
        out: List[Diagnostic] = []
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            kind = _is_record_site(node)
            if not kind:
                continue
            # an audit site may also be guarded by the optional-audit
            # idiom ``if self.audit is not None:`` (registries that have
            # no obs flag and receive the log by injection)
            names = (_OBS_AND_AUDIT if kind == "audit.record"
                     else _OBS_ONLY)
            if _guarded(node, names):
                continue
            d = module.diag(
                node, self.name,
                f"unguarded {kind} record site; wrap it in "
                f"`if self.obs:` so telemetry=False serving pays nothing")
            if d:
                out.append(d)
        return out
