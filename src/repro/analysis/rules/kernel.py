"""RULE-KERNEL: every Pallas kernel is oracle-paired and test-runnable.

The kernel contract (``kernels/ref.py``): each ``pl.pallas_call`` site
ships with a pure-jnp oracle of the same name that tests
``assert_allclose`` against, and an ``interpret=`` seam so the kernel
*body* runs on CPU CI.  Donation must line up with aliasing — a jit
wrapper that donates its buffer but whose kernel never aliases an
operand silently clones the buffer anyway, voiding the in-place
contract staged sync relies on.

Checks, per module under ``kernels/`` (the oracle file itself, the
``ops.py`` dispatch layer, and ``__init__.py`` are exempt):

* every ``pl.pallas_call(...)`` passes an explicit ``interpret=`` kwarg
  (the CPU-test seam);
* every public kernel entry (top-level jit-wrapped function, or any
  public function whose body reaches a ``pallas_call``) has a same-named
  oracle in the sibling ``ref.py`` (prefix match covers ``_inplace``
  variants);
* a jit wrapper declaring ``donate_argnums`` requires at least one
  ``pallas_call`` in the module carrying ``input_output_aliases``;
* literal ``input_output_aliases`` keys must index real operands of the
  call (operand indices count scalar-prefetch args first, matching
  Pallas semantics).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Iterable, List, Optional, Set

from repro.analysis.lint import Diagnostic, ModuleInfo
from repro.analysis.rules import Rule, _attr_chain

_EXEMPT = {"ref.py", "ops.py", "__init__.py"}


def _ref_names(module: ModuleInfo) -> Optional[Set[str]]:
    ref = Path(module.path).parent / "ref.py"
    if not ref.is_file():
        return None
    try:
        tree = ast.parse(ref.read_text())
    except (OSError, SyntaxError):
        return None
    return {n.name for n in tree.body if isinstance(n, ast.FunctionDef)}


def _pallas_calls(tree: ast.AST) -> List[ast.Call]:
    return [n for n in ast.walk(tree)
            if isinstance(n, ast.Call)
            and _attr_chain(n.func)[-1:] == ["pallas_call"]]


def _kw(call: ast.Call, name: str) -> Optional[ast.expr]:
    for k in call.keywords:
        if k.arg == name:
            return k.value
    return None


def _has_alias_dict(expr: Optional[ast.expr]) -> bool:
    """True when the expression can produce a non-empty alias mapping."""
    if expr is None:
        return False
    for n in ast.walk(expr):
        if isinstance(n, ast.Dict) and n.keys:
            return True
    return False


def _donates(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        for n in ast.walk(dec):
            if isinstance(n, ast.keyword) and n.arg == "donate_argnums":
                return True
    return False


def _is_jit_wrapped(fn: ast.FunctionDef) -> bool:
    for dec in fn.decorator_list:
        for n in ast.walk(dec):
            if _attr_chain(n)[-1:] == ["jit"]:
                return True
    return False


class KernelRule(Rule):
    name = "kernel"

    def applies(self, module: ModuleInfo) -> bool:
        return "kernels" in module.parts and module.name not in _EXEMPT

    def check(self, module: ModuleInfo) -> Iterable[Diagnostic]:
        if not self.applies(module):
            return []
        calls = _pallas_calls(module.tree)
        if not calls:
            return []
        out: List[Diagnostic] = []

        for call in calls:
            if _kw(call, "interpret") is None:
                d = module.diag(
                    call, self.name,
                    "pl.pallas_call without an `interpret=` kwarg; the "
                    "kernel body must be runnable on CPU CI")
                if d:
                    out.append(d)
            alias = _kw(call, "input_output_aliases")
            parent = getattr(call, "_lint_parent", None)
            if isinstance(alias, ast.Dict) and alias.keys \
                    and isinstance(parent, ast.Call) and parent.func is call:
                n_ops = len(parent.args)
                for key in alias.keys:
                    if isinstance(key, ast.Constant) \
                            and isinstance(key.value, int) \
                            and key.value >= n_ops:
                        d = module.diag(
                            call, self.name,
                            f"input_output_aliases key {key.value} exceeds "
                            f"the call's {n_ops} operands")
                        if d:
                            out.append(d)

        refs = _ref_names(module)
        has_pallas_fn: Set[str] = set()
        for fn in [n for n in module.tree.body
                   if isinstance(n, ast.FunctionDef)]:
            if any(c in ast.walk(fn) for c in calls):
                has_pallas_fn.add(fn.name)
        for fn in [n for n in module.tree.body
                   if isinstance(n, ast.FunctionDef)]:
            if fn.name.startswith("_"):
                continue
            if not (_is_jit_wrapped(fn) or fn.name in has_pallas_fn):
                continue
            if refs is None:
                d = module.diag(
                    fn, self.name,
                    f"kernel entry `{fn.name}` has no sibling ref.py to "
                    f"hold its oracle")
                if d:
                    out.append(d)
                continue
            if not any(fn.name == r or fn.name.startswith(r + "_")
                       or fn.name.startswith(r) for r in refs):
                d = module.diag(
                    fn, self.name,
                    f"kernel entry `{fn.name}` has no oracle counterpart "
                    f"in ref.py")
                if d:
                    out.append(d)

        if any(_donates(fn) for fn in module.tree.body
               if isinstance(fn, ast.FunctionDef)) \
                and not any(_has_alias_dict(_kw(c, "input_output_aliases"))
                            for c in calls):
            fn = next(f for f in module.tree.body
                      if isinstance(f, ast.FunctionDef) and _donates(f))
            d = module.diag(
                fn, self.name,
                f"`{fn.name}` declares donate_argnums but no pallas_call "
                f"in this module aliases an operand; the donated buffer "
                f"is silently copied")
            if d:
                out.append(d)
        return out
