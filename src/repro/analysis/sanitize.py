"""Opt-in runtime sanitizers for the serving path.

Two independent detectors, wired into a gateway by constructing it with
``sanitize=True`` (or exporting ``REPRO_SANITIZE=1``):

* **Block sanitizer** — a shadow refcount model of the
  :class:`~repro.serving.paging.BlockAllocator`, mirrored by wrapping
  the allocator's four mutators on the live instance.  It catches, at
  the *first wrong operation* rather than at the eventual crash:
  double-free / decref of a dead block, free of a still-shared block,
  allocation handing out a live block, a block-table entry pointing at a
  freed block, a decode write landing on a shared block that should have
  been CoW-copied first, and blocks still held after the gateway drains
  with no request or prefix-tree reference to them (a leak).
* **Retrace sentinel** — counts distinct jit specializations per entry
  point family against the pow2-bucket bound the gateway's design
  promises (sampling variants, chunked-prefill ``(batch, cols)``
  buckets, decode table width).  A shape that escapes its bucket shows
  up as an over-bound family, not as mysterious p99 latency.

Every violation raises :class:`SanitizerError` — loud and synchronous,
because the sanitizer's job is pinpointing the op that broke the
invariant.  The wrappers cost one dict op per allocator call and are
never installed unless sanitizing, so production serving pays nothing.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Iterable, List, Set

__all__ = ["SanitizerError", "RetraceSentinel", "ServingSanitizer",
           "sanitize_from_env"]


class SanitizerError(RuntimeError):
    """A serving invariant was violated (block lifecycle or retracing)."""


def sanitize_from_env() -> bool:
    """The ``REPRO_SANITIZE`` opt-in (the CI sanitizer lane sets it)."""
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


# --------------------------------------------------------------- retracing
class RetraceSentinel:
    """Count distinct compilation keys per jit entry family.

    ``note(family, key)`` records one specialization; exceeding the
    family's declared bound raises — the bound IS the design contract
    (pow2 bucketing keeps compilations logarithmic in config, not linear
    in traffic)."""

    def __init__(self) -> None:
        self._keys: Dict[str, Set[Any]] = {}
        self._bounds: Dict[str, int] = {}

    def bound(self, family: str, n: int) -> None:
        self._bounds[family] = int(n)

    def note(self, family: str, key: Any) -> None:
        keys = self._keys.setdefault(family, set())
        if key in keys:
            return
        keys.add(key)
        bound = self._bounds.get(family)
        if bound is not None and len(keys) > bound:
            raise SanitizerError(
                f"retracing sentinel: jit family {family!r} reached "
                f"{len(keys)} distinct specializations, over its bound "
                f"of {bound} — a shape is escaping its pow2 bucket "
                f"(keys: {sorted(map(repr, keys))})")

    def stats(self) -> Dict[str, int]:
        return {f: len(k) for f, k in self._keys.items()}


# ----------------------------------------------------------- block shadow
class ServingSanitizer:
    """Shadow-model sanitizer for one gateway/slot.

    ``attach_allocator`` must run before any block traffic (the shadow
    assumes it sees every mutation); the gateway calls the ``check_*``
    hooks at its step boundaries."""

    def __init__(self) -> None:
        self.shadow: Dict[int, int] = {}         # block id -> refcount
        self.retrace = RetraceSentinel()
        self._allocator: Any = None

    # ------------------------------------------------- allocator mirror
    def attach_allocator(self, allocator: Any) -> None:
        if self._allocator is not None:
            raise SanitizerError("sanitizer already attached")
        self._allocator = allocator
        if getattr(allocator, "num_held", 0):
            raise SanitizerError(
                "attach_allocator on an allocator with live blocks; the "
                "shadow must see every allocation")
        orig_alloc = allocator.alloc
        orig_free = allocator.free
        orig_incref = allocator.incref
        orig_decref = allocator.decref
        shadow = self.shadow

        def alloc(n: int):
            got = orig_alloc(n)
            if got is not None:
                for b in got:
                    if b in shadow:
                        raise SanitizerError(
                            f"allocator handed out block {b} which the "
                            f"shadow believes is live (ref "
                            f"{shadow[b]}) — free-list corruption")
                    shadow[b] = 1
                self._cross_check("alloc")
            return got

        def incref(b: int) -> int:
            if b not in shadow:
                raise SanitizerError(
                    f"incref of non-live block {b} (use-after-free)")
            ref = orig_incref(b)
            shadow[b] += 1
            self._cross_check("incref")
            return ref

        def decref(b: int) -> int:
            if b not in shadow:
                raise SanitizerError(
                    f"decref of non-live block {b} (double free)")
            ref = orig_decref(b)
            shadow[b] -= 1
            if shadow[b] == 0:
                del shadow[b]
            self._cross_check("decref")
            return ref

        def free(blocks) -> None:
            blist = list(blocks)
            for b in blist:
                if b not in shadow:
                    raise SanitizerError(
                        f"free of non-live block {b} (double free)")
                if shadow[b] != 1:
                    raise SanitizerError(
                        f"free of block {b} with shadow refcount "
                        f"{shadow[b]} — shared blocks must drop via decref")
            orig_free(blist)
            for b in blist:
                del shadow[b]
            self._cross_check("free")

        allocator.alloc = alloc
        allocator.incref = incref
        allocator.decref = decref
        allocator.free = free

    def _cross_check(self, op: str) -> None:
        real = getattr(self._allocator, "_ref", None)
        if real is not None and dict(real) != self.shadow:
            raise SanitizerError(
                f"shadow/allocator divergence after {op}: allocator "
                f"{dict(real)!r} vs shadow {self.shadow!r}")

    # ---------------------------------------------------- gateway hooks
    def check_decode_writes(self, reqs: Iterable[Any], pool: Any) -> None:
        """Post-CoW pre-write check: every table entry of every decoding
        request is live, and the block the next token lands in is
        exclusively owned (CoW must have split it)."""
        bs = int(pool.block_size)
        for req in reqs:
            if not req.blocks:
                continue
            for b in req.blocks:
                if b not in self.shadow:
                    raise SanitizerError(
                        f"request {req.rid}: block table entry {b} points "
                        f"at a freed block")
            w = min(req.pos // bs, len(req.blocks) - 1)
            tail = req.blocks[w]
            if self.shadow.get(tail, 0) > 1:
                raise SanitizerError(
                    f"request {req.rid}: decode write targets block "
                    f"{tail} with refcount {self.shadow[tail]} — write "
                    f"to a shared block without CoW")

    def after_step(self, gw: Any) -> None:
        """Step-boundary sweep: every request-held block is still live."""
        sched = gw.scheduler
        for req in list(sched.running) + list(sched.waiting):
            for b in req.blocks:
                if b not in self.shadow:
                    raise SanitizerError(
                        f"request {req.rid}: holds freed block {b} after "
                        f"step")
        self._cross_check("step")

    def check_drained(self, gw: Any) -> None:
        """Leak check at drain: a live block with no request and no
        prefix-tree node retaining it is unreachable — nothing can ever
        free it."""
        sched = gw.scheduler
        reachable: Set[int] = set()
        for req in list(sched.running) + list(sched.waiting):
            reachable.update(req.blocks)
        prefix = getattr(gw, "prefix", None)
        if prefix is not None:
            reachable.update(prefix._by_block.keys())
        leaked: List[int] = sorted(set(self.shadow) - reachable)
        if leaked:
            raise SanitizerError(
                f"leak at drain: blocks {leaked} still held with no "
                f"request or prefix reference "
                f"(refs {[self.shadow[b] for b in leaked]})")
