"""Project-specific AST lint pass over the serving tree.

Usage::

    python -m repro.analysis.lint src/            # lint a tree
    python -m repro.analysis.lint src/repro/serving/gateway.py

Each rule lives in its own module under :mod:`repro.analysis.rules` and
checks one serving invariant (see ``docs/ANALYSIS.md`` for the catalog).
Diagnostics carry ``path:line`` so editors and CI can jump to the site.
A finding is suppressed by putting ``# lint: allow-<rule>`` on the
flagged line or the line directly above it — e.g.::

    t0 = time.monotonic()   # lint: allow-clock

Exit status is 0 when the tree is clean, 1 when any diagnostic fired,
2 on usage errors — the contract the CI ``lint`` lane relies on.
"""
from __future__ import annotations

import argparse
import ast
import re
import sys
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set

__all__ = [
    "Diagnostic", "ModuleInfo", "load_module", "collect_modules",
    "run_paths", "render", "main",
]

_ALLOW_RE = re.compile(r"#\s*lint:\s*((?:allow-[A-Za-z0-9_-]+[,\s]*)+)")
_ALLOW_TOKEN_RE = re.compile(r"allow-([A-Za-z0-9_-]+)")


@dataclass(frozen=True)
class Diagnostic:
    """One finding: ``path:line: RULE-NAME: message``."""

    path: str
    line: int
    rule: str            # short kebab-case rule id, e.g. "clock"
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: RULE-{self.rule.upper()}: " \
               f"{self.message}"


@dataclass
class ModuleInfo:
    """A parsed source file plus the bits every rule needs: the AST with
    parent links, raw lines, and the per-line suppression sets."""

    path: Path
    root: Path                      # scan root the path was found under
    tree: ast.AST
    lines: List[str]
    suppressions: Dict[int, Set[str]] = field(default_factory=dict)

    @property
    def rel(self) -> str:
        try:
            return self.path.relative_to(self.root).as_posix()
        except ValueError:
            return self.path.as_posix()

    @property
    def name(self) -> str:
        return self.path.name

    @property
    def parts(self) -> Sequence[str]:
        return Path(self.rel).parts

    def suppressed(self, line: int, rule: str) -> bool:
        """A diagnostic at ``line`` is suppressed by an allow comment on
        that line or the line directly above."""
        for ln in (line, line - 1):
            if rule in self.suppressions.get(ln, ()):
                return True
        return False

    def diag(self, node_or_line, rule: str, message: str,
             ) -> Optional[Diagnostic]:
        line = (node_or_line if isinstance(node_or_line, int)
                else getattr(node_or_line, "lineno", 1))
        if self.suppressed(line, rule):
            return None
        return Diagnostic(self.rel, line, rule, message)


def _parse_suppressions(lines: List[str]) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, text in enumerate(lines, start=1):
        m = _ALLOW_RE.search(text)
        if m:
            out[i] = set(_ALLOW_TOKEN_RE.findall(m.group(1)))
    return out


def _link_parents(tree: ast.AST) -> None:
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._lint_parent = parent  # type: ignore[attr-defined]


def ancestors(node: ast.AST) -> Iterable[ast.AST]:
    cur = getattr(node, "_lint_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_lint_parent", None)


def load_module(path: Path, root: Path) -> Optional[ModuleInfo]:
    try:
        source = path.read_text()
        tree = ast.parse(source, filename=str(path))
    except (OSError, SyntaxError) as exc:
        print(f"lint: skipping {path}: {exc}", file=sys.stderr)
        return None
    _link_parents(tree)
    lines = source.splitlines()
    return ModuleInfo(path=path, root=root, tree=tree, lines=lines,
                      suppressions=_parse_suppressions(lines))


def collect_modules(paths: Sequence[Path]) -> List[ModuleInfo]:
    mods: List[ModuleInfo] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                if "__pycache__" in f.parts:
                    continue
                m = load_module(f, p)
                if m is not None:
                    mods.append(m)
        elif p.suffix == ".py":
            # anchor at the fs root so path-scoped rules ("serving" in
            # parts) still see the directory when given a lone file
            p = p.resolve()
            m = load_module(p, Path(p.anchor))
            if m is not None:
                mods.append(m)
        else:
            raise FileNotFoundError(f"not a python file or directory: {p}")
    return mods


def run_paths(paths: Sequence[Path], rules=None) -> List[Diagnostic]:
    """Lint ``paths`` (files or trees) and return all diagnostics."""
    from repro.analysis.rules import ALL_RULES

    rules = list(ALL_RULES if rules is None else rules)
    modules = collect_modules([Path(p) for p in paths])
    diags: List[Diagnostic] = []
    for rule in rules:
        diags.extend(rule.check_modules(modules))
    return sorted(diags, key=lambda d: (d.path, d.line, d.rule))


def render(diags: Sequence[Diagnostic]) -> str:
    return "\n".join(d.render() for d in diags)


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="serving-invariant lint pass (see docs/ANALYSIS.md)")
    parser.add_argument("paths", nargs="+",
                        help="files or directory trees to lint")
    parser.add_argument("--rule", action="append", default=None,
                        help="run only this rule id (repeatable), "
                             "e.g. --rule clock")
    args = parser.parse_args(argv)
    from repro.analysis.rules import ALL_RULES

    rules = ALL_RULES
    if args.rule:
        wanted = set(args.rule)
        rules = [r for r in ALL_RULES if r.name in wanted]
        unknown = wanted - {r.name for r in rules}
        if unknown:
            parser.error(f"unknown rule(s): {sorted(unknown)} "
                         f"(known: {[r.name for r in ALL_RULES]})")
    try:
        diags = run_paths(args.paths, rules)
    except FileNotFoundError as exc:
        parser.error(str(exc))
    if diags:
        print(render(diags))
        print(f"lint: {len(diags)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
