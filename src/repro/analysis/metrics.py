"""Runtime metrics-schema checking (the dynamic half of RULE-METRICS).

A ``metrics()`` dict is valid when every dotted leaf path is covered by
the declared key schema (``GATEWAY_METRICS_KEYS`` /
``FLEET_METRICS_KEYS`` in :mod:`repro.serving.telemetry`) — ``.*``
entries accept any leaf under a dynamic section (tier names, tenant
names, bucket widths).  This module owns the set-difference primitives;
``telemetry.validate_gateway_metrics`` / ``validate_fleet_metrics``
build their assertions on top of them, and the schema tests call
:func:`unregistered_metric_keys` directly.

(Promoted from an inline checker in ``tests/test_telemetry.py`` so the
same API serves tests, validators, and ad-hoc debugging.)
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List

__all__ = ["declared_match", "unregistered_metric_keys",
           "missing_metric_keys"]


def declared_match(path: str, declared: Iterable[str]) -> bool:
    """True when leaf ``path`` is covered by one declared key.

    A declared key ``a.b.*`` covers ``a.b`` itself and any leaf below
    it; anything else must match exactly."""
    for d in declared:
        if d.endswith(".*"):
            if path == d[:-2] or path.startswith(d[:-1]):
                return True
        elif path == d:
            return True
    return False


def unregistered_metric_keys(metrics: Dict[str, Any],
                             declared: Iterable[str]) -> List[str]:
    """Leaf paths of ``metrics`` not covered by the declared schema."""
    from repro.serving.telemetry import flatten_metric_keys

    declared = list(declared)
    return [p for p in flatten_metric_keys(metrics)
            if not declared_match(p, declared)]


def missing_metric_keys(metrics: Dict[str, Any],
                        declared: Iterable[str],
                        optional: Iterable[str] = ()) -> List[str]:
    """Declared keys with no witness in ``metrics`` (the reverse
    direction): an exact key must be present as a leaf, a ``.*`` key
    needs at least one leaf under its stem.  Keys in ``optional`` (and
    prefixes ending in ``.``) are configuration-dependent and skipped."""
    from repro.serving.telemetry import flatten_metric_keys

    flat = set(flatten_metric_keys(metrics))
    optional = list(optional)

    def _optional(decl: str) -> bool:
        return any(decl == o or (o.endswith(".") and decl.startswith(o))
                   for o in optional)

    def _present(decl: str) -> bool:
        if decl.endswith(".*"):
            stem = decl[:-2]
            return any(p == stem or p.startswith(stem + ".") for p in flat)
        return decl in flat

    return [d for d in declared if not _optional(d) and not _present(d)]
