"""AdamW + cosine schedule + global-norm clipping, from scratch (no optax
offline).  Optimizer state mirrors the param tree (m, v in f32 regardless
of param dtype — mixed-precision discipline) and shards identically to the
params under pjit, so data-parallel training needs no extra sharding rules.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray       # () int32
    m: Any                  # f32 tree like params
    v: Any                  # f32 tree like params


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    grad_accum: int = 1      # microbatches per step (activation-memory knob)


def init_state(params: Any) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros))


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def _decay_mask(path) -> bool:
    """No weight decay on norms/biases/1-D dynamics params."""
    name = "/".join(str(getattr(p, "key", getattr(p, "name", p))) for p in path)
    return not any(k in name for k in ("norm", "bias", "A_log", "dt_bias",
                                       "a_param", "D_skip"))


def apply_updates(
    params: Any, grads: Any, state: AdamWState, cfg: OptimizerConfig,
) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    flat_params = jax.tree_util.tree_leaves_with_path(params)
    decay_flags = [_decay_mask(path) for path, _ in flat_params]
    treedef = jax.tree_util.tree_structure(params)
    decay_tree = jax.tree_util.tree_unflatten(treedef, decay_flags)

    def upd(p, g, m, v, decay):
        g32 = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g32
        v_new = b2 * v + (1 - b2) * g32 * g32
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new, v_new

    out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v, decay_tree)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda t: isinstance(t, tuple) and len(t) == 3)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(step=step, m=new_m, v=new_v), metrics
