"""Training: AdamW optimizer + LM/MLP train loops with versioned checkpoints.

``train_mlp``/``finetune_pruned_mlp`` cover the paper's edge MLP (train,
prune, fine-tune); ``train_loop``/``make_train_step`` the LM-scale path.
"""
from repro.training.optimizer import AdamWState, OptimizerConfig, apply_updates, init_state
from repro.training.train_lib import (
    TrainState,
    finetune_pruned_mlp,
    init_mlp_params,
    make_train_step,
    mlp_accuracy,
    mlp_forward,
    train_loop,
    train_mlp,
)

__all__ = [
    "AdamWState", "OptimizerConfig", "apply_updates", "init_state", "TrainState",
    "finetune_pruned_mlp", "init_mlp_params", "make_train_step", "mlp_accuracy",
    "mlp_forward", "train_loop", "train_mlp",
]
