"""Training loop library: train_step (fwd+bwd+AdamW), metrics, and
WeightStore-backed checkpointing (the paper's versioned storage IS the
checkpoint substrate — every checkpoint is a delta commit)."""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.training import optimizer as opt_lib


@dataclass
class TrainState:
    params: Any
    opt_state: opt_lib.AdamWState

    def as_tuple(self):
        return (self.params, self.opt_state)


def make_train_step(
    cfg: ModelConfig, ocfg: opt_lib.OptimizerConfig,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics).

    ``batch`` = {tokens (B,S), labels (B,S)} (+ patch_embeds for VLM).
    Pure function — jit/pjit it with the mesh shardings at the call site.
    """

    def grad_fn(params, batch):
        def loss_fn(p):
            return model_lib.lm_loss(
                p, cfg, batch["tokens"], batch["labels"],
                patch_embeds=batch.get("patch_embeds"),
            )

        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        m = ocfg.grad_accum
        if m <= 1:
            (loss, parts), grads = grad_fn(params, batch)
        else:
            # microbatch over the leading batch dim; grads accumulate in f32
            from repro.models.layers import hint_sharding

            micro = jax.tree_util.tree_map(
                lambda x: hint_sharding(
                    x.reshape(m, x.shape[0] // m, *x.shape[1:]),
                    None, "batch", *([None] * (x.ndim - 1)),
                ),
                batch,
            )

            def accum(carry, mb):
                g_acc, l_acc, a_acc = carry
                (l, parts_i), g = grad_fn(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l, a_acc + parts_i["aux_loss"]), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss, aux), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros(()), jnp.zeros(())), micro
            )
            grads = jax.tree_util.tree_map(lambda g: g / m, grads)
            loss = loss / m
            parts = {"lm_loss": loss, "aux_loss": aux / m}
        new_params, new_opt, om = opt_lib.apply_updates(params, grads, opt_state, ocfg)
        metrics = {"loss": loss, **parts, **om}
        return new_params, new_opt, metrics

    return train_step


def train_loop(
    cfg: ModelConfig,
    ocfg: opt_lib.OptimizerConfig,
    batches: Iterator[Dict[str, np.ndarray]],
    num_steps: int,
    *,
    seed: int = 0,
    params: Any = None,
    log_every: int = 10,
    store=None,
    store_model: Optional[str] = None,
    checkpoint_every: int = 0,
    log_fn: Callable[[str], None] = print,
) -> Tuple[Any, Dict[str, list]]:
    """Single-host training driver (CPU-scale; the launcher handles pjit)."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = model_lib.init_params(key, cfg)
    opt_state = opt_lib.init_state(params)
    step_fn = jax.jit(make_train_step(cfg, ocfg))

    history: Dict[str, list] = {"loss": [], "step": []}
    t0 = time.time()
    for step in range(num_steps):
        batch = {k: jnp.asarray(v) for k, v in next(batches).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % log_every == 0 or step == num_steps - 1:
            loss = float(metrics["loss"])
            history["loss"].append(loss)
            history["step"].append(step)
            log_fn(f"step {step:5d}  loss {loss:.4f}  "
                   f"gnorm {float(metrics['grad_norm']):.3f}  "
                   f"lr {float(metrics['lr']):.2e}  "
                   f"({time.time() - t0:.1f}s)")
        if store is not None and checkpoint_every and (step + 1) % checkpoint_every == 0:
            store.commit(store_model or cfg.name, jax.device_get(params),
                         message=f"step {step + 1}")
    return params, history


# ------------------------------------------------------- paper-scale MLP
def init_mlp_params(key, mlp_cfg) -> Dict[str, Any]:
    dims = (mlp_cfg.in_dim, *mlp_cfg.hidden, mlp_cfg.num_classes)
    ks = jax.random.split(key, len(dims))
    params = {}
    for i in range(len(dims) - 1):
        params[f"layer{i + 1}"] = {
            "kernel": jax.random.normal(ks[i], (dims[i], dims[i + 1]), jnp.float32)
            * np.sqrt(2.0 / dims[i]),
            "bias_vec": jnp.zeros((dims[i + 1],), jnp.float32),
        }
    return params


def mlp_forward(params: Dict[str, Any], x: jnp.ndarray) -> jnp.ndarray:
    n = len(params)
    for i in range(1, n + 1):
        p = params[f"layer{i}"]
        x = x @ p["kernel"] + p["bias_vec"]
        if i < n:
            x = jax.nn.relu(x)
    return x


def mlp_accuracy(params, x: np.ndarray, y: np.ndarray) -> float:
    logits = mlp_forward(params, jnp.asarray(x))
    return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y)))


def train_mlp(
    mlp_cfg, x: np.ndarray, y: np.ndarray, *, steps: int = 300, lr: float = 1e-2,
    seed: int = 0, params=None, batch: int = 256,
) -> Dict[str, Any]:
    """Train the paper's small classifier to ~98% (or fine-tune pruned)."""
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = init_mlp_params(key, mlp_cfg)

    @jax.jit
    def step_fn(p, xb, yb):
        def loss(p):
            logits = mlp_forward(p, xb)
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

        g = jax.grad(loss)(p)
        return jax.tree_util.tree_map(lambda a, b: a - lr * b, p, g)

    rng = np.random.default_rng(seed)
    for _ in range(steps):
        idx = rng.integers(0, len(x), batch)
        params = step_fn(params, jnp.asarray(x[idx]), jnp.asarray(y[idx]))
    return params


def finetune_pruned_mlp(mlp_cfg, params, x, y, *, steps: int = 150, lr: float = 5e-3,
                        seed: int = 1):
    """Fine-tune while preserving the pruned mask (Fig. 3's fine-tune stage)."""
    masks = jax.tree_util.tree_map(lambda p: (np.asarray(p) != 0).astype(np.float32),
                                   params)

    @jax.jit
    def step_fn(p, xb, yb):
        def loss(p):
            logits = mlp_forward(p, xb)
            logp = jax.nn.log_softmax(logits)
            return -jnp.mean(jnp.take_along_axis(logp, yb[:, None], 1))

        g = jax.grad(loss)(p)
        return jax.tree_util.tree_map(lambda a, b, m: (a - lr * b) * m, p, g, masks)

    rng = np.random.default_rng(seed)
    for _ in range(steps):
        idx = rng.integers(0, len(x), 256)
        params = step_fn(params, jnp.asarray(x[idx]), jnp.asarray(y[idx]))
    return params
