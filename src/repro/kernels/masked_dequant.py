"""Pallas TPU kernel: fused int8 dequant + license-interval masking.

The paper applies license masks in the database layer (§3.5); at serve time
that would mean a dequant pass *plus* a mask pass over the weights — two
HBM round-trips for a purely memory-bound op.  Fusing them means the
licensed weight tensor is produced in exactly one read of the int8 codes
and one write of the output: dynamic licensing at zero marginal bandwidth.

Interval bounds arrive as two small (MAX_INTERVALS,) f32 arrays replicated
to every block (index_map -> 0); padding intervals have lo == hi and are
inert.  The interval loop is unrolled (MAX_INTERVALS is static), so the
kernel body is branch-free elementwise VPU work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MAX_INTERVALS = 8


def _kernel(codes_ref, scale_ref, lo_ref, hi_ref, out_ref, *, n_intervals: int):
    w = codes_ref[...].astype(jnp.float32) * scale_ref[...].astype(jnp.float32)
    mag = jnp.abs(w)
    dead = jnp.zeros(w.shape, dtype=jnp.bool_)
    for i in range(n_intervals):  # static unroll
        lo = lo_ref[0, i]
        hi = hi_ref[0, i]
        dead = dead | ((mag >= lo) & (mag < hi))
    out_ref[...] = jnp.where(dead, jnp.zeros_like(w), w).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_c", "out_dtype", "interpret")
)
def masked_dequant(
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    lo: jnp.ndarray,
    hi: jnp.ndarray,
    *,
    block_r: int = 256,
    block_c: int = 256,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """codes (R,C) int8, scale (1,C) or (R,1) f32, lo/hi (MAX_INTERVALS,).

    Returns licensed bf16/f32 weights: dequantized, zeroed where
    lo[i] <= |w| < hi[i] for any i.  Shapes pre-padded to block multiples.
    """
    r, c = codes.shape
    assert r % block_r == 0 and c % block_c == 0, (r, c, block_r, block_c)
    assert lo.shape == hi.shape == (MAX_INTERVALS,)
    # broadcast scale to a full-block-compatible layout
    if scale.shape == (1, c):
        scale_spec = pl.BlockSpec((1, block_c), lambda i, j: (0, j))
    elif scale.shape == (r, 1):
        scale_spec = pl.BlockSpec((block_r, 1), lambda i, j: (i, 0))
    elif scale.shape == (1, 1):
        scale_spec = pl.BlockSpec((1, 1), lambda i, j: (0, 0))
    else:
        raise ValueError(f"scale shape {scale.shape} not broadcastable to {(r, c)}")

    grid = (r // block_r, c // block_c)
    return pl.pallas_call(
        functools.partial(_kernel, n_intervals=MAX_INTERVALS),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            scale_spec,
            pl.BlockSpec((1, MAX_INTERVALS), lambda i, j: (0, 0)),
            pl.BlockSpec((1, MAX_INTERVALS), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), out_dtype),
        interpret=interpret,
    )(codes, scale, lo.reshape(1, -1), hi.reshape(1, -1))
