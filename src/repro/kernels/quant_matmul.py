"""Pallas TPU kernel: bf16-activation × int8-weight matmul with per-channel
dequantization — the licensed-serving hot path once the paper's quantization
pipeline (§3.2) is adopted.

TPU mapping (DESIGN.md §2): int8 codes stay packed in VMEM (half the bytes
of bf16, ~1/4 of f32), dequantize in-register right before the MXU dot.
Block shapes are MXU-aligned (multiples of 128 on M/N, 128 on K); the K grid
axis accumulates into the output block (revisiting — K is the innermost,
sequential grid dim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, codes_ref, scale_ref, out_ref, *, n_k: int, out_dtype):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)                       # (bm, bk)
    w = codes_ref[...].astype(jnp.float32)                   # (bk, bn)
    w = w * scale_ref[...].astype(jnp.float32)               # (1, bn) broadcast
    acc = jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    out_ref[...] += acc.astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "out_dtype", "interpret"),
)
def quant_matmul(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    """x (M,K) @ (codes (K,N) * scale (N,)) -> (M,N) in out_dtype.

    Shapes must be pre-padded to block multiples (``ops.quant_matmul`` pads).
    """
    m, k = x.shape
    k2, n = codes.shape
    assert k == k2 and scale.shape == (n,)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"unpadded shapes {(m, k, n)} vs blocks {(block_m, block_k, block_n)}"
    )
    grid = (m // block_m, n // block_n, k // block_k)
    return pl.pallas_call(
        functools.partial(_kernel, n_k=grid[2], out_dtype=out_dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(x, codes, scale.reshape(1, n))
