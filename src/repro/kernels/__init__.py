"""Pallas TPU kernels for the paper's serve/update hot paths.

- quant_matmul:    int8-weight matmul (post-compression serving, §3.2)
- masked_dequant:  fused dequant + license-interval mask (§3.5)
- delta_apply:     sparse weight-delta scatter (low-latency update, §4.3)
- flash_attention: online-softmax attention (GQA via index-map, sliding
  window, decode offsets) — the roofline-directed fix for the score-
  materialization traffic that dominates dense train/prefill rows

``ops`` holds the jit'd public wrappers; ``ref`` the pure-jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
