"""Pallas TPU kernels for the paper's serve/update hot paths.

- quant_matmul:    int8-weight matmul (post-compression serving, §3.2)
- masked_dequant:  fused dequant + license-interval mask (§3.5)
- delta_apply:     sparse weight-delta scatter (low-latency update, §4.3)
- flash_attention: online-softmax attention (GQA via index-map, sliding
  window, decode offsets) — the roofline-directed fix for the score-
  materialization traffic that dominates dense train/prefill rows
- paged_attention: decode attention over the block-paged KV pool
  (serving/paging.py) — the block table rides the grid as a scalar-
  prefetch operand so each step DMAs exactly the blocks the table names

``ops`` holds the jit'd public wrappers; ``ref`` the pure-jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
