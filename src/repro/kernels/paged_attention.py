"""Pallas TPU kernel: paged-attention decode through a block table.

The paged cache pool (``serving/paging.py``) stores K/V as fixed-size
physical blocks shared by every request; a request's logical cache is the
concatenation of the blocks named by its **block table**.  The host-side
serving path materializes that view with a gather before the vmapped
decode — one extra HBM round-trip per step.  This kernel removes it: the
block table rides the grid as a **scalar-prefetch** operand, so each
(sequence, block) grid step DMAs exactly the physical K/V block the table
names straight into VMEM — decode reads each byte of cache exactly once,
with no contiguous copy of the sequence ever existing.

Layout: one query token per sequence (decode), GQA handled in-kernel by
reshaping the query to (kv_heads, group, head_dim) and unrolling the
(static, small) kv-head loop into 2-D MXU dots.  Online-softmax running
stats (m, l) persist in output refs across the sequential innermost
block-table axis, exactly like ``flash_attention.py``; positions at or
beyond a sequence's ``context_lens`` (including anything read through
null/pad table entries) are masked inert.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            *, scale: float, block_size: int, kv_heads: int, groups: int,
            n_blocks: int):
    b = pl.program_id(0)
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    ctx = lens_ref[b]

    @pl.when(t * block_size < ctx)          # skip fully-dead blocks
    def _block():
        q = q_ref[0].astype(jnp.float32) * scale          # (H, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bs, KH, hd)
        v = v_ref[0].astype(jnp.float32)
        h, hd = q.shape
        qg = q.reshape(kv_heads, groups, hd)

        k_pos = t * block_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_size), 1)[0]
        valid = k_pos < ctx                               # (bs,)

        # per-kv-head 2-D dots (KH is static and small -> unrolled)
        s = jnp.stack([
            jax.lax.dot_general(qg[kh], k[:, kh], (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
            for kh in range(kv_heads)
        ], 0).reshape(h, block_size)
        s = jnp.where(valid[None, :], s, NEG_INF)

        m_prev = m_ref[0]
        l_prev = l_ref[0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.where(valid[None, :], jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        alpha = jnp.where(m_prev == NEG_INF, 0.0, alpha)
        pg = p.reshape(kv_heads, groups, block_size)
        acc = jnp.stack([
            jax.lax.dot_general(pg[kh], v[:, kh], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
            for kh in range(kv_heads)
        ], 0).reshape(h, hd)
        o_ref[0] = o_ref[0] * alpha[:, None] + acc
        m_ref[0] = m_new
        l_ref[0] = l_prev * alpha + jnp.sum(p, axis=-1)

    @pl.when(t == n_blocks - 1)
    def _normalize():
        o_ref[0] = o_ref[0] / jnp.maximum(l_ref[0], 1e-20)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(
    q: jnp.ndarray,           # (B, H, hd)   one decode query per sequence
    k_blocks: jnp.ndarray,    # (P, bs, KH, hd) physical key blocks
    v_blocks: jnp.ndarray,    # (P, bs, KH, hd) physical value blocks
    block_tables: jnp.ndarray,  # (B, T) int32; entry t covers positions
                                # [t*bs, (t+1)*bs); pad entries may point
                                # anywhere in [0, P) — they are masked
    context_lens: jnp.ndarray,  # (B,) int32 valid cache length (pos + 1)
    *,
    interpret: bool = False,
) -> jnp.ndarray:
    """Decode attention over a block-paged KV cache; returns (B, H, hd) f32.

    GQA via ``H == KH * groups``.  The block table and context lengths are
    scalar-prefetched so the BlockSpec index map can route each grid step's
    DMA through the table — the gather lives in the kernel, not in HBM.
    """
    b, h, hd = q.shape
    p_blocks, bs, kh, _ = k_blocks.shape
    assert v_blocks.shape == k_blocks.shape, (v_blocks.shape, k_blocks.shape)
    assert h % kh == 0, (h, kh)
    groups = h // kh
    n_t = block_tables.shape[1]
    assert block_tables.shape[0] == b and context_lens.shape == (b,)
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _kernel, scale=scale, block_size=bs, kv_heads=kh, groups=groups,
        n_blocks=n_t)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, n_t),
        in_specs=[
            pl.BlockSpec((1, h, hd), lambda b, t, tab, ln: (b, 0, 0)),
            pl.BlockSpec((1, bs, kh, hd),
                         lambda b, t, tab, ln: (tab[b, t], 0, 0, 0)),
            pl.BlockSpec((1, bs, kh, hd),
                         lambda b, t, tab, ln: (tab[b, t], 0, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, h, hd), lambda b, t, tab, ln: (b, 0, 0)),
            pl.BlockSpec((1, h), lambda b, t, tab, ln: (b, 0)),
            pl.BlockSpec((1, h), lambda b, t, tab, ln: (b, 0)),
        ],
    )
    out, _, _ = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
            jax.ShapeDtypeStruct((b, h), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(block_tables, jnp.int32),
      jnp.asarray(context_lens, jnp.int32), q, k_blocks, v_blocks)
    return out


def _write_kernel(blocks_ref, offs_ref, nk_ref, nv_ref, kb_ref, vb_ref,
                  ok_ref, ov_ref):
    # the scalars are consumed by the index maps; the aliased pools are
    # written through the out refs, never read
    del blocks_ref, offs_ref, kb_ref, vb_ref
    ok_ref[0, 0] = nk_ref[0].astype(ok_ref.dtype)
    ov_ref[0, 0] = nv_ref[0].astype(ov_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_write(
    k_blocks: jnp.ndarray,    # (P, bs, KH, hd) physical key blocks
    v_blocks: jnp.ndarray,    # (P, bs, KH, hd) physical value blocks
    new_k: jnp.ndarray,       # (B, KH, hd)  this step's key, one per lane
    new_v: jnp.ndarray,       # (B, KH, hd)  this step's value
    block_ids: jnp.ndarray,   # (B,) int32 physical block receiving the token
    offsets: jnp.ndarray,     # (B,) int32 row inside that block
    *,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Block-indexed scatter of ONE K/V token per lane — the write half of
    kernel-resident paged decode.

    Each lane's token lands at ``(block_ids[b], offsets[b])``; the block
    ids and offsets ride as scalar-prefetch operands so the output
    BlockSpec routes every grid step's (1, 1, KH, hd) store straight to
    its physical row, and ``input_output_aliases`` makes the update
    in-place — the untouched 2 * (P - B) blocks are never copied.  Pad
    lanes target the pool's null block (duplicates allowed: the null
    block absorbs garbage by contract).  Oracle: ``ref.paged_decode_write``.
    """
    b, kh, hd = new_k.shape
    assert new_v.shape == new_k.shape, (new_v.shape, new_k.shape)
    assert block_ids.shape == offsets.shape == (b,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, kh, hd), lambda i, blk, off: (i, 0, 0)),
            pl.BlockSpec((1, kh, hd), lambda i, blk, off: (i, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),   # aliased k pool (unread)
            pl.BlockSpec(memory_space=pltpu.ANY),   # aliased v pool (unread)
        ],
        out_specs=[
            pl.BlockSpec((1, 1, kh, hd),
                         lambda i, blk, off: (blk[i], off[i], 0, 0)),
            pl.BlockSpec((1, 1, kh, hd),
                         lambda i, blk, off: (blk[i], off[i], 0, 0)),
        ],
    )
    return pl.pallas_call(
        _write_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(k_blocks.shape, k_blocks.dtype),
            jax.ShapeDtypeStruct(v_blocks.shape, v_blocks.dtype),
        ],
        # alias the block pools through (operand indices count the scalar
        # prefetch args): only the B addressed rows are ever written
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(jnp.asarray(block_ids, jnp.int32), jnp.asarray(offsets, jnp.int32),
      new_k, new_v, k_blocks, v_blocks)
