"""Public jit'd wrappers around the Pallas kernels.

Handles padding to block multiples, backend selection (interpret=True on
CPU so the kernel *body* is what runs in tests), and the pure-jnp fallback
for shapes too small to tile.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import delta_apply as _delta
from repro.kernels import masked_dequant as _mask
from repro.kernels import quant_matmul as _qmm
from repro.kernels import ref

MAX_INTERVALS = _mask.MAX_INTERVALS


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_to(x: jnp.ndarray, mults: Tuple[int, ...], value=0) -> jnp.ndarray:
    pads = []
    for dim, m in zip(x.shape, mults):
        pads.append((0, (-dim) % m))
    if not any(p[1] for p in pads):
        return x
    return jnp.pad(x, pads, constant_values=value)


def quant_matmul(
    x: jnp.ndarray,
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    *,
    out_dtype=None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Activation (…,K) × int8 weights (K,N) with per-channel scales (N,)."""
    out_dtype = out_dtype or x.dtype
    interpret = _on_cpu() if interpret is None else interpret
    lead = x.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    k = x.shape[-1]
    n = codes.shape[-1]
    x2 = x.reshape(m, k)
    # tiny shapes: pallas tiling has no win; use the oracle (identical math)
    if m * n * k < 128 * 128 * 128:
        return ref.quant_matmul(x2, codes, scale, out_dtype).reshape(*lead, n)
    bm = min(block_m, max(8, 1 << (m - 1).bit_length()))
    xp = _pad_to(x2, (bm, block_k))
    cp = _pad_to(codes, (block_k, block_n))
    sp = _pad_to(scale, (block_n,))
    out = _qmm.quant_matmul(
        xp, cp, sp, block_m=bm, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:m, :n].reshape(*lead, n)


def pack_intervals(intervals: Sequence[Tuple[float, float]]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pad a license tier's interval list to (MAX_INTERVALS,) lo/hi arrays."""
    ivs = list(intervals)[:MAX_INTERVALS]
    lo = np.zeros(MAX_INTERVALS, np.float32)
    hi = np.zeros(MAX_INTERVALS, np.float32)
    for i, (a, b) in enumerate(ivs):
        lo[i], hi[i] = a, b
    return jnp.asarray(lo), jnp.asarray(hi)


def masked_dequant(
    codes: jnp.ndarray,
    scale: jnp.ndarray,
    intervals: Sequence[Tuple[float, float]] = (),
    *,
    out_dtype=jnp.float32,
    block_r: int = 256,
    block_c: int = 256,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Licensed weights from int8 codes in one fused pass (paper §3.5)."""
    interpret = _on_cpu() if interpret is None else interpret
    lo, hi = pack_intervals(intervals)
    r, c = codes.shape
    if r * c < 256 * 256:
        return ref.masked_dequant(codes, jnp.broadcast_to(scale, codes.shape), lo, hi, out_dtype)
    cp = _pad_to(codes, (block_r, block_c))
    if scale.ndim != 2:
        scale = scale.reshape((1, -1)) if scale.size == c else scale.reshape((-1, 1))
    sp = scale
    if scale.shape == (1, c):
        sp = _pad_to(scale, (1, block_c))
    elif scale.shape == (r, 1):
        sp = _pad_to(scale, (block_r, 1))
    out = _mask.masked_dequant(
        cp, sp, lo, hi, block_r=block_r, block_c=block_c,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:r, :c]


def delta_apply(
    buf: jnp.ndarray,
    indices: jnp.ndarray,
    values: jnp.ndarray,
    *,
    block: int = 4096,
    interpret: Optional[bool] = None,
    donate: bool = False,
) -> jnp.ndarray:
    """buf.at[indices].set(values) via the Pallas scatter kernel.

    ``donate=True`` hands ``buf`` to the kernel for in-place update
    (``delta_apply_inplace``): the caller's array is consumed, and the
    scatter writes O(delta) bytes instead of cloning the buffer — the
    contract staged weight sync relies on when applying many bounded
    parts against one staging copy."""
    interpret = _on_cpu() if interpret is None else interpret
    (n,) = buf.shape
    if n < block or indices.shape[0] == 0:
        return ref.delta_apply(buf, indices, values)
    # interpret mode executes the kernel body in Python per grid cell —
    # O(tiles × n_delta) work is fine compiled on TPU but pathological
    # interpreted; large updates take the (identical-semantics) ref path.
    # (The old 1<<22 threshold let a full-layer update burn ~7s *per
    # layer* interpreted — a whole-model pull through apply_packet spent
    # minutes here on CPU.)
    if interpret and (n // block) * indices.shape[0] > 1 << 18:
        return ref.delta_apply(buf, indices, values)
    pad = (-n) % block
    bufp = jnp.pad(buf, (0, pad)) if pad else buf
    # the padded copy is fresh, so aliasing it is always safe; unpadded,
    # in-place needs the caller's explicit donation
    kernel = (_delta.delta_apply_inplace if (donate or pad)
              else _delta.delta_apply)
    out = kernel(
        bufp, indices.astype(jnp.int32), values.astype(buf.dtype),
        block=block, interpret=interpret,
    )
    return out[:n]
