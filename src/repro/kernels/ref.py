"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernel tests
``assert_allclose`` against (interpret=True on CPU, compiled on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quant_matmul(x: jnp.ndarray, codes: jnp.ndarray, scale: jnp.ndarray,
                 out_dtype=jnp.float32) -> jnp.ndarray:
    """x (M,K) @ dequant(codes (K,N), scale (N,)) -> (M,N).

    Per-output-channel symmetric int8 dequant: W = codes * scale[None, :].
    Accumulation in f32 regardless of input dtype (MXU semantics).
    """
    w = codes.astype(jnp.float32) * scale.astype(jnp.float32)[None, :]
    acc = jnp.dot(x.astype(jnp.float32), w, precision="highest")
    return acc.astype(out_dtype)


def masked_dequant(codes: jnp.ndarray, scale: jnp.ndarray, lo: jnp.ndarray,
                   hi: jnp.ndarray, out_dtype=jnp.float32) -> jnp.ndarray:
    """Fused dequant + license-interval mask (paper §3.5).

    w = codes * scale (per-channel, axis -1); w is zeroed where
    lo[i] <= |w| < hi[i] for any interval i.  Intervals with lo == hi are
    inert padding.
    """
    w = codes.astype(jnp.float32) * scale.astype(jnp.float32)
    mag = jnp.abs(w)
    dead = jnp.zeros(w.shape, dtype=bool)
    for i in range(lo.shape[0]):
        dead = dead | ((mag >= lo[i]) & (mag < hi[i]))
    return jnp.where(dead, jnp.zeros_like(w), w).astype(out_dtype)


def delta_apply(buf: jnp.ndarray, indices: jnp.ndarray, values: jnp.ndarray) -> jnp.ndarray:
    """Sparse scatter-set of ``values`` at flat ``indices`` (unique) into buf.

    Out-of-range indices (used as padding, index == buf.size) are dropped.
    """
    valid = indices < buf.shape[0]
    safe = jnp.where(valid, indices, 0)
    vals = jnp.where(valid, values, buf[safe])
    return buf.at[safe].set(vals.astype(buf.dtype))


def paged_attention(q: jnp.ndarray, k_blocks: jnp.ndarray,
                    v_blocks: jnp.ndarray, block_tables: jnp.ndarray,
                    context_lens: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the paged decode kernel: gather-then-softmax attention.

    q (B,H,hd); k/v blocks (P,bs,KH,hd); block_tables (B,T) concatenated
    in logical order; context_lens (B,) masks positions >= len (including
    everything read through pad table entries).
    """
    import numpy as _np

    b, h, hd = q.shape
    _, bs, kh, _ = k_blocks.shape
    t = block_tables.shape[1]
    groups = h // kh
    k = jnp.repeat(k_blocks[block_tables].reshape(b, t * bs, kh, hd),
                   groups, axis=2)
    v = jnp.repeat(v_blocks[block_tables].reshape(b, t * bs, kh, hd),
                   groups, axis=2)
    s = jnp.einsum("bhd,bshd->bhs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / _np.sqrt(hd)
    mask = jnp.arange(t * bs)[None, :] < context_lens[:, None]
    s = jnp.where(mask[:, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, None, :].any(-1, keepdims=True), p, 0.0)
    return jnp.einsum("bhs,bshd->bhd", p, v.astype(jnp.float32))


def paged_decode_write(k_blocks: jnp.ndarray, v_blocks: jnp.ndarray,
                       new_k: jnp.ndarray, new_v: jnp.ndarray,
                       block_ids: jnp.ndarray, offsets: jnp.ndarray):
    """Oracle for the block-indexed decode write: one K/V token per lane
    lands at ``(block_ids[b], offsets[b])``.

    k/v blocks (P,bs,KH,hd); new_k/new_v (B,KH,hd).  Lanes never share a
    write target except the null block (pad lanes), where any of the
    duplicate writes may win — its content is garbage by contract.
    """
    kb = k_blocks.at[block_ids, offsets].set(new_k.astype(k_blocks.dtype))
    vb = v_blocks.at[block_ids, offsets].set(new_v.astype(v_blocks.dtype))
    return kb, vb


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, q_offset: int = 0,
                    groups: int = 1) -> jnp.ndarray:
    """Oracle for the flash kernel: materialized-softmax attention.

    q (BH,Sq,hd); k/v (BKH,Sk,hd) with BH == BKH*groups (GQA).
    """
    import numpy as _np

    bh, sq, hd = q.shape
    kr = jnp.repeat(k, groups, axis=0)
    vr = jnp.repeat(v, groups, axis=0)
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / _np.sqrt(hd)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(kr.shape[1])[None, :]
    mask = jnp.ones((sq, kr.shape[1]), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.any(mask[None], -1, keepdims=True), p, 0.0)
    return jnp.einsum("bqk,bkh->bqh", p, vr.astype(jnp.float32))
