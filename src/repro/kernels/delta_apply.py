"""Pallas TPU kernel: sparse weight-delta scatter (low-latency update, §4.3).

GPU scatter uses atomics; the TPU has no scatter unit, so we ADAPT
(DESIGN.md §2): scatter-as-compare.  The flat parameter buffer is tiled
over the grid; each tile loads the (replicated) index/value arrays, builds
`hit = indices - tile_start ∈ [0, tile)` and reduces a one-hot selection
over the delta axis on the VPU.  Indices are unique (the WeightStore
guarantees one row per flat index per version), so the sum over the delta
axis touches each position at most once.

Cost: O(tiles × n_delta) compares — bandwidth-optimal in HBM terms (buffer
read once, written once; delta read per-tile from VMEM) and far cheaper
than a full-buffer download, which is the paper's point.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(buf_ref, idx_ref, val_ref, out_ref, *, block: int):
    tile = pl.program_id(0)
    start = tile * block
    buf = buf_ref[...]                                # (1, block)
    idx = idx_ref[...].astype(jnp.int32)              # (1, n_delta)
    val = val_ref[...].astype(jnp.float32)            # (1, n_delta)

    pos = idx - start                                  # (1, n_delta)
    in_tile = (pos >= 0) & (pos < block)
    lanes = jax.lax.broadcasted_iota(jnp.int32, (block, idx.shape[1]), 0)
    onehot = (lanes == pos) & in_tile                  # (block, n_delta)
    update = jnp.sum(jnp.where(onehot, val, 0.0), axis=1)          # (block,)
    touched = jnp.any(onehot, axis=1)                  # (block,)
    out_ref[...] = jnp.where(
        touched[None, :], update[None, :].astype(buf.dtype), buf
    )


def _call(buf, indices, values, *, block, interpret, alias):
    (n,) = buf.shape
    assert n % block == 0, (n, block)
    n_delta = indices.shape[0]
    grid = (n // block,)
    return pl.pallas_call(
        functools.partial(_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block), lambda i: (0, i)),
            pl.BlockSpec((1, n_delta), lambda i: (0, 0)),
            pl.BlockSpec((1, n_delta), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), buf.dtype),
        input_output_aliases={0: 0} if alias else {},
        interpret=interpret,
    )(buf.reshape(1, n), indices.reshape(1, -1), values.reshape(1, -1)).reshape(n)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def delta_apply(
    buf: jnp.ndarray,
    indices: jnp.ndarray,
    values: jnp.ndarray,
    *,
    block: int = 4096,
    interpret: bool = False,
) -> jnp.ndarray:
    """Set buf[indices] = values (indices unique; padding idx >= buf.size).

    buf is flat (N,) with N % block == 0 (``ops.delta_apply`` pads); indices
    int32/int64 (n,), values (n,) castable to buf.dtype.
    """
    return _call(buf, indices, values, block=block, interpret=interpret,
                 alias=False)


@functools.partial(jax.jit, static_argnames=("block", "interpret"),
                   donate_argnums=(0,))
def delta_apply_inplace(
    buf: jnp.ndarray,
    indices: jnp.ndarray,
    values: jnp.ndarray,
    *,
    block: int = 4096,
    interpret: bool = False,
) -> jnp.ndarray:
    """:func:`delta_apply` that consumes ``buf``: the parameter buffer is
    donated and the scatter lands in place (``input_output_aliases``), so
    a staged weight update writes O(delta) bytes instead of cloning the
    whole layer per applied part.  The caller's ``buf`` array is invalid
    afterwards; backends without donation fall back to a copy."""
    return _call(buf, indices, values, block=block, interpret=interpret,
                 alias=True)
