"""Pallas TPU kernel: flash attention (online-softmax, O(1) HBM scores).

The roofline table (EXPERIMENTS.md §Roofline) shows every dense train/
prefill row is memory-dominated by the pure-JAX attention's (chunk × S)
score materialization.  This kernel keeps the running (m, l, acc) state in
VMEM across the innermost KV-block grid axis, so scores never touch HBM:
per-layer attention traffic drops from O(S·S) to O(S·d).

Layout: q (BH, Sq, hd), k/v (BKH, Sk, hd); GQA is handled in the index
map (kv block index = bh // group) — kv heads are never replicated in HBM.
Running stats live in the m/l output refs (f32), which persist across the
sequential innermost kk axis; the final kk step normalizes in-register.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, q_offset: int,
            block_q: int, block_k: int, n_k: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32) * scale                  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                          # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bq, bk)

    qi = pl.program_id(1)
    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = kk * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]                                         # (bq,)
    l_prev = l_ref[0]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # rows with no valid key yet keep m == NEG_INF; guard the exps
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
    alpha = jnp.where(m_prev == NEG_INF, 0.0, alpha)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    acc = o_ref[0].astype(jnp.float32) * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    m_ref[0] = m_new
    l_ref[0] = l_new
    o_ref[0] = acc.astype(o_ref.dtype)

    @pl.when(kk == n_k - 1)
    def _normalize():
        denom = jnp.maximum(l_ref[0], 1e-20)
        o_ref[0] = (o_ref[0].astype(jnp.float32) / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k",
                     "groups", "interpret"),
)
def flash_attention(
    q: jnp.ndarray,    # (BH, Sq, hd)
    k: jnp.ndarray,    # (BKH, Sk, hd)
    v: jnp.ndarray,    # (BKH, Sk, hd)
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 256,
    block_k: int = 256,
    groups: int = 1,    # q heads per kv head (BH == BKH * groups)
    interpret: bool = False,
) -> jnp.ndarray:
    bh, sq, hd = q.shape
    bkh, sk, _ = k.shape
    assert bh == bkh * groups, (bh, bkh, groups)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    scale = 1.0 / np.sqrt(hd)
    grid = (bh, sq // block_q, sk // block_k)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, q_offset=q_offset,
        block_q=block_q, block_k=block_k, n_k=grid[2],
    )
    out, _, _ = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, kk: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, qi, kk, g=groups: (b // g, kk, 0)),
            pl.BlockSpec((1, block_k, hd),
                         lambda b, qi, kk, g=groups: (b // g, kk, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, kk: (b, qi, 0)),
            pl.BlockSpec((1, block_q), lambda b, qi, kk: (b, qi)),
            pl.BlockSpec((1, block_q), lambda b, qi, kk: (b, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, hd), jnp.float32),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
            jax.ShapeDtypeStruct((bh, sq), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out
