"""Synthetic data: the paper MLP's classification task + LM token streams."""
from repro.data.pipeline import LMDataConfig, classification_data, lm_batches

__all__ = ["LMDataConfig", "classification_data", "lm_batches"]
