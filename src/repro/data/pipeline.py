"""Synthetic data pipelines (offline container — no external corpora).

``lm_batches`` generates structured pseudo-language streams: a Zipfian
unigram mixture with Markov bigram structure, so models actually *learn*
(loss decreases) rather than memorizing noise — required for the
fine-tune stage of the paper's compression pipeline and the licensing
accuracy ladders.

``classification_data`` builds the Gaussian-cluster task used for the
paper-scale MLP experiments (98%-accuracy freemium example, §3.5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclass
class LMDataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    zipf_a: float = 1.2
    markov_order: int = 1


def _zipf_probs(v: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, v + 1) ** a
    return p / p.sum()


def lm_batches(cfg: LMDataConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Infinite stream of {tokens, labels} with next-token labels."""
    rng = np.random.default_rng(cfg.seed)
    v = cfg.vocab_size
    base = _zipf_probs(min(v, 4096), cfg.zipf_a)
    support = min(v, 4096)
    # sparse bigram transition: each token prefers a few successors
    n_next = 8
    nxt = rng.integers(0, support, size=(support, n_next))
    while True:
        toks = np.empty((cfg.batch_size, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.choice(support, size=cfg.batch_size, p=base)
        for t in range(cfg.seq_len):
            prev = toks[:, t]
            use_markov = rng.random(cfg.batch_size) < 0.7
            succ = nxt[prev, rng.integers(0, n_next, cfg.batch_size)]
            rand = rng.choice(support, size=cfg.batch_size, p=base)
            toks[:, t + 1] = np.where(use_markov, succ, rand)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:].astype(np.int32)}


def classification_data(
    n: int, in_dim: int, num_classes: int, *, seed: int = 0,
    spread: Optional[float] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Gaussian clusters (one per class) — separable to ~98% like the
    paper's 3-layer-MLP example.  Default spread is dimension-normalized
    so the ~98% regime holds for any in_dim."""
    if spread is None:
        spread = 7.5 / np.sqrt(in_dim)
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((num_classes, in_dim)) * spread
    y = rng.integers(0, num_classes, size=n)
    x = centers[y] + rng.standard_normal((n, in_dim))
    return x.astype(np.float32), y.astype(np.int32)
