"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against the production mesh with ShapeDtypeStruct inputs —
no allocation, real SPMD partitioning.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k
  python -m repro.launch.dryrun --all
  python -m repro.launch.dryrun --all --mesh multi

Outputs one JSON per combo under benchmarks/dryrun_results/.
"""
import os
os.environ["XLA_FLAGS"] = (  # noqa: E402 — MUST precede any jax import
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.distribution import sharding as shd
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, adapt_config, input_specs
from repro.models import model as model_lib
from repro.serving.engine import prefill_step, serve_step
from repro.training import OptimizerConfig, make_train_step
from repro.training import optimizer as opt_lib

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "dryrun_results"


def _abstract_params(cfg):
    return jax.eval_shape(lambda: model_lib.init_params(jax.random.PRNGKey(0), cfg))


def lower_combo(arch: str, shape_name: str, mesh, mesh_name: str,
                cfg_override=None, note_suffix: str = "", quantized: bool = False):
    """Lower + compile one combination; returns (report_dict)."""
    base_cfg = get_config(arch)
    shape = SHAPES[shape_name]
    cfg, note = adapt_config(base_cfg, shape)
    if cfg_override:
        cfg = cfg.replace(**cfg_override)
        note = (note + "; " if note else "") + note_suffix
    kind, spec = input_specs(cfg, shape)

    params_shapes = _abstract_params(cfg)
    p_sh = shd.params_shardings(params_shapes, mesh, cfg)

    t0 = time.time()
    with mesh:
        if kind == "train":
            opt_shapes = jax.eval_shape(opt_lib.init_state, params_shapes)
            o_sh = shd.opt_state_shardings(opt_shapes, params_shapes, mesh)
            d_sh = shd.data_shardings(spec["batch"], mesh)
            step = make_train_step(cfg, OptimizerConfig(grad_accum=4))
            lowered = jax.jit(
                step, in_shardings=(p_sh, o_sh, d_sh)
            ).lower(params_shapes, opt_shapes, spec["batch"])
        elif kind == "prefill":
            c_sh = shd.cache_shardings(spec["cache"], mesh, shape.batch)
            d_sh = shd.data_shardings(
                {k: v for k, v in spec.items() if k != "cache"}, mesh
            )
            if "patch_embeds" in spec:
                fn = lambda p, t, c, pe: prefill_step(p, cfg, t, c, patch_embeds=pe)
                lowered = jax.jit(
                    fn, in_shardings=(p_sh, d_sh["tokens"], c_sh, d_sh["patch_embeds"]),
                ).lower(params_shapes, spec["tokens"], spec["cache"], spec["patch_embeds"])
            else:
                fn = lambda p, t, c: prefill_step(p, cfg, t, c)
                lowered = jax.jit(
                    fn, in_shardings=(p_sh, d_sh["tokens"], c_sh),
                ).lower(params_shapes, spec["tokens"], spec["cache"])
        else:  # decode
            c_sh = shd.cache_shardings(spec["cache"], mesh, shape.batch)
            d_sh = shd.data_shardings({"tokens": spec["tokens"]}, mesh)
            if quantized:
                from repro.serving.quantized import quantize_serving_params

                params_shapes = jax.eval_shape(quantize_serving_params, params_shapes)
                p_sh = shd.params_shardings(params_shapes, mesh, cfg)
                lo = jax.ShapeDtypeStruct((8,), jnp.float32)
                fn = lambda p, t, c, pos, lo_, hi_: serve_step(
                    p, cfg, t, c, pos, license_intervals=(lo_, hi_))
                lowered = jax.jit(
                    fn, in_shardings=(p_sh, d_sh["tokens"], c_sh,
                                      shd.replicated(mesh), shd.replicated(mesh),
                                      shd.replicated(mesh)),
                ).lower(params_shapes, spec["tokens"], spec["cache"], spec["pos"],
                        lo, lo)
            else:
                fn = lambda p, t, c, pos: serve_step(p, cfg, t, c, pos)
                lowered = jax.jit(
                    fn, in_shardings=(p_sh, d_sh["tokens"], c_sh, shd.replicated(mesh)),
                ).lower(params_shapes, spec["tokens"], spec["cache"], spec["pos"])

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = compiled.cost_analysis() or {}
    try:
        mem = compiled.memory_analysis()
        mem_stats = {
            "bytes_per_device": getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "generated_code_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        }
    except Exception:  # pragma: no cover - backend-dependent
        mem_stats = {}

    hlo = compiled.as_text()
    chips = mesh.devices.size
    report = rl.build_report(
        arch=arch, shape=shape_name, mesh_name=mesh_name, chips=chips,
        cost=cost, hlo_text=hlo,
        model_flops=rl.model_step_flops(cfg, shape),
        memory_stats=mem_stats, note=note,
    )
    out = report.as_dict()
    out.update(mem_stats)
    out["lower_s"] = round(t_lower, 2)
    out["compile_s"] = round(t_compile, 2)
    out["step_kind"] = kind
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable); tags the "
                         "result file with __opt")
    ap.add_argument("--tag", default="opt")
    ap.add_argument("--quantized", action="store_true",
                    help="decode shapes: int8 fused masked-dequant serving")
    args = ap.parse_args(argv)

    override = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        override[k] = {"true": True, "false": False}.get(
            v.lower(), int(v) if v.isdigit() else v)

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    mesh_name = "2x16x16" if args.mesh == "multi" else "16x16"

    combos = []
    if args.all:
        for arch in ASSIGNED_ARCHS:
            for shape in SHAPES:
                combos.append((arch, shape))
    else:
        combos.append((args.arch, args.shape))

    failures = 0
    for arch, shape in combos:
        tag = f"{arch}__{shape}__{mesh_name}"
        if override or args.quantized:
            tag += f"__{args.tag}"
        try:
            rep = lower_combo(arch, shape, mesh, mesh_name,
                              cfg_override=override or None,
                              note_suffix=args.tag + ": "
                              + ",".join(args.set), quantized=args.quantized)
            (outdir / f"{tag}.json").write_text(json.dumps(rep, indent=1))
            print(f"OK   {tag}: dominant={rep['dominant']} "
                  f"compute={rep['compute_s']:.4f}s memory={rep['memory_s']:.4f}s "
                  f"collective={rep['collective_s']:.4f}s "
                  f"bytes/dev={rep.get('bytes_per_device', 0)/2**30:.2f}GiB "
                  f"compile={rep['compile_s']}s", flush=True)
        except Exception as e:  # noqa: BLE001 - report and continue the sweep
            failures += 1
            print(f"FAIL {tag}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
