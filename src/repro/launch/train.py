"""Training launcher.

CPU smoke scale (default): trains a reduced variant of --arch on synthetic
LM data for --steps steps, committing versioned checkpoints to the
WeightStore (the paper's storage plane is the checkpoint substrate).

Production scale: pass --production to pjit the full config against the
16×16 (or 2×16×16) mesh — on real hardware this trains; in this container
it requires the dry-run path instead (lower+compile only), which
``repro.launch.dryrun`` provides.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-moe-16b \
      --steps 30 --store /tmp/weights.db --checkpoint-every 10
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, list_configs, smoke_variant
from repro.core.weightstore import WeightStore
from repro.data import LMDataConfig, lm_batches
from repro.training import OptimizerConfig, train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(list_configs()))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--store", default=None, help="WeightStore path for checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (not smoke) config — needs real HW")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = smoke_variant(cfg)
    print(f"training {cfg.name}: {cfg.num_layers}L d{cfg.d_model} "
          f"vocab {cfg.vocab_size} on {jax.default_backend()}")

    data = lm_batches(LMDataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch,
        seed=args.seed,
    ))
    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                           total_steps=args.steps)
    store = WeightStore(args.store) if args.store else None
    params, history = train_loop(
        cfg, ocfg, data, args.steps, seed=args.seed,
        store=store, store_model=cfg.name,
        checkpoint_every=args.checkpoint_every,
    )
    first, last = history["loss"][0], history["loss"][-1]
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    if store is not None:
        print("checkpoints:", [h["id"] for h in store.history(cfg.name)])
        store.close()


if __name__ == "__main__":
    main()
