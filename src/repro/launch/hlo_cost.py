"""Loop-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any
scanned-layers model under-reports FLOPs/bytes/collectives by the trip
count (layers × q-chunks × ssd-chunks...).  This parser walks the
post-SPMD-partitioning HLO text, builds a per-computation symbol table,
and resolves costs through the call graph with ``known_trip_count``
multipliers on while bodies.

Costs per computation:
  flops            2 · prod(dot output dims) · contraction size
  traffic bytes    Σ instruction output bytes + operand-read bytes
                   (post-fusion ⇒ each instruction output ≈ one HBM
                   round-trip; elementwise ops inside fusions are free)
  collective bytes Σ collective output bytes, by kind

Validated against unrolled-vs-scanned equivalence in tests.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
    "token": 0, "s2": 1, "u2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"^([a-z][a-z0-9]*)\[([\d,]*)\]")
_TUPLE_SHAPE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")
_OPNAME = re.compile(r"^(?:\([^)]*\)|[a-z][a-z0-9]*\[[\d,]*\][^\s]*)\s+([\w\-]+)")
_OPERANDS = re.compile(r"%([\w\.\-]+)")
_CALLEE = re.compile(r"(?:body|to_apply|called_computations?|branch_computations)=\{?%?([\w\.\-]+)")
_BODY = re.compile(r"body=%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_FUSION_CALLS = re.compile(r"(?:calls|fusion)=%?([\w\.\-]+)")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _shape_info(text: str) -> Tuple[int, int]:
    """(elements, bytes) for a possibly-tuple shape string."""
    total_e = total_b = 0
    for dt, dims in _TUPLE_SHAPE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES.get(dt, 4)
    return total_e, total_b


@dataclass
class _Instr:
    name: str
    op: str
    out_bytes: int
    out_dims: List[int]
    out_dtype: str
    operands: List[str]
    rhs: str


@dataclass
class _Computation:
    name: str
    instrs: List[_Instr] = field(default_factory=list)
    shapes: Dict[str, Tuple[str, List[int]]] = field(default_factory=dict)


@dataclass
class HloCost:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)

    def __add__(self, other: "HloCost") -> "HloCost":
        kinds = {**self.collective_by_kind}
        for k, v in other.collective_by_kind.items():
            kinds[k] = kinds.get(k, 0) + v
        counts = {**self.collective_counts}
        for k, v in other.collective_counts.items():
            counts[k] = counts.get(k, 0) + v
        return HloCost(self.flops + other.flops,
                       self.traffic_bytes + other.traffic_bytes,
                       self.collective_bytes + other.collective_bytes,
                       kinds, counts)

    def scaled(self, m: float) -> "HloCost":
        return HloCost(self.flops * m, self.traffic_bytes * m,
                       self.collective_bytes * m,
                       {k: v * m for k, v in self.collective_by_kind.items()},
                       {k: v * m for k, v in self.collective_counts.items()})


# ops whose output we do NOT count as HBM traffic (no materialization or
# bookkeeping only)
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "token", "partition-id", "replica-id", "iota",
             "bitcast-convert"}

# elementwise / layout ops the TPU compiler fuses into neighbours; the CPU
# backend leaves many unfused, which would wildly overstate TPU HBM traffic.
# Their outputs/operands are not charged (the consumer's operand read pays).
_FUSABLE_OPS = {
    "add", "subtract", "multiply", "divide", "power", "negate", "abs",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "sine", "cosine", "sqrt", "rsqrt", "cbrt", "sign", "floor",
    "ceil", "round-nearest-even", "round-nearest-afz", "maximum", "minimum",
    "compare", "select", "convert", "and", "or", "not", "xor", "clamp",
    "broadcast", "reshape", "is-finite", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "remainder", "atan2", "erf", "expm1", "log1p",
    "copy-done", "all-reduce-done", "all-gather-done", "collective-permute-done",
    "slice", "real", "imag", "reduce-precision", "stochastic-convert",
    "rng-bit-generator", "rng",
}


def parse_computations(hlo: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    current: Optional[_Computation] = None
    entry: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR.match(line.strip())
        if hdr and line.rstrip().endswith("{"):
            current = _Computation(name=hdr.group(1))
            comps[current.name] = current
            if line.strip().startswith("ENTRY"):
                comps["__entry__"] = current
            continue
        if current is None:
            continue
        if line.strip() == "}":
            current = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        opm = _OPNAME.match(rhs)
        op = opm.group(1) if opm else ""
        # output shape: leading shape or tuple
        sm = _SHAPE.match(rhs)
        if sm:
            dt, dims = sm.groups()
            out_dims = [int(d) for d in dims.split(",") if d]
            _, out_bytes = _shape_info(rhs[: rhs.index("]") + 1])
        else:
            # tuple result: take everything up to the op name
            close = rhs.find(") ")
            head = rhs[: close + 1] if close > 0 else rhs
            _, out_bytes = _shape_info(head)
            dt, out_dims = "tuple", []
        # operand names: appear after the first '(' of the op call
        call_idx = rhs.find("(")
        operand_str = rhs[call_idx:] if call_idx >= 0 else ""
        # strip metadata/backend_config to avoid matching their contents
        for cut in (", metadata=", ", backend_config=", ", sharding="):
            j = operand_str.find(cut)
            if j >= 0:
                operand_str = operand_str[:j]
        operands = _OPERANDS.findall(operand_str)
        current.shapes[name] = (dt, out_dims)
        current.instrs.append(_Instr(name=name, op=op, out_bytes=out_bytes,
                                     out_dims=out_dims, out_dtype=dt,
                                     operands=operands, rhs=rhs))
    return comps


def _local_cost(comp: _Computation, comps: Dict[str, _Computation]) -> Tuple[HloCost, List[Tuple[str, float]]]:
    """(local cost, [(callee, multiplier), ...])"""
    cost = HloCost()
    calls: List[Tuple[str, float]] = []
    for ins in comp.instrs:
        op = ins.op
        if op in ("dot", "dot-general") or op.startswith("dot"):
            csize = 1
            cm = _CONTRACT.search(ins.rhs)
            lhs = ins.operands[0] if ins.operands else None
            if cm and lhs and lhs in comp.shapes:
                ldims = comp.shapes[lhs][1]
                for ci in cm.group(1).split(","):
                    if ci and int(ci) < len(ldims):
                        csize *= ldims[int(ci)]
            out_elems = 1
            for d in ins.out_dims:
                out_elems *= d
            cost.flops += 2.0 * out_elems * csize
        elif op == "convolution":
            out_elems = 1
            for d in ins.out_dims:
                out_elems *= d
            cost.flops += 2.0 * out_elems  # lower bound; convs are stubs here

        if any(op.startswith(c) for c in _COLLECTIVES):
            if op.endswith("-done"):
                continue
            kind = op.replace("-start", "")
            cost.collective_bytes += ins.out_bytes
            cost.collective_by_kind[kind] = cost.collective_by_kind.get(kind, 0) + ins.out_bytes
            cost.collective_counts[kind] = cost.collective_counts.get(kind, 0) + 1

        # -------- HBM traffic (producer-side model) ------------------------
        # Each heavy op's output is written once and read ~once downstream
        # (out × 2); dot/conv additionally charge their operand reads (weight
        # streams dominate matmul traffic and operands are often parameters,
        # which no producer accounts for).  Loop/tuple plumbing and in-place
        # dynamic-update-slice charge only the moved slice, mirroring TPU
        # in-place semantics.
        if op in ("while", "conditional", "optimization-barrier", "copy-start",
                  "domain", "call"):
            pass
        elif op == "dynamic-update-slice":
            upd = ins.operands[1] if len(ins.operands) > 1 else None
            if upd and upd in comp.shapes:
                dt, dims = comp.shapes[upd]
                n = 1
                for d in dims:
                    n *= d
                cost.traffic_bytes += 2 * n * _DTYPE_BYTES.get(dt, 4)
        elif op in ("dot", "convolution") or op.startswith("dot"):
            cost.traffic_bytes += 2 * ins.out_bytes
            for o in ins.operands:
                if o in comp.shapes:
                    dt, dims = comp.shapes[o]
                    n = 1
                    for d in dims:
                        n *= d
                    cost.traffic_bytes += n * _DTYPE_BYTES.get(dt, 4)
        elif op not in _FREE_OPS and op not in _FUSABLE_OPS:
            cost.traffic_bytes += 2 * ins.out_bytes

        if op == "while":
            bm = _BODY.search(ins.rhs)
            tm = _TRIP.search(ins.rhs)
            trip = float(tm.group(1)) if tm else 1.0
            if bm:
                calls.append((bm.group(1), trip))
            cm2 = _COND.search(ins.rhs)
            if cm2:
                calls.append((cm2.group(1), trip))
        elif op == "fusion":
            fm = re.search(r"calls=%?([\w\.\-]+)", ins.rhs)
            if fm:
                calls.append((fm.group(1), 0.0))  # fusion interior is free
        elif op in ("call", "custom-call", "conditional", "map", "reduce",
                    "reduce-window", "scatter", "sort", "select-and-scatter",
                    "all-reduce", "reduce-scatter"):
            for cal in re.findall(r"(?:to_apply|called_computations=\{|branch_computations=\{)%?([\w\.\-]+)", ins.rhs):
                calls.append((cal, 1.0))
    return cost, calls


def analyze(hlo: str) -> HloCost:
    comps = parse_computations(hlo)
    if "__entry__" not in comps:
        return HloCost()
    memo: Dict[str, HloCost] = {}

    def total(name: str, depth=0) -> HloCost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        if comp is None or depth > 64:
            return HloCost()
        memo[name] = HloCost()  # cycle guard
        local, calls = _local_cost(comp, comps)
        agg = local
        for callee, mult in calls:
            if mult == 0.0:
                continue
            agg = agg + total(callee, depth + 1).scaled(mult)
        memo[name] = agg
        return agg

    return total(comps["__entry__"].name)
