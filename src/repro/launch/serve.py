"""Serving launcher: licensed batched generation (Fig. 2's edge role).

Loads the production version from a WeightStore (or random-inits), builds
the tier ladder, and serves a batch of requests per tier — demonstrating
one stored weight set serving multiple accuracy tiers (§3.5).

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      --tiers full,free --prompt-len 32 --new-tokens 8
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, list_configs, smoke_variant
from repro.core.licensing import FULL_TIER, LicenseTier
from repro.core.weightstore import WeightStore
from repro.models import init_params
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(list_configs()))
    ap.add_argument("--store", default=None)
    ap.add_argument("--tiers", default="full,free")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = smoke_variant(cfg)

    key = jax.random.PRNGKey(args.seed)
    if args.store:
        store = WeightStore(args.store)
        template = init_params(key, cfg)
        params = store.checkout(cfg.name, template=template)
        print(f"loaded production version {store.production_version(cfg.name)}")
    else:
        params = init_params(key, cfg)

    tiers = {"full": FULL_TIER,
             "free": LicenseTier(name="free", masks={"*": ((0.0, 0.01),)})}
    engine = ServingEngine(cfg, params, tiers=tiers)

    rng = np.random.default_rng(args.seed)
    for tier in args.tiers.split(","):
        reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                            dtype=np.int32),
                        max_new_tokens=args.new_tokens, license=tier)
                for _ in range(args.batch)]
        engine.generate(reqs, seed=args.seed)
        print(f"tier={tier}: " + " | ".join(str(r.out_tokens) for r in reqs[:2]))


if __name__ == "__main__":
    main()
