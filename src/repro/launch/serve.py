"""Serving launcher: licensed batched generation (Fig. 2's edge role).

Loads the production version from a WeightStore (or random-inits),
builds the tier ladder, and drains a batch of requests per tier through
the continuous-batching ``LicensedGateway`` — demonstrating one stored
weight set serving multiple accuracy tiers (§3.5).

The observability layer rides along: ``--prometheus-out`` dumps the
Prometheus text exposition, ``--trace-out`` the whole-gateway Chrome
trace (load it in Perfetto / chrome://tracing), ``--audit-out`` the
licensing audit stream as JSONL.  Pass ``-`` to print to stdout.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
      --tiers full,free --prompt-len 32 --new-tokens 8 \
      --prometheus-out - --trace-out trace.json --audit-out audit.jsonl
"""
from __future__ import annotations

import argparse
import sys

import jax
import numpy as np

from repro.configs import get_config, list_configs, smoke_variant
from repro.core.licensing import FULL_TIER, LicenseTier
from repro.core.weightstore import WeightStore
from repro.models import init_params
from repro.serving import LicensedGateway


def _dump(dest: str, text: str, label: str) -> None:
    if dest == "-":
        sys.stdout.write(text if text.endswith("\n") else text + "\n")
    else:
        with open(dest, "w") as f:
            f.write(text)
        print(f"wrote {label} to {dest}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(list_configs()))
    ap.add_argument("--store", default=None)
    ap.add_argument("--tiers", default="full,free")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="disable tracing/metrics/audit recording")
    ap.add_argument("--prometheus-out", default=None, metavar="PATH",
                    help="dump Prometheus text exposition ('-' = stdout)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="dump Chrome trace_event JSON ('-' = stdout)")
    ap.add_argument("--audit-out", default=None, metavar="PATH",
                    help="dump licensing audit JSONL ('-' = stdout)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = smoke_variant(cfg)

    key = jax.random.PRNGKey(args.seed)
    if args.store:
        store = WeightStore(args.store)
        template = init_params(key, cfg)
        params = store.checkout(cfg.name, template=template)
        print(f"loaded production version {store.production_version(cfg.name)}")
    else:
        params = init_params(key, cfg)

    tiers = {"full": FULL_TIER,
             "free": LicenseTier(name="free", masks={"*": ((0.0, 0.01),)})}
    gw = LicensedGateway(cfg, params, tiers=tiers, max_batch=args.batch,
                         max_prompt=args.prompt_len,
                         max_new_cap=args.new_tokens,
                         telemetry=not args.no_telemetry)

    rng = np.random.default_rng(args.seed)
    for tier in args.tiers.split(","):
        reqs = [gw.submit(rng.integers(0, cfg.vocab_size, args.prompt_len,
                                       dtype=np.int32),
                          max_new_tokens=args.new_tokens, license=tier,
                          seed=args.seed)
                for _ in range(args.batch)]
        gw.run()
        print(f"tier={tier}: " + " | ".join(str(r.out_tokens) for r in reqs[:2]))

    m = gw.metrics()
    print(f"served {m['completed']} requests, "
          f"{m['tokens_generated']} tokens; "
          f"ttft p99 {m['latency']['ttft_s']['p99'] * 1e3:.1f}ms")
    if args.prometheus_out:
        _dump(args.prometheus_out, gw.render_prometheus(), "Prometheus text")
    if args.trace_out:
        _dump(args.trace_out, gw.chrome_trace(), "Chrome trace")
    if args.audit_out:
        _dump(args.audit_out, gw.audit.render_jsonl(), "audit JSONL")


if __name__ == "__main__":
    main()
