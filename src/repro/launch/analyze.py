"""Dry-run deep-dive: attribute loop-aware traffic/flops/collectives to
HLO op_name provenance for one (arch × shape × mesh) combo.

  PYTHONPATH=src python -m repro.launch.analyze --arch granite-34b \
      --shape train_4k [--mesh multi] [--top 15]
"""
import os
os.environ["XLA_FLAGS"] = (  # noqa: E402
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse
import re
from collections import Counter

import jax

from repro.configs import get_config
from repro.distribution import sharding as shd
from repro.launch import hlo_cost
from repro.launch.dryrun import _abstract_params
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, adapt_config, input_specs
from repro.serving.engine import prefill_step, serve_step
from repro.training import OptimizerConfig, make_train_step
from repro.training import optimizer as opt_lib


def compile_combo(arch: str, shape_name: str, multi: bool = False,
                  cfg_override=None, grad_accum: int = 4):
    mesh = make_production_mesh(multi_pod=multi)
    cfg, note = adapt_config(get_config(arch), SHAPES[shape_name])
    if cfg_override:
        cfg = cfg.replace(**cfg_override)
    kind, spec = input_specs(cfg, SHAPES[shape_name])
    params_shapes = _abstract_params(cfg)
    p_sh = shd.params_shardings(params_shapes, mesh, cfg)
    with mesh:
        if kind == "train":
            opt_shapes = jax.eval_shape(opt_lib.init_state, params_shapes)
            o_sh = shd.opt_state_shardings(opt_shapes, params_shapes, mesh)
            d_sh = shd.data_shardings(spec["batch"], mesh)
            step = make_train_step(cfg, OptimizerConfig(grad_accum=grad_accum))
            compiled = jax.jit(step, in_shardings=(p_sh, o_sh, d_sh)).lower(
                params_shapes, opt_shapes, spec["batch"]).compile()
        elif kind == "prefill":
            c_sh = shd.cache_shardings(spec["cache"], mesh, SHAPES[shape_name].batch)
            d_sh = shd.data_shardings(
                {k: v for k, v in spec.items() if k != "cache"}, mesh)
            fn = lambda p, t, c: prefill_step(p, cfg, t, c)
            compiled = jax.jit(fn, in_shardings=(p_sh, d_sh["tokens"], c_sh)).lower(
                params_shapes, spec["tokens"], spec["cache"]).compile()
        else:
            c_sh = shd.cache_shardings(spec["cache"], mesh, SHAPES[shape_name].batch)
            d_sh = shd.data_shardings({"tokens": spec["tokens"]}, mesh)
            fn = lambda p, t, c, pos: serve_step(p, cfg, t, c, pos)
            compiled = jax.jit(
                fn, in_shardings=(p_sh, d_sh["tokens"], c_sh, shd.replicated(mesh)),
            ).lower(params_shapes, spec["tokens"], spec["cache"], spec["pos"]).compile()
    return compiled, mesh, cfg


def attribute(hlo: str, top: int = 15):
    """Loop-aware per-op_name tallies of traffic/flops/collective bytes."""
    comps = hlo_cost.parse_computations(hlo)
    traffic = Counter()
    flops = Counter()
    coll = Counter()

    def opname(ins):
        m = re.search(r'op_name="([^"]*)"', ins.rhs)
        if not m:
            return f"<{ins.op}>"
        return re.sub(r"/\d+", "", m.group(1))[:100]

    def visit(name, mult, depth=0):
        comp = comps.get(name)
        if comp is None or depth > 64:
            return
        _, calls = hlo_cost._local_cost(comp, comps)
        for ins in comp.instrs:
            one = hlo_cost._Computation(name="x", instrs=[ins], shapes=comp.shapes)
            c, _ = hlo_cost._local_cost(one, comps)
            if c.traffic_bytes:
                traffic[opname(ins)] += c.traffic_bytes * mult
            if c.flops:
                flops[opname(ins)] += c.flops * mult
            if c.collective_bytes:
                coll[opname(ins)] += c.collective_bytes * mult
        for callee, m in calls:
            if m:
                visit(callee, mult * m, depth + 1)

    visit(comps["__entry__"].name, 1.0)
    return traffic, flops, coll


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args(argv)

    compiled, mesh, cfg = compile_combo(args.arch, args.shape,
                                        multi=args.mesh == "multi")
    hlo = compiled.as_text()
    traffic, flops, coll = attribute(hlo, args.top)
    total = hlo_cost.analyze(hlo)
    print(f"== totals: flops {total.flops:.3e}  traffic {total.traffic_bytes/2**40:.2f} TiB"
          f"  collective {total.collective_bytes/2**30:.2f} GiB")
    mem = compiled.memory_analysis()
    print(f"== memory: temp {mem.temp_size_in_bytes/2**30:.2f} GiB  "
          f"args {mem.argument_size_in_bytes/2**30:.2f} GiB")
    print(f"\n-- top traffic (TiB, loop-aware) --")
    for k, v in traffic.most_common(args.top):
        print(f"{v/2**40:8.3f}  {k}")
    print(f"\n-- top collectives (GiB) --")
    for k, v in coll.most_common(args.top):
        print(f"{v/2**30:8.2f}  {k}")


if __name__ == "__main__":
    main()
