"""Assigned input shapes + ``input_specs`` ShapeDtypeStruct builders.

``input_specs(cfg, shape)`` returns (step_kind, kwargs-tree of
ShapeDtypeStructs) — weak-type-correct, shardable, zero allocation.

``long_500k`` requires sub-quadratic attention: SSM/hybrid run natively;
full-attention archs run their sliding-window variant (window=4096), which
is a first-class config flag — the KV cache is window-sized.  The variant
used is recorded in the dry-run output.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_lib

SWA_WINDOW = 4096


@dataclass(frozen=True)
class InputShape:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4_096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32_768, 128),
    "long_500k": InputShape("long_500k", "decode", 524_288, 1),
}


def adapt_config(cfg: ModelConfig, shape: InputShape) -> Tuple[ModelConfig, str]:
    """Per-shape config adaptation (returns (cfg, note))."""
    note = ""
    if shape.name == "long_500k" and cfg.window == 0 and "attn" in cfg.layer_pattern:
        cfg = cfg.replace(window=SWA_WINDOW)
        note = f"sliding-window variant (window={SWA_WINDOW}) for 500k decode"
    if shape.kind == "train" and shape.seq_len >= 32_768:
        cfg = cfg.replace(q_chunk=512)
    return cfg, note


def token_struct(batch: int, seq: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, seq), jnp.int32)


def input_specs(cfg: ModelConfig, shape: InputShape) -> Tuple[str, Dict[str, Any]]:
    """(kind, kwargs) for the step function this shape lowers."""
    b, s = shape.batch, shape.seq_len
    vlm = cfg.frontend == "vision"
    p = cfg.num_patches if vlm else 0

    if shape.kind == "train":
        batch = {"tokens": token_struct(b, s - p), "labels": token_struct(b, s - p)}
        if vlm:
            batch["patch_embeds"] = jax.ShapeDtypeStruct((b, p, cfg.d_model), cfg.dtype)
        return "train", {"batch": batch}

    if shape.kind == "prefill":
        cache = jax.eval_shape(lambda: model_lib.init_cache(cfg, b, s))
        spec: Dict[str, Any] = {"tokens": token_struct(b, s - p), "cache": cache}
        if vlm:
            spec["patch_embeds"] = jax.ShapeDtypeStruct((b, p, cfg.d_model), cfg.dtype)
        return "prefill", spec

    # decode: ONE token against a seq_len cache
    cache = jax.eval_shape(lambda: model_lib.init_cache(cfg, b, s))
    return "decode", {
        "tokens": token_struct(b, 1),
        "cache": cache,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
