"""Roofline-term extraction from compiled dry-run artifacts.

compute   = HLO_FLOPs / (chips × peak)        [s]
memory    = HLO_bytes / (chips × HBM_bw)      [s]
collective= coll_bytes / (chips × link_bw)    [s]

``cost_analysis`` on the SPMD-partitioned executable reports the
PER-DEVICE module, so compute/memory terms divide by ONE chip's peak;
collective bytes are summed from the partitioned HLO's collective ops
(output-operand sizes) and likewise per-device.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12       # bf16 FLOP/s
HBM_BW = 819e9            # B/s
LINK_BW = 50e9            # B/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# one HLO instruction result, e.g.:  %x = f32[256,1024]{1,0} all-reduce(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([\d,]*)\][^ ]*\s+(" + "|".join(_COLLECTIVES) + r")\("
)
# tuple-result collectives:  = (f32[8,128], f32[8,128]) all-to-all(
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]+)\)\s+(" + "|".join(_COLLECTIVES) + r")\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        if not any(c in line for c in _COLLECTIVES):
            continue
        if "-start" in line and "-done" not in line:
            pass  # count the -start; the -done reuses the same buffer
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, kind = m.groups()
            b = _shape_bytes(dtype, dims)
        else:
            m2 = _TUPLE_RE.search(line)
            if not m2:
                continue
            shapes, kind = m2.groups()
            b = sum(_shape_bytes(dt, dd) for dt, dd in _SHAPE_RE.findall(shapes))
        kind = kind.replace("-start", "")
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + b
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float              # per device
    hlo_bytes: float              # per device
    collective_bytes: float       # per device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float            # 6·N(_active)·D, whole step, all chips
    useful_flops_ratio: float     # model_flops / (hlo_flops × chips)
    bytes_per_device: Optional[float] = None
    collectives: Dict[str, int] = field(default_factory=dict)
    note: str = ""

    def as_dict(self):
        return asdict(self)


def build_report(
    *, arch: str, shape: str, mesh_name: str, chips: int,
    cost: Dict[str, float], hlo_text: str, model_flops: float,
    memory_stats: Optional[Dict[str, float]] = None, note: str = "",
) -> RooflineReport:
    """Loop-aware terms from the partitioned HLO (``hlo_cost``); XLA's own
    cost_analysis (which counts while-bodies once) is kept as xla_raw_*."""
    from repro.launch import hlo_cost

    hc = hlo_cost.analyze(hlo_text)
    flops = hc.flops
    raw_bytes = hc.traffic_bytes
    coll_bytes = hc.collective_bytes

    compute_s = flops / PEAK_FLOPS
    memory_s = raw_bytes / HBM_BW
    collective_s = coll_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops / (flops * chips) if flops else 0.0
    collectives = (
        {f"{k}_bytes": v for k, v in hc.collective_by_kind.items()}
        | {f"{k}_count": v for k, v in hc.collective_counts.items()}
        | {"xla_raw_flops": float(cost.get("flops", 0.0)),
           "xla_raw_bytes": float(cost.get("bytes accessed", 0.0))}
    )
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=raw_bytes, collective_bytes=coll_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, useful_flops_ratio=useful,
        bytes_per_device=(memory_stats or {}).get("bytes_per_device"),
        collectives=collectives,
        note=note,
    )


def model_step_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D for train (fwd+bwd), 2·N·D per generated/scored
    token otherwise; N = active params."""
    counts = cfg.param_counts()
    n = counts["active"]
    if shape.kind == "train":
        tokens = shape.batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.batch  # decode: one token per sequence
