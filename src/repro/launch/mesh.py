"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run sets XLA_FLAGS for 512 host devices before any jax
import; real deployments get the same mesh over real chips.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke/bench runs (same axis names)."""
    return jax.make_mesh((1, 1), ("data", "model"))
