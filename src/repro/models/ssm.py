"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Training/prefill uses the chunked SSD form: quadratic attention-like
within-chunk term + a linear inter-chunk state recurrence (``lax.scan``
over chunks), so 500k-token contexts never build an S×S matrix and decode
state is O(1) in sequence length.  Decode is the single-step SSM
recurrence.  TPU adaptation: the within-chunk einsums are MXU matmuls over
(chunk × chunk) and (state × head_dim) tiles; chunk size (default 256) is
the VMEM/MXU tiling knob.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, rms_norm


def d_inner(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_heads(cfg) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def conv_dim(cfg) -> int:
    return d_inner(cfg) + 2 * cfg.ssm_state  # x plus B and C (single group)


def init_ssm(key, cfg, dtype) -> Dict[str, Any]:
    d = cfg.d_model
    di, h, n = d_inner(cfg), n_heads(cfg), cfg.ssm_state
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * n + h  # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], d, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim(cfg)), jnp.float32)
                   / np.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_dim(cfg),), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D_skip": jnp.ones((h,), jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[3], di, d, dtype),
    }


def _split_proj(cfg, proj):
    di, h, n = d_inner(cfg), n_heads(cfg), cfg.ssm_state
    z = proj[..., :di]
    xbc = proj[..., di : di + di + 2 * n]
    dt = proj[..., di + di + 2 * n :]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv1d.  xbc (B,L,C), w (K,C).  Returns (out, new_state)."""
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    padded = jnp.concatenate([state, xbc], axis=1)               # (B, L+K-1, C)
    out = sum(padded[:, i : i + xbc.shape[1]] * w[i][None, None] for i in range(k))
    new_state = padded[:, -(k - 1) :]
    return jax.nn.silu(out + b[None, None]), new_state


def ssd_chunked(
    x: jnp.ndarray,    # (B, L, H, P)  input (unscaled)
    dt: jnp.ndarray,   # (B, L, H)     softplus'd step
    A: jnp.ndarray,    # (H,)          negative
    Bm: jnp.ndarray,   # (B, L, N)
    Cm: jnp.ndarray,   # (B, L, N)
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,  # (B, H, N, P)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked SSD scan.  Returns (y (B,L,H,P), final_state (B,H,N,P))."""
    b, l, h, p = x.shape
    n = Bm.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk

    f32 = jnp.float32
    xc = x.reshape(b, nc, chunk, h, p).swapaxes(0, 1).astype(f32)   # (nc,B,Q,H,P)
    dtc = dt.reshape(b, nc, chunk, h).swapaxes(0, 1).astype(f32)
    Bc = Bm.reshape(b, nc, chunk, n).swapaxes(0, 1).astype(f32)
    Cc = Cm.reshape(b, nc, chunk, n).swapaxes(0, 1).astype(f32)

    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    s0 = (jnp.zeros((b, h, n, p), f32) if init_state is None
          else init_state.astype(jnp.float32))

    def chunk_step(s_prev, inp):
        """One chunk: within-chunk quadratic term + state read/update.

        Live memory per step is O(B·Q·Q·H) (one chunk's decay), not
        O(B·nc·Q·Q·H); the body is checkpointed so backward recomputes it
        instead of saving nc copies.
        """
        xci, dtci, Bci, Cci = inp                       # (B,Q,...)
        a = dtci * A[None, None, :]                     # (B,Q,H) log-decay <= 0
        cum_a = jnp.cumsum(a, axis=1)
        total_a = cum_a[:, -1, :]                       # (B,H)
        xdt = xci * dtci[..., None]

        # within-chunk: L[i,j] = exp(cum_a[i]-cum_a[j]) for i >= j.
        # Mask BEFORE exp: above the diagonal diff > 0 explodes, and
        # where(mask, inf, 0) back-propagates 0·inf = NaN.
        diff = cum_a[:, :, None, :] - cum_a[:, None, :, :]       # (B,Q,Q,H)
        decay = jnp.exp(jnp.where(causal[None, :, :, None], diff, -1e30))
        scores = jnp.einsum("bin,bjn->bij", Cci, Bci)            # (B,Q,Q)
        y = jnp.einsum("bij,bijh,bjhp->bihp", scores, decay, xdt)

        # off-diagonal: contribution of the entering state
        y += jnp.einsum("bin,bih,bhnp->bihp", Cci, jnp.exp(cum_a), s_prev)

        # state update: S' = exp(total_a)·S + Σ_j exp(total_a-cum_a[j]) B_j⊗xdt_j
        w_state = jnp.exp(total_a[:, None, :] - cum_a)           # (B,Q,H)
        S_c = jnp.einsum("bjn,bjh,bjhp->bhnp", Bci, w_state, xdt)
        s_new = s_prev * jnp.exp(total_a)[:, :, None, None] + S_c
        return s_new, y

    final, ys = jax.lax.scan(
        jax.checkpoint(chunk_step, prevent_cse=False), s0, (xc, dtc, Bc, Cc)
    )
    y = ys.swapaxes(0, 1).reshape(b, l, h, p)
    return y.astype(x.dtype), final


def ssm_block(
    p: Dict[str, Any], xin: jnp.ndarray, cfg, *,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Full Mamba-2 block: in_proj -> conv -> SSD -> gated norm -> out_proj."""
    b, l, _ = xin.shape
    di, h, n, pd = d_inner(cfg), n_heads(cfg), cfg.ssm_state, cfg.ssm_head_dim

    proj = jnp.einsum("bld,df->blf", xin, p["in_proj"])
    z, xbc, dt_raw = _split_proj(cfg, proj)

    conv_state = cache["conv"] if cache is not None else None
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :di].reshape(b, l, h, pd)
    Bm = xbc[..., di : di + n]
    Cm = xbc[..., di + n :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])

    if l == 1 and cache is not None:
        # -------- decode: single-step recurrence --------------------------
        s_prev = cache["state"].astype(jnp.float32)              # (B,H,N,P)
        a = jnp.exp(dt[:, 0] * A[None, :])                       # (B,H)
        upd = jnp.einsum("bn,bh,bhp->bhnp", Bm[:, 0].astype(jnp.float32),
                         dt[:, 0], xs[:, 0].astype(jnp.float32))
        s_new = s_prev * a[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), s_new)
        y = y[:, None]                                           # (B,1,H,P)
        final = s_new
    else:
        init_state = cache["state"] if cache is not None else None
        chunk = min(cfg.ssm_chunk, l)
        pad = (-l) % chunk
        if pad:
            # zero-pad is exact: dt=0 at padded steps means no input
            # contribution and unit decay, so y[:l] and the state both match
            xs_p = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt_p = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            Bm_p = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
            Cm_p = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
            y, final = ssd_chunked(xs_p, dt_p, A, Bm_p, Cm_p, chunk, init_state)
            y = y[:, :l]
        else:
            y, final = ssd_chunked(xs, dt, A, Bm, Cm, chunk, init_state)

    y = y + xs.astype(jnp.float32) * p["D_skip"][None, None, :, None]
    y = y.reshape(b, l, di).astype(xin.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"])
    out = jnp.einsum("bld,df->blf", y, p["out_proj"])

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "state": final.astype(cache["state"].dtype)}
    return out, new_cache


def init_ssm_cache(cfg, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim(cfg)), dtype),
        "state": jnp.zeros((batch, n_heads(cfg), cfg.ssm_state, cfg.ssm_head_dim),
                           jnp.float32),
    }
