"""Mixture-of-Experts layer: shared experts + fine-grained routed experts
(DeepSeekMoE / DeepSeek-V2 style: top-k of E small experts + always-on
shared experts).

Dispatch is capacity-based gather/scatter with fixed shapes (TPU-friendly,
no ragged GEMMs): tokens are ranked within their expert via a sort-free
cumsum-of-one-hot, gathered into an (E, C, D) buffer, processed by a single
batched einsum over the expert-stacked weights (expert-parallel shardable
on axis 0), and scattered back weighted by router probs.  Tokens beyond
capacity are dropped (standard switch-style semantics); the router aux loss
keeps load balanced so drops are rare at cf >= 1.25.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, hint_sharding, init_mlp, mlp_block


def init_moe(key, cfg, dtype) -> Dict[str, Any]:
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p: Dict[str, Any] = {
        "router": dense_init(ks[0], d, e, jnp.float32, scale=0.02),
        "experts": {
            "w_gate": (jax.random.normal(ks[1], (e, d, ff), jnp.float32) / np.sqrt(d)).astype(dtype),
            "w_up": (jax.random.normal(ks[2], (e, d, ff), jnp.float32) / np.sqrt(d)).astype(dtype),
            "w_down": (jax.random.normal(ks[3], (e, ff, d), jnp.float32) / np.sqrt(ff)).astype(dtype),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, dtype,
                               d_ff=cfg.moe_d_ff * cfg.num_shared_experts)
    return p


def moe_block(
    p: Dict[str, Any], x: jnp.ndarray, cfg, *, capacity_factor: Optional[float] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), aux load-balance loss scalar).

    Dispatch is PER BATCH ROW (per-device capacity semantics): each row
    ranks its own tokens within each expert and scatters into a private
    (E, C_row, D) slice.  Under the production mesh the batch dim is
    data-sharded and the expert dim model-sharded, so dispatch, expert
    GEMMs, and combine are all collective-free — the only cross-chip
    traffic MoE adds is the routed tokens' contribution to the residual,
    which GSPMD folds into the block's existing output reduction.  The
    within-row order is deterministic (token i, choice j at i·k+j), so the
    combine is a reshape + weighted sum — no scatter.
    """
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                       # (B,S,E)
    top_p, top_e = jax.lax.top_k(probs, k)                        # (B,S,k)
    if cfg.moe_renormalize:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # -- aux loss (switch-style): mean prob * mean assignment fraction per e
    assign = jax.nn.one_hot(top_e, e, dtype=jnp.float32)          # (B,S,k,E)
    frac_tokens = jnp.mean(jnp.sum(assign, axis=2), axis=(0, 1))  # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))                     # (E,)
    aux = jnp.sum(frac_tokens * frac_probs) * e

    # -- per-row capacity dispatch ----------------------------------------
    capacity = max(int(np.ceil(s * k / e * capacity_factor)), 8)
    flat_e = top_e.reshape(b, s * k)                              # (B, S·k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)           # (B, S·k, E)
    pos_in_e = jnp.cumsum(onehot, axis=1) - onehot                # rank within expert
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                     # (B, S·k)
    keep = pos < capacity
    safe_pos = jnp.where(keep, pos, capacity - 1)

    vals = jnp.repeat(x, k, axis=1)                               # (B, S·k, D)
    vals = jnp.where(keep[..., None], vals, 0)

    # vmap'd scatter/gather so the batch dim is an operand-batching dim —
    # GSPMD partitions those; an explicit row-index coordinate would force
    # replication (measured: 48 GiB all-gathers per layer).
    def row_dispatch(er, pr, vr):
        return jnp.zeros((e, capacity, d), x.dtype).at[er, pr].add(vr)

    buf = jax.vmap(row_dispatch)(flat_e, safe_pos, vals.astype(x.dtype))
    buf = hint_sharding(buf, "batch", "model", None, None)

    w = p["experts"]
    g = jnp.einsum("becd,edf->becf", buf, w["w_gate"])
    u = jnp.einsum("becd,edf->becf", buf, w["w_up"])
    h = jax.nn.silu(g) * u
    out_buf = jnp.einsum("becf,efd->becd", h, w["w_down"]).astype(x.dtype)
    out_buf = hint_sharding(out_buf, "batch", "model", None, None)  # (B,E,C,D)

    # combine: deterministic within-row order — reshape + weighted sum
    gathered = jax.vmap(lambda ob, er, pr: ob[er, pr])(
        out_buf, flat_e, safe_pos
    )                                                             # (B, S·k, D)
    weight = (top_p.reshape(b, s * k) * keep.astype(top_p.dtype))
    y = jnp.sum(
        (gathered * weight[..., None].astype(gathered.dtype)).reshape(b, s, k, d),
        axis=2,
    ).astype(x.dtype)

    if "shared" in p:
        y = y + mlp_block(p["shared"], x, cfg)
    return y, aux
