"""Shared neural layers: norms, RoPE, attention variants, MLP variants.

Attention is query-chunked ("flash-style" via ``lax.scan`` over Q blocks
against resident K/V with explicit masks) so 32k-token prefill never
materializes an S×S score matrix.  GQA/MQA, MLA (DeepSeek compressed KV),
sliding windows and decode-with-cache all route through here.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------- init
def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def hint_sharding(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint that degrades to identity off-mesh.

    Model code stays mesh-agnostic: under the production mesh the hint pins
    GSPMD's intermediate sharding (critical for MoE dispatch); in 1-device
    tests it is a no-op.  The sentinel "batch" resolves to ("pod","data")
    when a pod axis exists, else ("data",)."""
    for batch_axes in (("pod", "data"), ("data",)):
        resolved = tuple(batch_axes if s == "batch" else s for s in spec)
        try:
            return jax.lax.with_sharding_constraint(
                x, jax.sharding.PartitionSpec(*resolved)
            )
        except (RuntimeError, ValueError, TypeError, AssertionError, KeyError):
            continue
    return x


# --------------------------------------------------------------------- norms
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
             bf16_apply: bool = False) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    if bf16_apply:
        # stats in f32, application in the residual dtype: the backward of
        # the (B,S,D)-sized multiplies then carries bf16 cotangents, halving
        # the per-layer all-reduce bytes (f32 only flows through the rank-1
        # variance chain)
        r = jax.lax.rsqrt(var + eps).astype(x.dtype)
        return x * r * scale.astype(x.dtype)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, p: Dict[str, jnp.ndarray], cfg=None) -> jnp.ndarray:
    if "bias" in p:
        return layer_norm(x, p["norm_scale"], p["bias"])
    return rms_norm(x, p["norm_scale"],
                    bf16_apply=bool(cfg is not None and cfg.norm_bf16_apply))


def init_norm(dim: int, dtype, layernorm: bool = False) -> Dict[str, jnp.ndarray]:
    p = {"norm_scale": jnp.ones((dim,), dtype)}
    if layernorm:
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


# ---------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                              # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- attention
def _masked_softmax(scores: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    # rows with no valid key (can happen in padded decode) -> zeros
    any_valid = jnp.any(mask, axis=-1, keepdims=True)
    return jnp.where(any_valid, probs, 0.0)


def attention_core(
    q: jnp.ndarray,              # (B, Sq, H, hd)
    k: jnp.ndarray,              # (B, Sk, KH, hd)
    v: jnp.ndarray,              # (B, Sk, KH, hd)
    *,
    q_offset,                    # scalar or (B,): absolute position of q[0]
    window: int = 0,             # 0 = full causal; >0 = sliding window
    kv_len: Optional[jnp.ndarray] = None,  # valid cache length (decode)
    q_chunk: int = 512,
    softmax_scale: Optional[float] = None,
    k_positions: Optional[jnp.ndarray] = None,  # (Sk,) absolute key positions
) -> jnp.ndarray:
    """Causal (optionally windowed) attention, chunked over queries.

    By default key slot ``i`` is assumed to hold absolute position ``i``
    (linear cache / fresh prefill).  ``k_positions`` overrides that for
    out-of-order key buffers (the ring suffix-prefill path): masking uses
    the supplied absolute position per slot, and slots with a negative
    position are treated as empty.
    """
    b, sq, h, hd = q.shape
    _, sk, kh, _ = k.shape
    groups = h // kh
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(hd)

    q = q * jnp.asarray(scale, q.dtype)
    qg = q.reshape(b, sq, kh, groups, hd)
    k_pos = jnp.arange(sk)
    kp = k_pos if k_positions is None else k_positions

    def block(q_blk, q_pos):
        # q_blk (B, c, KH, G, hd); q_pos (c,) absolute positions
        scores = jnp.einsum("bqkgh,bskh->bkgqs", q_blk.astype(jnp.float32),
                            k.astype(jnp.float32))
        qp = q_pos[:, None]                         # (c, 1)
        mask = kp[None, :] <= qp                    # causal
        if k_positions is not None:
            mask &= kp[None, :] >= 0                # empty ring slots
        if window:
            mask &= kp[None, :] > qp - window
        mask = mask[None, None, None]               # (1,1,1,c,S)
        if kv_len is not None:
            valid = k_pos[None, :] < jnp.reshape(kv_len, (-1, 1, 1))[:, None]
            mask = mask & valid.reshape(b, 1, 1, 1, sk)
        probs = _masked_softmax(scores, mask)
        out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)
        return out

    vd = v.shape[-1]
    if sq <= q_chunk:
        pos = q_offset + jnp.arange(sq)
        out = block(qg, pos)
    else:
        assert sq % q_chunk == 0, (sq, q_chunk)
        n_blk = sq // q_chunk
        qs = qg.reshape(b, n_blk, q_chunk, kh, groups, hd).swapaxes(0, 1)

        def step(_, inp):
            q_blk, i = inp
            pos = q_offset + i * q_chunk + jnp.arange(q_chunk)
            return None, block(q_blk, pos)

        _, outs = jax.lax.scan(step, None, (qs, jnp.arange(n_blk)))
        out = outs.swapaxes(0, 1).reshape(b, sq, kh, groups, vd)
    return out.reshape(b, sq, h, vd)


def _attn_qkv(p: Dict[str, Any], x: jnp.ndarray, cfg,
              positions: jnp.ndarray):
    """Shared GQA q/k/v projection + bias + RoPE — the ONE front end of
    both the contiguous and the kernel-resident paged attention paths
    (``positions`` broadcastable to (B, S)), so they cannot drift."""
    b, s, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,df->bsf", x, p["wq"])
    k = jnp.einsum("bsd,df->bsf", x, p["wk"])
    v = jnp.einsum("bsd,df->bsf", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kh, hd)
    v = v.reshape(b, s, kh, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mla_qkv(p: Dict[str, Any], x: jnp.ndarray, cfg,
             positions: jnp.ndarray):
    """Shared MLA projection front end (query, compressed KV, rotary
    key) of the contiguous and paged paths; see :func:`_attn_qkv`."""
    b, s, _ = x.shape
    h = cfg.num_heads
    nope, rope_d, r = cfg.qk_nope_dim, cfg.rope_head_dim, cfg.kv_lora_rank
    q = jnp.einsum("bsd,df->bsf", x, p["wq"]).reshape(b, s, h, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    dkv = jnp.einsum("bsd,df->bsf", x, p["w_dkv"])            # (B,S,r+rope_d)
    c_kv = rms_norm(dkv[..., :r], p["ckv_norm"])
    k_rope = dkv[..., r:][:, :, None, :]                       # (B,S,1,rope_d)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


# ---------------------------------------------- kernel-resident paged decode
def gather_paged(blocks: jnp.ndarray, tables: jnp.ndarray) -> jnp.ndarray:
    """(P, bs, *rest) physical blocks + (B, T) tables -> (B, T*bs, *rest).

    The only read of paged cache bytes during kernel-resident decode:
    tables are trimmed to the micro-batch's used width, so this is
    O(context) — not O(capacity) — and there is no write-back (the one
    new token went in through its block index)."""
    g = blocks[tables]
    s = g.shape
    return g.reshape(s[0], s[1] * s[2], *s[3:])


def paged_decode_attend(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        ctx: jnp.ndarray, *,
                        softmax_scale: Optional[float] = None) -> jnp.ndarray:
    """One-token-per-lane attention over a table-gathered cache.

    q (B, H, hd); k/v (B, S, KH, hd) in logical order with junk past each
    lane's ``ctx`` (B,) valid length (masked).  Mirrors
    :func:`attention_core`'s decode numerics — q scaled in its own dtype,
    f32 scores, :func:`_masked_softmax`, probs cast to ``v.dtype`` — so
    kernel-resident and gather/scatter decode agree to float tolerance.
    """
    b, h, hd = q.shape
    kh = k.shape[2]
    groups = h // kh
    scale = softmax_scale if softmax_scale is not None else 1.0 / np.sqrt(hd)
    qg = (q * jnp.asarray(scale, q.dtype)).reshape(b, kh, groups, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                        k.astype(jnp.float32))
    mask = jnp.arange(k.shape[1])[None, :] < ctx[:, None]     # (B, S)
    probs = _masked_softmax(scores, mask[:, None, None, :])
    out = jnp.einsum("bkgs,bskh->bkgh", probs.astype(v.dtype), v)
    return out.reshape(b, h, v.shape[-1])


def attention_block_paged(
    p: Dict[str, Any], x: jnp.ndarray, cfg, *,
    cache: Dict[str, jnp.ndarray], tables: jnp.ndarray, pos: jnp.ndarray,
    use_kernel: bool = False, interpret: bool = False,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """GQA decode straight against the paged pool — no contiguous view.

    ``x`` is (B, 1, d) — one token per lane; ``cache`` holds this layer's
    *physical block* leaves ``k``/``v`` (1, P+1, bs, KH, hd) (plus int8
    scales) shared by every lane, and the per-lane ``len`` (B,).
    ``tables`` (B, T) names each lane's blocks in logical order (trimmed
    to the batch's used width, null-padded); ``pos`` (B,) is each lane's
    absolute position.  The new K/V token is written through
    ``(tables[b, pos // bs], pos % bs)`` — a block-indexed scatter, the
    write half of ``kernels/paged_attention.paged_decode_write`` — and
    attention reads the cache once through the table (``use_kernel=True``
    routes it through the Pallas scalar-prefetch kernel; the default is
    the pure-JAX gather fallback with identical semantics).
    """
    b, s, d = x.shape
    assert s == 1, s
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q, k, v = _attn_qkv(p, x, cfg, pos[:, None])

    quant = "k_scale" in cache
    kc = cache["k"][0]                                        # (P+1, bs, ...)
    vc = cache["v"][0]
    bs_sz = kc.shape[1]
    blk = jnp.take_along_axis(tables, (pos // bs_sz)[:, None], axis=1)[:, 0]
    off = pos % bs_sz
    if quant:
        kq, ks = _kv_quantize(k)
        vq, vs = _kv_quantize(v)
        kc = kc.at[blk, off].set(kq[:, 0])
        vc = vc.at[blk, off].set(vq[:, 0])
        ksc = cache["k_scale"][0].at[blk, off].set(ks[:, 0])
        vsc = cache["v_scale"][0].at[blk, off].set(vs[:, 0])
        kk = _kv_dequantize(gather_paged(kc, tables),
                            gather_paged(ksc, tables), k.dtype)
        vv = _kv_dequantize(gather_paged(vc, tables),
                            gather_paged(vsc, tables), v.dtype)
        out = paged_decode_attend(q[:, 0], kk, vv, pos + 1)
    elif use_kernel:
        from repro.kernels.paged_attention import (paged_attention,
                                                   paged_decode_write)

        kc, vc = paged_decode_write(kc, vc, k[:, 0], v[:, 0], blk, off,
                                    interpret=interpret)
        out = paged_attention(q[:, 0], kc, vc, tables, pos + 1,
                              interpret=interpret).astype(x.dtype)
    else:
        kc = kc.at[blk, off].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[blk, off].set(v[:, 0].astype(vc.dtype))
        out = paged_decode_attend(q[:, 0], gather_paged(kc, tables),
                                  gather_paged(vc, tables), pos + 1)
    # len + 1 never clamps here: the gateway admits pos < capacity only
    new_cache = {"k": kc[None], "v": vc[None], "len": cache["len"] + 1}
    if quant:
        new_cache["k_scale"] = ksc[None]
        new_cache["v_scale"] = vsc[None]
    y = jnp.einsum("bsf,fd->bsd", out.reshape(b, 1, h * hd), p["wo"])
    return y, new_cache


def mla_block_paged(
    p: Dict[str, Any], x: jnp.ndarray, cfg, *,
    cache: Dict[str, jnp.ndarray], tables: jnp.ndarray, pos: jnp.ndarray,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """MLA decode against paged compressed-KV blocks.

    Same contract as :func:`attention_block_paged`: write the token's
    ``c_kv``/rotary key through its block index, gather the lane's chain
    once, decompress, attend.  Decompression covers T*bs gathered
    positions instead of the full capacity — strictly fewer FLOPs than
    the contiguous decode it replaces."""
    b, s, d = x.shape
    assert s == 1, s
    h = cfg.num_heads
    nope, rope_d, vd, r = (cfg.qk_nope_dim, cfg.rope_head_dim,
                           cfg.v_head_dim, cfg.kv_lora_rank)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, pos[:, None])

    ckv_blocks = cache["ckv"][0]                              # (P+1, bs, r)
    kr_blocks = cache["k_rope"][0]
    bs_sz = ckv_blocks.shape[1]
    blk = jnp.take_along_axis(tables, (pos // bs_sz)[:, None], axis=1)[:, 0]
    off = pos % bs_sz
    ckv_blocks = ckv_blocks.at[blk, off].set(
        c_kv[:, 0].astype(ckv_blocks.dtype))
    kr_blocks = kr_blocks.at[blk, off].set(
        k_rope[:, 0, 0].astype(kr_blocks.dtype))

    c_all = gather_paged(ckv_blocks, tables)                  # (B, S, r)
    kr_all = gather_paged(kr_blocks, tables)[:, :, None, :]   # (B, S, 1, rd)
    ukv = jnp.einsum("bsr,rf->bsf", c_all, p["w_ukv"]).reshape(
        b, c_all.shape[1], h, nope + vd)
    k_nope, v = ukv[..., :nope], ukv[..., nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_all, (*k_nope.shape[:3], rope_d))],
        axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = paged_decode_attend(qfull[:, 0], k, v, pos + 1,
                              softmax_scale=1.0 / np.sqrt(nope + rope_d))
    new_cache = {"ckv": ckv_blocks[None], "k_rope": kr_blocks[None],
                 "len": cache["len"] + 1}
    y = jnp.einsum("bsf,fd->bsd", out.reshape(b, 1, h * vd), p["wo"])
    return y, new_cache


# ------------------------------------------------------------- GQA attention
def init_attention(key, cfg, dtype) -> Dict[str, Any]:
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kh * hd, dtype),
        "wv": dense_init(ks[2], d, kh * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kh * hd,), dtype)
        p["bv"] = jnp.zeros((kh * hd,), dtype)
    return p


def attention_block(
    p: Dict[str, Any], x: jnp.ndarray, cfg, *,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    pos=0, window: int = 0, attend_cache: bool = False,
    chunk_valid=None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """GQA/MQA attention.  ``cache`` holds k/v (B, cap, KH, hd) + ``len``.

    Modes: train/prefill (cache None or filled-from-empty), decode
    (Sq == 1 with a pre-filled ring/linear cache), and — with
    ``attend_cache=True`` — *suffix/chunked prefill*: Sq > 1 new tokens
    starting at absolute ``pos`` attend over the updated cache contents
    instead of only each other, so a prompt whose prefix ``[0, pos)`` is
    already resident (prefix cache, or an earlier chunk of the same
    prompt) runs prefill on the uncached tail alone.

    For a *linear* cache (``window == 0``, slot == absolute position)
    writes beyond the last slot clamp onto it (masked until a real decode
    write lands there) rather than wrapping over live prefix slots.  With
    ``window > 0`` the cache is a ring: the chunk's own writes may evict
    positions its earliest queries still need, so attention reads a
    pre-write snapshot of the ring concatenated with the fresh chunk K/V,
    with per-slot absolute positions reconstructed from ``pos`` (see
    ``k_positions`` in :func:`attention_core`); writes then land at
    ``mod(position, cap)`` as usual.

    ``chunk_valid`` (optional, scalar or (B,)) is the number of leading
    *real* rows in this chunk — trailing rows are right-padding.  It
    keeps the ``len`` counter exact and, on the ring path, masks the pad
    rows' writes so they cannot clobber live slots.  Pad rows on the
    linear path are safe unmasked: their clamped/high slots are causally
    invisible to every real query and are overwritten by the next chunk
    or the first decode write before anything can attend to them.
    """
    b, s, d = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    positions = pos + jnp.arange(s)
    q, k, v = _attn_qkv(p, x, cfg, jnp.broadcast_to(positions, (b, s)))

    if cache is None:
        out = attention_core(q, k, v, q_offset=pos, window=window,
                             q_chunk=cfg.q_chunk)
        new_cache = None
    else:
        quant = "k_scale" in cache
        cap = cache["k"].shape[1]
        ring = bool(window) and attend_cache
        if ring:
            assert s <= cap, (s, cap)  # one chunk may not lap the ring
            slot = jnp.mod(positions, cap)
            # snapshot BEFORE the writes: the chunk's earliest queries may
            # need positions its own writes are about to evict
            if quant:
                old_k = _kv_dequantize(cache["k"], cache["k_scale"], k.dtype)
                old_v = _kv_dequantize(cache["v"], cache["v_scale"], v.dtype)
            else:
                old_k, old_v = cache["k"], cache["v"]
            # absolute position resident in ring slot i before this chunk:
            # the largest p < pos with mod(p, cap) == i (negative = empty,
            # masked in attention_core)
            last = positions[0] - 1
            old_pos = last - jnp.mod(last - jnp.arange(cap), cap)
        elif attend_cache:
            # linear cache: clamp instead of wrap, so a lane whose suffix
            # is padded past the capacity piles the pad writes onto the
            # (masked) last slot rather than corrupting prefix slots
            slot = jnp.clip(positions, 0, cap - 1)
        else:
            slot = jnp.mod(positions, cap)                 # ring for windowed
        sel = None
        if ring and chunk_valid is not None:
            # mask pad rows' writes: an invalid row re-writes the old
            # content of its slot (identity), so junk never lands
            keep = (jnp.arange(s)[None, :]
                    < jnp.reshape(jnp.asarray(chunk_valid), (-1, 1)))
            sel = keep[..., None, None]                     # (B|1, s, 1, 1)
        if quant:
            kq, ks = _kv_quantize(k)
            vq, vs = _kv_quantize(v)
            if sel is not None:
                # mask at the code/scale level so pad rows round-trip the
                # resident int8 content exactly
                kq = jnp.where(sel, kq, cache["k"][:, slot])
                vq = jnp.where(sel, vq, cache["v"][:, slot])
                ks = jnp.where(sel, ks, cache["k_scale"][:, slot])
                vs = jnp.where(sel, vs, cache["v_scale"][:, slot])
            ck = cache["k"].at[:, slot].set(kq)
            cv = cache["v"].at[:, slot].set(vq)
            cks = cache["k_scale"].at[:, slot].set(ks)
            cvs = cache["v_scale"].at[:, slot].set(vs)
        else:
            k_w = k if sel is None else jnp.where(sel, k, old_k[:, slot])
            v_w = v if sel is None else jnp.where(sel, v, old_v[:, slot])
            # the offset-0 contiguous fast path only holds for a filled-
            # from-empty prefill; a chunk at pos > 0 must scatter by slot
            dus = s == cap and not attend_cache
            ck = jax.lax.dynamic_update_slice(  # contiguous when s==cap write
                cache["k"], k_w.astype(cache["k"].dtype), (0, 0, 0, 0)
            ) if dus else cache["k"].at[:, slot].set(
                k_w.astype(cache["k"].dtype))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v_w.astype(cache["v"].dtype), (0, 0, 0, 0)
            ) if dus else cache["v"].at[:, slot].set(
                v_w.astype(cache["v"].dtype))
        cv_n = s if chunk_valid is None else jnp.asarray(chunk_valid)
        new_len = jnp.minimum(cache["len"] + cv_n, cap)
        if ring:
            # attend over [ring snapshot | fresh chunk K/V] with explicit
            # absolute key positions; window masking bounds the lookback.
            # Quantized caches attend the fresh chunk in round-tripped
            # int8 form so every key is seen dequantized no matter which
            # chunk boundary it fell on.
            if quant:
                k_att = _kv_dequantize(*_kv_quantize(k), k.dtype)
                v_att = _kv_dequantize(*_kv_quantize(v), v.dtype)
            else:
                k_att, v_att = k, v
            out = attention_core(
                q, jnp.concatenate([old_k, k_att], axis=1),
                jnp.concatenate([old_v, v_att], axis=1),
                q_offset=pos, window=window, q_chunk=cfg.q_chunk,
                k_positions=jnp.concatenate([old_pos, positions]),
            )
        elif s == 1 or attend_cache:
            # decode: attend over the valid cache (mask handles ring order —
            # with RoPE already applied per absolute position, order in the
            # buffer is irrelevant to the score computation).  Suffix
            # prefill attends the same way, but causal masking alone bounds
            # it: every slot <= query position holds either the resident
            # prefix or a token written this step, and ``len`` may be
            # unseeded (the gateway overrides counters after the step).
            if quant:
                kk = _kv_dequantize(ck, cks, k.dtype)
                vv = _kv_dequantize(cv, cvs, v.dtype)
            else:
                kk, vv = ck, cv
            out = attention_core(
                q, kk, vv, q_offset=pos, window=0,
                kv_len=None if attend_cache else new_len,
                q_chunk=cfg.q_chunk,
            )
        else:
            out = attention_core(q, k, v, q_offset=pos, window=window,
                                 q_chunk=cfg.q_chunk)
        new_cache = {"k": ck, "v": cv, "len": new_len}
        if quant:
            new_cache["k_scale"] = cks
            new_cache["v_scale"] = cvs
    y = jnp.einsum("bsf,fd->bsd", out.reshape(b, s, h * hd), p["wo"])
    return y, new_cache


def init_attn_cache(cfg, batch: int, capacity: int, dtype) -> Dict[str, jnp.ndarray]:
    kh, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.kv_cache_int8:
        # int8 codes + per-(token, head) scales: 2x less cache traffic than
        # bf16 at <0.5% logit error (decode rows are cache-read-bound)
        return {
            "k": jnp.zeros((batch, capacity, kh, hd), jnp.int8),
            "v": jnp.zeros((batch, capacity, kh, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, capacity, kh, 1), jnp.float32),
            "v_scale": jnp.zeros((batch, capacity, kh, 1), jnp.float32),
            "len": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros((batch, capacity, kh, hd), dtype),
        "v": jnp.zeros((batch, capacity, kh, hd), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


def _kv_quantize(x: jnp.ndarray):
    """(B,S,KH,hd) -> int8 codes + (B,S,KH,1) scales (symmetric absmax)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return codes.astype(jnp.int8), scale


def _kv_dequantize(codes: jnp.ndarray, scale: jnp.ndarray, dtype):
    return (codes.astype(jnp.float32) * scale).astype(dtype)


# ------------------------------------------------------------- MLA attention
def init_mla(key, cfg, dtype) -> Dict[str, Any]:
    d, h = cfg.d_model, cfg.num_heads
    nope, rope_d, vd, r = cfg.qk_nope_dim, cfg.rope_head_dim, cfg.v_head_dim, cfg.kv_lora_rank
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, h * (nope + rope_d), dtype),
        "w_dkv": dense_init(ks[1], d, r + rope_d, dtype),
        "w_ukv": dense_init(ks[2], r, h * (nope + vd), dtype),
        "wo": dense_init(ks[3], h * vd, d, dtype),
        "ckv_norm": jnp.ones((r,), dtype),
    }


def mla_block(
    p: Dict[str, Any], x: jnp.ndarray, cfg, *,
    cache: Optional[Dict[str, jnp.ndarray]] = None, pos=0, window: int = 0,
    attend_cache: bool = False, chunk_valid=None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    """Multi-head Latent Attention (DeepSeek-V2).  The cache stores the
    COMPRESSED c_kv (r) + shared rotary key (rope_d) — the paper's KV-cache
    reduction.  Baseline decompresses per step (the weight-absorbed decode
    variant is a §Perf hillclimb)."""
    b, s, d = x.shape
    h = cfg.num_heads
    nope, rope_d, vd, r = cfg.qk_nope_dim, cfg.rope_head_dim, cfg.v_head_dim, cfg.kv_lora_rank

    positions = pos + jnp.arange(s)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(
        p, x, cfg, jnp.broadcast_to(positions, (b, s)))

    if cache is not None:
        cap = cache["ckv"].shape[1]
        # suffix/chunked prefill (attend_cache): linear cache — clamp,
        # don't wrap (see attention_block); pad writes pile onto the
        # masked last slot.  MLA configs never use sliding windows, so
        # the ring snapshot path is not implemented here.
        assert not (attend_cache and window), \
            "windowed MLA chunked prefill is unsupported"
        slot = (jnp.clip(positions, 0, cap - 1) if attend_cache
                else jnp.mod(positions, cap))
        c_all = cache["ckv"].at[:, slot].set(c_kv.astype(cache["ckv"].dtype))
        kr_all = cache["k_rope"].at[:, slot].set(k_rope.squeeze(2).astype(cache["k_rope"].dtype))
        cv_n = s if chunk_valid is None else jnp.asarray(chunk_valid)
        new_len = jnp.minimum(cache["len"] + cv_n, cap)
        new_cache = {"ckv": c_all, "k_rope": kr_all, "len": new_len}
        kv_src, kr_src = c_all, kr_all[:, :, None, :]
        # attend_cache: causal masking alone bounds the scores (slot ==
        # absolute position and ``len`` may be unseeded), matching the
        # suffix-prefill contract in attention_block
        kv_len = None if attend_cache else new_len
    else:
        new_cache = None
        kv_src, kr_src, kv_len = c_kv, k_rope, None

    ukv = jnp.einsum("bsr,rf->bsf", kv_src, p["w_ukv"]).reshape(
        b, kv_src.shape[1], h, nope + vd
    )
    k_nope, v = ukv[..., :nope], ukv[..., nope:]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr_src, (*k_nope.shape[:3], rope_d))], axis=-1)
    qfull = jnp.concatenate([q_nope, q_rope], axis=-1)

    out = attention_core(
        qfull, k, v, q_offset=pos, window=window,
        kv_len=kv_len if s == 1 else None, q_chunk=cfg.q_chunk,
        softmax_scale=1.0 / np.sqrt(nope + rope_d),
    )
    y = jnp.einsum("bsf,fd->bsd", out.reshape(b, s, h * vd), p["wo"])
    return y, new_cache


def init_mla_cache(cfg, batch: int, capacity: int, dtype) -> Dict[str, jnp.ndarray]:
    return {
        "ckv": jnp.zeros((batch, capacity, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, capacity, cfg.rope_head_dim), dtype),
        "len": jnp.zeros((batch,), jnp.int32),
    }


# ---------------------------------------------------------------------- MLPs
def init_mlp(key, cfg, dtype, d_ff: Optional[int] = None) -> Dict[str, Any]:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d, ff, dtype),
            "w_up": dense_init(ks[1], d, ff, dtype),
            "w_down": dense_init(ks[2], ff, d, dtype),
        }
    # squared_relu (nemotron family): two matrices
    return {
        "w_up": dense_init(ks[0], d, ff, dtype),
        "w_down": dense_init(ks[1], ff, d, dtype),
    }


def mlp_block(p: Dict[str, Any], x: jnp.ndarray, cfg) -> jnp.ndarray:
    if "w_gate" in p:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        h = jax.nn.silu(g) * u
    else:
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"])
        r = jax.nn.relu(u)
        h = r * r  # squared ReLU
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])
