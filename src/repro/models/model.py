"""Unified decoder-only model over all supported block kinds.

Layers are grouped into *pattern units* (one unit = one cycle of
``cfg.layer_pattern``), stacked over units, and evaluated with
``lax.scan`` so an 88-layer model lowers to the HLO of one unit — compile
time and HLO size stay bounded.  KV/SSM/LRU caches ride the scan as
stacked xs/ys.  ``remat`` checkpoints each unit for training.

Entry points:
  init_params(key, cfg)                      -> param pytree
  forward(params, cfg, tokens/embeds, ...)   -> logits (+ aux, + cache)
  init_cache(cfg, batch, capacity)           -> cache pytree
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.configs.base import ModelConfig


# ------------------------------------------------------------------- blocks
def _init_block(key, cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    dtype = cfg.dtype
    ln = cfg.norm_layernorm
    if kind == "attn":
        mixer = (L.init_mla(ks[0], cfg, dtype) if cfg.use_mla
                 else L.init_attention(ks[0], cfg, dtype))
        p = {"norm1": L.init_norm(cfg.d_model, dtype, ln), "mixer": mixer,
             "norm2": L.init_norm(cfg.d_model, dtype, ln)}
        if cfg.num_experts:
            p["ffn"] = M.init_moe(ks[1], cfg, dtype)
        else:
            p["ffn"] = L.init_mlp(ks[1], cfg, dtype)
        return p
    if kind == "ssm":
        return {"norm1": L.init_norm(cfg.d_model, dtype, ln),
                "mixer": S.init_ssm(ks[0], cfg, dtype)}
    if kind == "rec":
        return {"norm1": L.init_norm(cfg.d_model, dtype, ln),
                "mixer": R.init_rglru(ks[0], cfg, dtype),
                "norm2": L.init_norm(cfg.d_model, dtype, ln),
                "ffn": L.init_mlp(ks[1], cfg, dtype)}
    raise ValueError(f"unknown block kind {kind!r}")


def _apply_block(
    p: Dict[str, Any], kind: str, x: jnp.ndarray, cfg: ModelConfig, *,
    cache: Optional[Dict[str, Any]], pos, attend_cache: bool = False,
    chunk_valid=None,
    paged_tables: Optional[jnp.ndarray] = None, paged_kernel: str = "off",
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict[str, Any]]]:
    """Pre-norm residual block.  Returns (x, aux_loss, new_cache).

    ``attend_cache`` (static) selects suffix-prefill attention — Sq > 1
    tokens starting at ``pos`` attend over resident cache contents; only
    attention blocks consume it (SSM/RG-LRU state is sequential, so the
    prefix-cache gate never routes those models here).

    ``paged_tables`` (B, T) selects *kernel-resident paged decode*:
    attention blocks receive physical block leaves plus per-lane block
    tables and absolute positions (``pos`` is a (B,) vector) instead of
    a contiguous cache; SSM/RG-LRU state is position-independent and
    batch-row-local, so those blocks run unchanged on their lane-stacked
    state."""
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(x, p["norm1"], cfg)
    if kind == "attn":
        if paged_tables is not None and cfg.use_mla:
            y, new_cache = L.mla_block_paged(p["mixer"], h, cfg, cache=cache,
                                             tables=paged_tables, pos=pos)
        elif paged_tables is not None:
            y, new_cache = L.attention_block_paged(
                p["mixer"], h, cfg, cache=cache, tables=paged_tables,
                pos=pos, use_kernel=paged_kernel != "off",
                interpret=paged_kernel == "interpret")
        elif cfg.use_mla:
            y, new_cache = L.mla_block(p["mixer"], h, cfg, cache=cache, pos=pos,
                                       window=cfg.window,
                                       attend_cache=attend_cache,
                                       chunk_valid=chunk_valid)
        else:
            y, new_cache = L.attention_block(p["mixer"], h, cfg, cache=cache,
                                             pos=pos, window=cfg.window,
                                             attend_cache=attend_cache,
                                             chunk_valid=chunk_valid)
        x = x + y.astype(x.dtype)
        h2 = L.apply_norm(x, p["norm2"], cfg)
        if cfg.num_experts:
            y2, aux = M.moe_block(p["ffn"], h2, cfg)
        else:
            y2 = L.mlp_block(p["ffn"], h2, cfg)
        return x + y2.astype(x.dtype), aux, new_cache
    if kind == "ssm":
        y, new_cache = S.ssm_block(p["mixer"], h, cfg, cache=cache)
        return x + y, aux, new_cache
    if kind == "rec":
        y, new_cache = R.rglru_block(p["mixer"], h, cfg, cache=cache)
        x = x + y
        h2 = L.apply_norm(x, p["norm2"], cfg)
        return x + L.mlp_block(p["ffn"], h2, cfg), aux, new_cache
    raise ValueError(kind)


def _init_block_cache(cfg: ModelConfig, kind: str, batch: int, capacity: int):
    dtype = cfg.dtype
    if kind == "attn":
        cap = min(capacity, cfg.window) if cfg.window else capacity
        if cfg.use_mla:
            return L.init_mla_cache(cfg, batch, cap, dtype)
        return L.init_attn_cache(cfg, batch, cap, dtype)
    if kind == "ssm":
        return S.init_ssm_cache(cfg, batch, dtype)
    if kind == "rec":
        return R.init_rglru_cache(cfg, batch, dtype)
    raise ValueError(kind)


# ------------------------------------------------------------------- params
def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    ks = jax.random.split(key, 8)
    dtype = cfg.dtype
    n_units = cfg.pattern_units
    pattern = cfg.layer_pattern

    def unit(k):
        kk = jax.random.split(k, len(pattern))
        return {f"b{j}": _init_block(kk[j], cfg, kind)
                for j, kind in enumerate(pattern)}

    unit_keys = jax.random.split(ks[0], n_units)
    units = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs), *[unit(k) for k in unit_keys]
    ) if n_units > 1 else jax.tree_util.tree_map(
        lambda x: x[None], unit(unit_keys[0])
    )

    params: Dict[str, Any] = {
        "embed": {"tok": (jax.random.normal(ks[1], (cfg.padded_vocab, cfg.d_model),
                                            jnp.float32) * 0.02).astype(dtype)},
        "units": units,
        "final_norm": L.init_norm(cfg.d_model, dtype, cfg.norm_layernorm),
        "lm_head": L.dense_init(ks[2], cfg.d_model, cfg.padded_vocab, dtype, scale=0.02),
    }
    tail = cfg.tail_pattern
    if tail:
        tk = jax.random.split(ks[3], len(tail))
        params["tail"] = {f"t{j}": _init_block(tk[j], cfg, kind)
                          for j, kind in enumerate(tail)}
    if cfg.frontend == "vision":
        # projector from the (stub) vision encoder's output to d_model
        params["vision_proj"] = L.dense_init(ks[4], cfg.d_model, cfg.d_model, dtype)
    return params


def init_cache(cfg: ModelConfig, batch: int, capacity: int) -> Dict[str, Any]:
    n_units = cfg.pattern_units
    pattern = cfg.layer_pattern

    def unit_cache():
        return {f"b{j}": _init_block_cache(cfg, kind, batch, capacity)
                for j, kind in enumerate(pattern)}

    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (n_units, *x.shape)), unit_cache()
    )
    cache: Dict[str, Any] = {"units": stacked}
    tail = cfg.tail_pattern
    if tail:
        cache["tail"] = {f"t{j}": _init_block_cache(cfg, kind, batch, capacity)
                         for j, kind in enumerate(tail)}
    return cache


# ------------------------------------------------------------------ forward
def forward(
    params: Dict[str, Any],
    cfg: ModelConfig,
    tokens: Optional[jnp.ndarray] = None,       # (B, S_text) int32
    *,
    patch_embeds: Optional[jnp.ndarray] = None,  # (B, P, D) vision stub output
    cache: Optional[Dict[str, Any]] = None,
    pos=0,
    license_intervals=None,   # (lo, hi) f32[MAX_INTERVALS] — fused-dequant licensing
    attend_cache: bool = False,  # static: suffix prefill attends cache contents
    chunk_valid=None,         # scalar or (B,): real rows in a right-padded chunk
    paged_tables: Optional[jnp.ndarray] = None,  # (B, T): kernel-resident decode
    paged_kernel: str = "off",   # static: "off" | "pallas" | "interpret"
) -> Tuple[jnp.ndarray, jnp.ndarray, Optional[Dict[str, Any]]]:
    """Returns (logits (B,S,V), aux_loss, new_cache or None).

    ``params`` may contain int8 {"codes","scale"} leaves (see
    serving/quantized.py); they are dequantized INSIDE the layer scan with
    ``license_intervals`` masks fused in, so weight HBM reads stay int8 and
    every license tier shares one stored model.

    ``attend_cache=True`` is the *suffix prefill* mode behind the prefix
    cache: ``tokens`` are the uncached tail of a prompt whose positions
    ``[0, pos)`` are already resident in ``cache``, and attention reads
    the cache (prefix + this step's writes) instead of only the provided
    tokens.  Linear caches clamp pad writes; windowed (ring) caches take
    the snapshot-attend path — see ``attention_block``.  ``chunk_valid``
    gives the number of leading real rows per lane when a chunk is
    right-padded (keeps ``len`` counters exact and masks ring pad
    writes); only attention blocks consume it.

    ``paged_tables`` selects *kernel-resident paged decode* (one token
    per lane): ``cache`` is the hybrid pytree from
    ``PagedCachePool.decode_cache`` — attention leaves are the pool's
    physical block arrays shared by every lane, per-lane state is
    lane-gathered — ``pos`` is a (B,) vector of absolute positions, and
    attention reads/writes the pool *through the block table* instead of
    a contiguous per-lane view.  ``paged_kernel`` routes the read through
    the Pallas scalar-prefetch kernel ("pallas"; "interpret" for CPU
    testing) or the pure-JAX gather fallback ("off")."""
    if paged_tables is not None:
        assert cache is not None and not attend_cache
    parts = []
    if patch_embeds is not None:
        proj = params.get("vision_proj")
        pe = jnp.einsum("bpd,df->bpf", patch_embeds, proj) if proj is not None else patch_embeds
        parts.append(pe.astype(cfg.dtype))
    if tokens is not None:
        parts.append(params["embed"]["tok"][tokens])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.pin_acts and x.shape[1] > 1:
        # pin the entry activation: the vocab-sharded embedding gather (and
        # the VLM patch/text concat) otherwise seed feature-sharded
        # residuals through the whole layer stack
        x = L.hint_sharding(x, "batch", None, None)

    pattern = cfg.layer_pattern
    aux_total = jnp.zeros((), jnp.float32)

    def unit_step(carry, xs):
        x, aux = carry
        unit_params, unit_cache = xs
        from repro.serving.quantized import dequant_tree

        unit_params = dequant_tree(unit_params, license_intervals, cfg.dtype)
        new_caches = {}
        for j, kind in enumerate(pattern):
            c = None if unit_cache is None else unit_cache[f"b{j}"]
            x, a, nc = _apply_block(unit_params[f"b{j}"], kind, x, cfg,
                                    cache=c, pos=pos,
                                    attend_cache=attend_cache,
                                    chunk_valid=chunk_valid,
                                    paged_tables=paged_tables,
                                    paged_kernel=paged_kernel)
            aux = aux + a
            new_caches[f"b{j}"] = nc if nc is not None else ()
        if cache is None and x.shape[1] > 1:
            # Pin the residual stream (== the per-unit activation checkpoint
            # jax.checkpoint saves): batch over DP axes, optionally
            # seq-sharded over "model" (Megatron-SP).
            if cfg.seq_sharded_acts:
                x = L.hint_sharding(x, "batch", "model", None)
            elif cfg.pin_acts:
                x = L.hint_sharding(x, "batch", None, None)
        return (x, aux), new_caches

    step = unit_step
    if cfg.remat and cache is None:
        step = jax.checkpoint(unit_step, prevent_cse=False)

    if cache is not None:
        (x, aux_total), new_unit_caches = jax.lax.scan(
            step, (x, aux_total), (params["units"], cache["units"])
        )
    else:
        (x, aux_total), _ = jax.lax.scan(
            lambda c, p_: (step(c, (p_, None))[0], ()), (x, aux_total),
            params["units"],
        )
        new_unit_caches = None

    new_cache = None
    if cache is not None:
        new_cache = {"units": new_unit_caches}

    tail = cfg.tail_pattern
    if tail:
        from repro.serving.quantized import dequant_tree as _dq

        new_tail = {}
        for j, kind in enumerate(tail):
            c = None if cache is None else cache["tail"][f"t{j}"]
            tp = _dq(params["tail"][f"t{j}"], license_intervals, cfg.dtype)
            x, a, nc = _apply_block(tp, kind, x, cfg,
                                    cache=c, pos=pos,
                                    attend_cache=attend_cache,
                                    chunk_valid=chunk_valid,
                                    paged_tables=paged_tables,
                                    paged_kernel=paged_kernel)
            aux_total = aux_total + a
            new_tail[f"t{j}"] = nc if nc is not None else ()
        if new_cache is not None:
            new_cache["tail"] = new_tail

    x = L.apply_norm(x, params["final_norm"], cfg)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"]).astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad_ids = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(pad_ids[None, None, :], -1e9, logits)
    return logits, aux_total, new_cache


# --------------------------------------------------------------------- loss
def lm_loss(
    params: Dict[str, Any], cfg: ModelConfig, tokens: jnp.ndarray,
    labels: jnp.ndarray, *, patch_embeds: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Causal LM cross-entropy (+ MoE aux).  labels = next-token ids, with
    -100 entries masked out.  For VLM inputs the patch prefix positions are
    excluded from the loss by construction (labels cover text only)."""
    logits, aux, _ = forward(params, cfg, tokens, patch_embeds=patch_embeds)
    if patch_embeds is not None:
        logits = logits[:, patch_embeds.shape[1]:]
    mask = labels != -100
    safe = jnp.where(mask, labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1)
    loss = jnp.sum(jnp.where(mask, nll, 0.0)) / denom
    total = loss + cfg.moe_aux_weight * aux
    return total, {"lm_loss": loss, "aux_loss": aux}
