"""Model zoo: unified decoder covering dense/GQA, MLA, MoE, Mamba-2 SSD,
RG-LRU hybrid, audio- and vision-conditioned backbones."""
from repro.models.model import forward, init_cache, init_params, lm_loss

__all__ = ["forward", "init_cache", "init_params", "lm_loss"]
