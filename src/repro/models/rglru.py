"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ u_t), with the real-gated
decay a_t = exp(-c · r_t · softplus(Λ)).  Training/prefill evaluates the
linear recurrence with ``lax.associative_scan`` (log-depth on TPU); decode
is the single step.  The block wraps the RG-LRU with the Griffin recipe:
parallel gate branch, causal conv1d on the recurrent branch, gated output.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init

_C = 8.0  # Griffin's fixed gate sharpness


def init_rglru(key, cfg, dtype) -> Dict[str, Any]:
    d, w = cfg.d_model, cfg.lru_width
    ks = jax.random.split(key, 6)
    # Λ init so that a ∈ [0.9, 0.999] at r=1 (Griffin appendix)
    u = np.random.default_rng(42).uniform(0.9, 0.999, size=(w,)) ** 2
    a_param = np.log(np.expm1(-np.log(u) / _C))  # inverse softplus
    return {
        "w_gate": dense_init(ks[0], d, w, dtype),
        "w_x": dense_init(ks[1], d, w, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, w), jnp.float32)
                   / np.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": dense_init(ks[3], w, w, dtype),
        "w_i": dense_init(ks[4], w, w, dtype),
        "a_param": jnp.asarray(a_param, jnp.float32),
        "w_out": dense_init(ks[5], w, d, dtype),
    }


def _rglru_scan(u: jnp.ndarray, r: jnp.ndarray, i: jnp.ndarray, a_param: jnp.ndarray,
                h0: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """u,r,i: (B,L,W) f32.  Returns (h (B,L,W), final state (B,W))."""
    log_a = -_C * r * jax.nn.softplus(a_param)[None, None]       # (B,L,W) <= 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u)

    if h0 is not None:
        # fold the entering state into the first step: h_1 = a_1 h0 + b_1
        gated = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_r * a_l, a_r * b_l + b_r

    a_acc, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h, h[:, -1]


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[-1]), x.dtype)
    padded = jnp.concatenate([state, x], axis=1)
    out = sum(padded[:, j : j + x.shape[1]] * w[j][None, None] for j in range(k))
    return out + b[None, None], padded[:, -(k - 1) :]


def rglru_block(
    p: Dict[str, Any], xin: jnp.ndarray, cfg, *,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    b, l, _ = xin.shape
    gate = jnp.einsum("bld,dw->blw", xin, p["w_gate"])
    u = jnp.einsum("bld,dw->blw", xin, p["w_x"])
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"],
                               cache["conv"] if cache is not None else None)

    u32 = u.astype(jnp.float32)
    r = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", u32, p["w_r"].astype(jnp.float32)))
    i = jax.nn.sigmoid(jnp.einsum("blw,wv->blv", u32, p["w_i"].astype(jnp.float32)))

    if l == 1 and cache is not None:
        h_prev = cache["state"].astype(jnp.float32)
        log_a = -_C * r[:, 0] * jax.nn.softplus(p["a_param"])[None]
        a = jnp.exp(log_a)
        h_new = a * h_prev + jnp.sqrt(jnp.maximum(1 - a * a, 1e-12)) * (i[:, 0] * u32[:, 0])
        h = h_new[:, None]
        final = h_new
    else:
        h0 = cache["state"].astype(jnp.float32) if cache is not None else None
        h, final = _rglru_scan(u32, r, i, p["a_param"], h0)

    out = jax.nn.gelu(gate.astype(jnp.float32)) * h
    out = jnp.einsum("blw,wd->bld", out.astype(xin.dtype), p["w_out"])

    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "state": final.astype(cache["state"].dtype)}
    return out, new_cache


def init_rglru_cache(cfg, batch: int, dtype) -> Dict[str, jnp.ndarray]:
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.lru_width), dtype),
        "state": jnp.zeros((batch, cfg.lru_width), jnp.float32),
    }
