"""Multi-device placement: named-axis shardings for params/opt/cache/data."""
from repro.distribution.sharding import (
    batch_spec,
    cache_shardings,
    data_shardings,
    dp_axes,
    opt_state_shardings,
    param_spec,
    params_shardings,
    replicated,
)

__all__ = [
    "batch_spec", "cache_shardings", "data_shardings", "dp_axes",
    "opt_state_shardings", "param_spec", "params_shardings", "replicated",
]
