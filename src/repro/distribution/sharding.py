"""Partition rules: param/activation/cache PartitionSpecs per architecture.

Scheme (baseline):
  * batch over ("pod", "data")  — pure DP across pods;
  * tensor parallel over "model" — column-parallel in-projections,
    row-parallel out-projections, vocab-sharded embed/head;
  * expert parallel over "model" — MoE expert stacks shard on E;
  * ZeRO-1: AdamW m/v additionally shard a replicated dim over "data"
    (needed to fit 34B-param training on 16 GB/chip, see DESIGN.md);
  * every rule checks divisibility and falls back to replication, so any
    (arch × mesh) combination lowers.

Long-context decode (batch 1) can't batch-shard: attention caches shard
their sequence dim over "data" instead (sequence-parallel KV).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# parameter name -> (sharded_dim_from_end, kind)
_COL = ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "w_x", "w_r", "w_i",
        "w_ukv", "vision_proj", "lm_head")
_ROW = ("wo", "w_down", "out_proj", "w_out")
_REPL = ("router", "conv_w", "conv_b", "A_log", "dt_bias", "D_skip", "a_param",
         "norm", "bias", "ckv_norm", "w_dkv", "bq", "bk", "bv")


def _divisible(dim: int, mesh: Mesh, axis: str) -> bool:
    return axis in mesh.shape and dim % mesh.shape[axis] == 0


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "name", getattr(p, "idx", p)))))
    return "/".join(parts)


def param_spec(name: str, shape: Tuple[int, ...], mesh: Mesh,
               replicate_keywords: Tuple[str, ...] = ()) -> P:
    """PartitionSpec for one parameter, identified by its tree path."""
    leaf = name.split("/")[-1]
    if leaf == "codes" and len(name.split("/")) >= 2:
        leaf = name.split("/")[-2]       # int8 codes shard like their weight
    elif leaf == "scale":
        return P()                       # per-channel scales are tiny
    if any(k in leaf for k in replicate_keywords):
        return P()
    stacked = name.startswith("units/")          # leading unit-scan dim
    nd = len(shape)
    base = [None] * nd
    off = 1 if stacked else 0
    in_experts = "/experts/" in name

    def set_if(idx: int, axis: str):
        if 0 <= idx < nd and _divisible(shape[idx], mesh, axis):
            base[idx] = axis

    if leaf == "tok":                             # embed (V, D): shard vocab
        set_if(off + 0, "model")
    elif in_experts:                              # (U, E, D, F): expert parallel
        set_if(off + 0, "model")
    elif any(k in leaf for k in _REPL):
        pass
    elif leaf in _COL or leaf == "lm_head":
        set_if(nd - 1, "model")                   # column parallel (output dim)
    elif leaf in _ROW:
        set_if(nd - 2, "model")                   # row parallel (input dim)
    elif nd >= 2:
        set_if(nd - 1, "model")
    return P(*base)


def opt_spec(pspec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """ZeRO-1: shard one replicated dim of m/v over 'data'."""
    base = list(pspec) + [None] * (len(shape) - len(pspec))
    for i, (s, d) in enumerate(zip(base, shape)):
        if s is None and _divisible(d, mesh, "data") and d >= mesh.shape["data"]:
            base[i] = "data"
            break
    return P(*base)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_spec(batch: int, mesh: Mesh) -> Any:
    """Spec for a batch dim: full DP if divisible, partial, or replicated."""
    axes = dp_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in axes]))
    if batch % total == 0:
        return axes if len(axes) > 1 else axes[0]
    if batch % mesh.shape["data"] == 0:
        return "data"
    return None


def tp_replicate_keywords(cfg, mesh: Mesh) -> Tuple[str, ...]:
    """Params to exclude from tensor parallelism for this (arch, mesh).

    Mamba-2's head count (e.g. 24) rarely divides the model axis; splitting
    d_inner mid-head makes GSPMD reshard every segment of the fused in_proj
    (measured: collective-dominant).  Such archs train DP-only on the
    mixer."""
    out: Tuple[str, ...] = ()
    if cfg is not None and "ssm" in cfg.layer_pattern:
        from repro.models.ssm import n_heads

        if n_heads(cfg) % mesh.shape.get("model", 1) != 0:
            out = out + ("in_proj", "out_proj")
    # GQA/MQA kv-replication: fewer kv heads than model shards would split
    # single heads across chips — GSPMD then reshards per attention op
    # (measured: per-layer collective-permute storms).  Replicating the
    # small kv projections is the standard TP practice.
    if (cfg is not None and not cfg.use_mla and 0 < cfg.num_kv_heads
            and cfg.num_kv_heads < mesh.shape.get("model", 1)):
        out = out + ("wk", "wv", "bk", "bv")
    return out


def fsdp_spec(pspec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """FSDP: additionally shard the largest replicated dim over 'data'."""
    base = list(pspec) + [None] * (len(shape) - len(pspec))
    cand = [i for i, (s, d) in enumerate(zip(base, shape))
            if s is None and _divisible(d, mesh, "data")]
    if cand:
        best = max(cand, key=lambda i: shape[i])
        base[best] = "data"
    return P(*base)


def params_shardings(params_shapes: Any, mesh: Mesh, cfg=None) -> Any:
    """Tree of NamedShardings matching a params (or grads) shape tree."""
    repl = tp_replicate_keywords(cfg, mesh)
    use_fsdp = bool(getattr(cfg, "fsdp", False))

    def one(path, leaf):
        spec = param_spec(_leaf_name(path), leaf.shape, mesh, repl)
        if use_fsdp and len(leaf.shape) >= 2:
            spec = fsdp_spec(spec, leaf.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def opt_state_shardings(opt_shapes: Any, params_shapes: Any, mesh: Mesh) -> Any:
    """AdamW state: step replicated; m/v like params + ZeRO-1 data shard."""
    def mv(path, leaf):
        ps = param_spec(_leaf_name(path), leaf.shape, mesh)
        return NamedSharding(mesh, opt_spec(ps, leaf.shape, mesh))

    m = jax.tree_util.tree_map_with_path(mv, opt_shapes.m)
    v = jax.tree_util.tree_map_with_path(mv, opt_shapes.v)
    step = NamedSharding(mesh, P())
    return type(opt_shapes)(step=step, m=m, v=v)


def cache_shardings(cache_shapes: Any, mesh: Mesh, batch: int) -> Any:
    """KV/SSM/LRU caches: batch-shard when possible, else sequence-shard."""
    bspec = batch_spec(batch, mesh)

    def one(path, leaf):
        name = _leaf_name(path)
        nd = len(leaf.shape)
        stacked = name.startswith("units/")
        off = 1 if stacked else 0
        base: list = [None] * nd
        if bspec is not None:
            base[off] = bspec
        leafname = name.split("/")[-1]
        if leafname in ("k", "v", "k_scale", "v_scale"):  # (U, B, cap, KH, hd|1)
            # sequence-parallel KV: the cache's seq dim shards over "model"
            # (decode scores stay local per shard; only softmax stats and the
            # small PV partials cross chips).  Heads stay whole.
            if _divisible(leaf.shape[off + 1], mesh, "model"):
                base[off + 1] = "model"
            elif _divisible(leaf.shape[off + 2], mesh, "model"):
                base[off + 2] = "model"
            if bspec is None and _divisible(leaf.shape[off + 1], mesh, "data") \
                    and base[off + 1] is None:
                base[off + 1] = "data"
        elif leafname in ("ckv", "k_rope"):        # (U, B, cap, r)
            if _divisible(leaf.shape[off + 1], mesh, "model"):
                base[off + 1] = "model"
            elif bspec is None and _divisible(leaf.shape[off + 1], mesh, "data"):
                base[off + 1] = "data"
        elif leafname == "state" and nd - off == 4:  # ssm (U,B,H,N,P)
            if _divisible(leaf.shape[off + 1], mesh, "model"):
                base[off + 1] = "model"
        elif leafname == "state" and nd - off == 2:  # rglru (U,B,W)
            if _divisible(leaf.shape[off + 1], mesh, "model"):
                base[off + 1] = "model"
        elif leafname == "conv":                   # (U,B,K-1,C)
            if _divisible(leaf.shape[off + 2], mesh, "model"):
                base[off + 2] = "model"
        elif leafname == "len":
            base = [None] * nd
            if bspec is not None:
                base[off] = bspec
        return NamedSharding(mesh, P(*base))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def data_shardings(batch_shapes: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    """tokens/labels (B,S), patch_embeds (B,P,D)."""
    out = {}
    for k, v in batch_shapes.items():
        bspec = batch_spec(v.shape[0], mesh)
        spec = [bspec] + [None] * (len(v.shape) - 1)
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())
