"""Client/server update + licensing protocol (paper §3.1, Fig. 2).

The paper's deployment plane is Django + Hasura/GraphQL over Postgres; we
model the same message flow in-process (DESIGN.md §2) and account for the
measurable quantity — bytes on the wire — exactly.

Message flow (paper §3.1.2):
  1. edge device sends (model, current_version, license) to the server;
  2. server answers with an UpdatePacket of weights created/updated since
     that version (skipping intermediate patches, §4.2), with the tier's
     license mask applied to the *shipped values* so unlicensed weights
     never leave the server (the paper's access-control-in-the-DB);
  3. device applies the sparse delta locally (Pallas ``delta_apply``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import delta as delta_lib
from repro.core.licensing import FULL_TIER, LicenseTier, mask_weight
from repro.core.pytree_io import flatten_params
from repro.core.weightstore import LayerDelta, UpdatePacket, WeightStore


@dataclass
class UpdateLog:
    model: str
    from_version: Optional[int]
    to_version: int
    tier: str
    bytes_sent: int
    entries: int


class LicenseServer:
    """Cloud side: wraps the WeightStore + Accuracy-table tiers."""

    def __init__(self, store: WeightStore):
        self.store = store
        self.log: List[UpdateLog] = []

    # -- publishing -------------------------------------------------------
    def publish(self, model: str, params: Any, **commit_kw) -> int:
        return self.store.commit(model, params, **commit_kw)

    def publish_tier(self, model: str, tier: LicenseTier) -> None:
        version = self.store.production_version(model)
        self.store.register_tier(
            model, version, tier.name, tier.accuracy or 0.0, tier.as_json()
        )

    def tier(self, model: str, name: str) -> LicenseTier:
        if name == "full":
            return FULL_TIER
        acc, masks = self.store.get_tier(model, name)
        return LicenseTier.from_json(name, masks, acc)

    def has_tier(self, model: str, name: str) -> bool:
        """Convenience predicate over :meth:`tier` (which raises KeyError)."""
        try:
            self.tier(model, name)
            return True
        except KeyError:
            return False

    # -- update requests ---------------------------------------------------
    def handle_update(
        self, model: str, client_version: Optional[int], license_name: str = "full"
    ) -> UpdatePacket:
        """§3.1.2: respond with only created/updated weights since the
        client's version, masked per the client's license tier."""
        tier = self.tier(model, license_name)
        packet = self.store.delta_since(model, client_version)
        packet = _mask_packet(packet, tier)
        self.log.append(UpdateLog(
            model=model, from_version=client_version, to_version=packet.to_version,
            tier=license_name, bytes_sent=packet.nbytes, entries=packet.num_entries,
        ))
        return packet


def _mask_packet(packet: UpdatePacket, tier: LicenseTier) -> UpdatePacket:
    """Apply license masks to the values being shipped (server-side access
    control: free-tier clients never receive masked weights)."""
    if not tier.masks:
        return packet
    import jax.numpy as jnp

    from repro.core.compression import is_dynamics_param

    out = UpdatePacket(model=packet.model, from_version=packet.from_version,
                       to_version=packet.to_version)
    for d in packet.deltas:
        ivs = tier.intervals_for(d.layer)
        if not ivs or is_dynamics_param(d.layer) or len(d.shape) < 2 or d.chunks is not None:
            if d.chunks is not None and ivs and not is_dynamics_param(d.layer) and len(d.shape) >= 2:
                # chunk mode: mask inside each page
                masked_chunks = []
                import zlib
                for payload in d.chunks:
                    try:
                        raw = zlib.decompress(payload)
                        compressed = True
                    except zlib.error:
                        raw, compressed = payload, False
                    page = np.frombuffer(raw, dtype=np.float32).copy()
                    page = np.asarray(mask_weight(jnp.asarray(page), ivs))
                    blob = page.tobytes()
                    masked_chunks.append(zlib.compress(blob, 1) if compressed else blob)
                out.deltas.append(LayerDelta(layer=d.layer, shape=d.shape, dtype=d.dtype,
                                             indices=d.indices, chunks=masked_chunks,
                                             chunk_elems=d.chunk_elems))
            else:
                out.deltas.append(d)
            continue
        vals = np.asarray(mask_weight(jnp.asarray(d.values), ivs))
        out.deltas.append(LayerDelta(layer=d.layer, shape=d.shape, dtype=d.dtype,
                                     indices=d.indices, values=vals))
    return out


class EdgeClient:
    """Edge-device side: holds local params + version, pulls delta updates."""

    def __init__(self, model: str, params_template: Any, license_name: str = "full"):
        self.model = model
        self.params = params_template
        self.version: Optional[int] = None
        self.license_name = license_name
        self.bytes_downloaded = 0
        self.updates = 0

    def request_update(self, server: LicenseServer) -> UpdatePacket:
        packet = server.handle_update(self.model, self.version, self.license_name)
        if packet.to_version != self.version:
            self.params = delta_lib.apply_packet(self.params, packet)
            self.version = packet.to_version
            self.bytes_downloaded += packet.nbytes
            self.updates += 1
        return packet
