"""Client/server update + licensing protocol (paper §3.1, Fig. 2).

The paper's deployment plane is Django + Hasura/GraphQL over Postgres; we
model the same message flow in-process (DESIGN.md §2) and account for the
measurable quantity — bytes on the wire — exactly.

Message flow (paper §3.1.2):
  1. edge device sends (model, current_version, license) to the server;
  2. server answers with an UpdatePacket of weights created/updated since
     that version (skipping intermediate patches, §4.2), with the tier's
     license mask applied to the *shipped values* so unlicensed weights
     never leave the server (the paper's access-control-in-the-DB);
  3. device applies the sparse delta locally (Pallas ``delta_apply``).

Chunk-granular fetch (staged weight sync): :meth:`LicenseServer.open_update`
answers the same query as ``handle_update`` but returns an
:class:`UpdateCursor` instead of the whole packet; the client then pulls
bounded *parts* (``fetch_update(cursor, max_bytes)``) — row-range or
chunk-page slices of the masked deltas — so an edge pod can interleave the
transfer and apply with its serving loop instead of stalling on the full
payload.  Bytes on the wire are identical either way and are logged once
when the cursor drains.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, List, Optional, Tuple

import numpy as np

from repro.core import delta as delta_lib
from repro.core.licensing import FULL_TIER, LicenseTier
from repro.core.weightstore import LayerDelta, UpdatePacket, WeightStore


@dataclass
class UpdateLog:
    model: str
    from_version: Optional[int]
    to_version: int
    tier: str
    bytes_sent: int
    entries: int


@dataclass
class UpdateCursor:
    """One incremental update session: the raw packet plus a read position.

    Produced by :meth:`LicenseServer.open_update`; consumed part-by-part
    through :meth:`LicenseServer.fetch_update`.  A *part* is a
    ``LayerDelta`` covering a slice of one layer's delta — a run of
    (index, value) rows or a run of whole chunk pages — so applying every
    fetched part in order reproduces ``handle_update``'s packet exactly.
    ``deltas`` are UNMASKED: license masking is applied per part at fetch
    time, so opening a session never pays the whole-packet masking pass
    (the point of the chunk-granular protocol is bounded per-step work).
    """

    model: str
    from_version: Optional[int]
    to_version: int
    tier: str
    deltas: List[LayerDelta] = field(default_factory=list)
    tier_obj: Any = field(default=None, repr=False)
    _delta_i: int = 0            # next delta to slice from
    _entry_off: int = 0          # entries already taken from deltas[_delta_i]
    fetched_bytes: int = 0
    fetched_parts: int = 0
    _log: Any = field(default=None, repr=False)   # live UpdateLog entry

    @property
    def done(self) -> bool:
        return self._delta_i >= len(self.deltas)

    def tell(self) -> Tuple[int, int]:
        """The durable read position: (next delta index, entries already
        taken from it).  A client snapshots this before a fetch so a
        lost response (mid-stream disconnect after the server advanced
        the cursor) can resume the session at its last *applied* entry
        instead of tearing the whole sync down."""
        return (self._delta_i, self._entry_off)

    def seek(self, pos: Tuple[int, int]) -> None:
        """Reposition to a :meth:`tell` snapshot — the row-range resume:
        the next ``_take`` slices from exactly that (delta, entry)."""
        i, off = int(pos[0]), int(pos[1])
        if not 0 <= i <= len(self.deltas):
            raise ValueError(f"resume delta index {i} outside "
                             f"[0, {len(self.deltas)}]")
        if i == len(self.deltas):
            if off != 0:
                raise ValueError(f"resume offset {off} past the last delta")
        elif not 0 <= off < max(1, len(self.deltas[i].indices)):
            raise ValueError(f"resume offset {off} outside delta {i} "
                             f"({len(self.deltas[i].indices)} entries)")
        self._delta_i = i
        self._entry_off = off

    @property
    def total_bytes(self) -> int:
        """Pre-mask payload size (masking preserves rows-mode sizes
        exactly; a masked-then-recompressed chunk page can differ by a
        few bytes)."""
        return int(sum(d.nbytes for d in self.deltas))

    def _take(self, budget: int) -> LayerDelta:
        """Slice the next part off the cursor: at least one row/page, at
        most ``budget`` bytes (a single page may overshoot — the page is
        the smallest unit of transfer in chunk mode)."""
        d = self.deltas[self._delta_i]
        j = self._entry_off
        if d.chunks is not None:
            flags = d.chunk_flags()
            k, got = j, 0
            while k < len(d.chunks) and (k == j or
                                         got + len(d.chunks[k]) + 8 <= budget):
                got += len(d.chunks[k]) + 8
                k += 1
            part = LayerDelta(layer=d.layer, shape=d.shape, dtype=d.dtype,
                              indices=d.indices[j:k], chunks=d.chunks[j:k],
                              chunk_elems=d.chunk_elems,
                              chunk_compressed=flags[j:k])
        else:
            per = d.indices.itemsize + d.values.itemsize
            k = j + max(1, min(budget // per, len(d.indices) - j))
            part = LayerDelta(layer=d.layer, shape=d.shape, dtype=d.dtype,
                              indices=d.indices[j:k], values=d.values[j:k])
        self._entry_off = k
        if k >= len(d.indices):
            self._delta_i += 1
            self._entry_off = 0
        return part


class LicenseServer:
    """Cloud side: wraps the WeightStore + Accuracy-table tiers."""

    def __init__(self, store: WeightStore):
        self.store = store
        self.log: List[UpdateLog] = []

    # -- publishing -------------------------------------------------------
    def publish(self, model: str, params: Any, **commit_kw) -> int:
        return self.store.commit(model, params, **commit_kw)

    def publish_tier(self, model: str, tier: LicenseTier) -> None:
        version = self.store.production_version(model)
        self.store.register_tier(
            model, version, tier.name, tier.accuracy or 0.0, tier.as_json()
        )

    def tier(self, model: str, name: str) -> LicenseTier:
        if name == "full":
            return FULL_TIER
        acc, masks = self.store.get_tier(model, name)
        return LicenseTier.from_json(name, masks, acc)

    def has_tier(self, model: str, name: str) -> bool:
        """Convenience predicate over :meth:`tier` (which raises KeyError)."""
        try:
            self.tier(model, name)
            return True
        except KeyError:
            return False

    # -- update requests ---------------------------------------------------
    def handle_update(
        self, model: str, client_version: Optional[int], license_name: str = "full"
    ) -> UpdatePacket:
        """§3.1.2: respond with only created/updated weights since the
        client's version, masked per the client's license tier."""
        tier = self.tier(model, license_name)
        packet = self.store.delta_since(model, client_version)
        packet = _mask_packet(packet, tier)
        self.log.append(UpdateLog(
            model=model, from_version=client_version, to_version=packet.to_version,
            tier=license_name, bytes_sent=packet.nbytes, entries=packet.num_entries,
        ))
        return packet

    def production_version(self, model: str) -> Optional[int]:
        """Cheap poll: the current production version id (None if unset) —
        lets an edge pod decide whether to open an update at all without
        paying the delta query."""
        return self.store.production_version(model, missing_ok=True)

    def open_update(
        self, model: str, client_version: Optional[int],
        license_name: str = "full",
        resume: Optional[Tuple[int, int]] = None,
    ) -> UpdateCursor:
        """Chunk-granular variant of :meth:`handle_update`: same query, same
        masking, but the payload stays server-side and the client pulls
        bounded parts via :meth:`fetch_update` — which is also where the
        license masking runs, one part at a time, so neither endpoint ever
        pays a whole-packet pass.  The session is logged immediately (an
        abandoned sync must still appear in the audit trail); its live
        entry accumulates bytes/entries as parts are fetched.

        ``resume`` is a :meth:`UpdateCursor.tell` snapshot from a
        previous session against the same ``(model, client_version)``:
        a client whose connection died mid-stream reopens here and the
        fresh cursor is seeked past everything it already durably
        applied — the delta query is deterministic, so the row ranges
        line up and the re-fetched entries are identical."""
        tier = self.tier(model, license_name)
        packet = self.store.delta_since(model, client_version)
        entry = UpdateLog(model=model, from_version=client_version,
                          to_version=packet.to_version, tier=license_name,
                          bytes_sent=0, entries=0)
        self.log.append(entry)
        cursor = UpdateCursor(model=model, from_version=client_version,
                              to_version=packet.to_version, tier=license_name,
                              deltas=packet.deltas, tier_obj=tier, _log=entry)
        if resume is not None:
            cursor.seek(resume)
        return cursor

    def fetch_update(self, cursor: UpdateCursor,
                     max_bytes: int = 1 << 20) -> List[LayerDelta]:
        """Pull the next parts off an open cursor: at least one part, at
        most ~``max_bytes`` on the wire (one chunk page may overshoot —
        pages are indivisible), masked per the session's tier as they are
        sliced.  Returns ``[]`` once the cursor is drained; the session's
        log entry ends up with the same bytes/entries a ``handle_update``
        of the whole packet would record."""
        parts: List[LayerDelta] = []
        got = 0
        while not cursor.done and (not parts or got < max_bytes):
            raw = cursor._take(max_bytes - got)
            part = _mask_packet(
                UpdatePacket(model=cursor.model,
                             from_version=cursor.from_version,
                             to_version=cursor.to_version, deltas=[raw]),
                cursor.tier_obj).deltas[0]
            parts.append(part)
            got += part.nbytes
            cursor._log.entries += len(part.indices)
        cursor.fetched_bytes += got
        cursor.fetched_parts += len(parts)
        cursor._log.bytes_sent = cursor.fetched_bytes
        return parts


def _mask_page(page: np.ndarray, ivs) -> np.ndarray:
    """Interval-mask one decoded chunk page in its own dtype.

    Pure-numpy twin of ``licensing.mask_weight``: kept entries pass
    through bit-identically (no float round trip through another
    precision), zeroed entries match the jnp semantics exactly."""
    mag = np.abs(page.astype(np.float32, copy=False))
    dead = np.zeros(page.shape, bool)
    for lo, hi in ivs:
        dead |= (mag >= lo) & (mag < hi)
    return np.where(dead, np.zeros((), page.dtype), page)


def _mask_packet(packet: UpdatePacket, tier: LicenseTier) -> UpdatePacket:
    """Apply license masks to the values being shipped (server-side access
    control: free-tier clients never receive masked weights)."""
    if not tier.masks:
        return packet
    from repro.core.compression import is_dynamics_param

    out = UpdatePacket(model=packet.model, from_version=packet.from_version,
                       to_version=packet.to_version)
    for d in packet.deltas:
        ivs = tier.intervals_for(d.layer)
        if not ivs or is_dynamics_param(d.layer) or len(d.shape) < 2 or d.chunks is not None:
            if d.chunks is not None and ivs and not is_dynamics_param(d.layer) and len(d.shape) >= 2:
                # chunk mode: mask inside each page, decoding with the
                # delta's dtype and trusting its explicit compression
                # flags — sniffing zlib by trial-decompress mangles raw
                # pages that happen to parse, and decoding non-f32 pages
                # as f32 corrupts every masked value
                import zlib
                masked_chunks = []
                flags = d.chunk_flags()
                for (_, page), compressed in zip(d.iter_pages(), flags):
                    blob = _mask_page(page, ivs).tobytes()
                    masked_chunks.append(zlib.compress(blob, 1)
                                         if compressed else blob)
                out.deltas.append(LayerDelta(layer=d.layer, shape=d.shape, dtype=d.dtype,
                                             indices=d.indices, chunks=masked_chunks,
                                             chunk_elems=d.chunk_elems,
                                             chunk_compressed=flags))
            else:
                out.deltas.append(d)
            continue
        # dtype-preserving (kept values pass through bit-identically; a
        # jnp round trip would downcast f64 rows to f32 with x64 off)
        vals = _mask_page(np.asarray(d.values), ivs)
        out.deltas.append(LayerDelta(layer=d.layer, shape=d.shape, dtype=d.dtype,
                                     indices=d.indices, values=vals))
    return out


class EdgeClient:
    """Edge-device side: holds local params + version, pulls delta updates."""

    def __init__(self, model: str, params_template: Any, license_name: str = "full"):
        self.model = model
        self.params = params_template
        self.version: Optional[int] = None
        self.license_name = license_name
        self.bytes_downloaded = 0
        self.updates = 0

    def request_update(self, server, retry=None) -> UpdatePacket:
        """Pull one whole-packet update.  ``server`` may be a raw
        :class:`LicenseServer` or any ``core.transport.Transport`` over
        one; ``retry`` is an optional ``RetryPolicy`` — with it, a
        timed-out or corrupted delivery is re-requested (the query is a
        pure read, so re-issuing is idempotent) instead of raised."""
        from repro.core.transport import as_transport

        transport = as_transport(server)

        def _pull() -> UpdatePacket:
            return transport.handle_update(self.model, self.version,
                                           self.license_name)

        packet = _pull() if retry is None else retry.run(_pull)
        if packet.to_version != self.version:
            self.params = delta_lib.apply_packet(self.params, packet)
            self.version = packet.to_version
            self.bytes_downloaded += packet.nbytes
            self.updates += 1
        return packet
