"""Dynamic and static model licensing (paper §3.5, Algorithm 1).

A *license tier* is a set of per-layer magnitude intervals; weights whose
|w| falls inside a masked interval are zeroed at serve time.  One stored
weight set thus serves unlimited accuracy tiers ("dynamic licensing").

* ``apply_license`` — pure-JAX mask transform (jit-able, shard-preserving).
* ``calibrate_license`` — Algorithm 1 verbatim: divide the weight range into
  k equal intervals, cumulatively cut intervals layer-by-layer until the
  evaluated accuracy reaches the target.
* ``make_static_tiers`` — precompute a ladder of tiers for the Accuracy
  table (static licensing = lookup; dynamic licensing = on-demand calibrate).

Adaptation (DESIGN.md §4): dynamics params (SSM A_log / dt_bias / RG-LRU
gates, norm scales) are excluded from masking — interval-pruning those can
destabilize the recurrence rather than merely degrade accuracy.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.compression import is_dynamics_param
from repro.core.pytree_io import flatten_params, unflatten_like

Interval = Tuple[float, float]


@dataclass(frozen=True)
class LicenseTier:
    """A named accuracy tier: per-layer-pattern magnitude-interval masks.

    ``masks`` maps a substring pattern (matched against the canonical layer
    path) to intervals [lo, hi); weights with lo <= |w| < hi are zeroed.
    Pattern "*" applies to every maskable layer.
    """

    name: str
    masks: Dict[str, Tuple[Interval, ...]] = field(default_factory=dict)
    accuracy: Optional[float] = None

    def intervals_for(self, layer_name: str) -> List[Interval]:
        out: List[Interval] = []
        for pattern, ivs in self.masks.items():
            if pattern == "*" or pattern in layer_name:
                out.extend(ivs)
        return out

    def as_json(self) -> Dict[str, list]:
        return {k: [list(iv) for iv in v] for k, v in self.masks.items()}

    def fingerprint(self) -> str:
        """Stable short hash of (name, masks) — the audit stream's proof
        of *which* mask definition a tier name meant at event time, so a
        redefined tier is distinguishable from its earlier self."""
        import hashlib
        import json as _json

        payload = _json.dumps({"name": self.name, "masks": self.as_json()},
                              sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:12]

    @staticmethod
    def from_json(name: str, masks: Dict[str, Sequence[Sequence[float]]],
                  accuracy: Optional[float] = None) -> "LicenseTier":
        return LicenseTier(
            name=name,
            masks={k: tuple((float(a), float(b)) for a, b in v) for k, v in masks.items()},
            accuracy=accuracy,
        )


FULL_TIER = LicenseTier(name="full", masks={})


def interval_mask(w: jnp.ndarray, intervals: Sequence[Interval]) -> jnp.ndarray:
    """Boolean mask: True where the weight SURVIVES (|w| outside all intervals)."""
    if not intervals:
        return jnp.ones(w.shape, dtype=bool)
    mag = jnp.abs(w)
    dead = jnp.zeros(w.shape, dtype=bool)
    for lo, hi in intervals:
        dead = dead | ((mag >= lo) & (mag < hi))
    return ~dead


def mask_weight(w: jnp.ndarray, intervals: Sequence[Interval]) -> jnp.ndarray:
    return jnp.where(interval_mask(w, intervals), w, jnp.zeros_like(w))


def apply_license(
    params: Any,
    tier: LicenseTier,
    *,
    exclude: Callable[[str], bool] = is_dynamics_param,
) -> Any:
    """Return params with the tier's interval masks applied (pure function).

    Shard-preserving: masking is elementwise, so output shardings match
    inputs under jit; this runs inside the licensed ``serve_step``.
    """
    if not tier.masks:
        return params
    flat = flatten_params(params)
    out = {}
    for name, arr in flat.items():
        ivs = tier.intervals_for(name)
        if not ivs or exclude(name) or np.ndim(arr) < 2:
            out[name] = arr
        else:
            out[name] = mask_weight(jnp.asarray(arr), ivs)
    return unflatten_like(params, out)


def license_stats(params: Any, tier: LicenseTier,
                  exclude: Callable[[str], bool] = is_dynamics_param) -> Dict[str, float]:
    """Fraction of weights hidden by the tier (reported per benchmark run)."""
    flat = flatten_params(params)
    total = masked = 0
    for name, arr in flat.items():
        ivs = tier.intervals_for(name)
        total += arr.size
        if ivs and not exclude(name) and arr.ndim >= 2:
            surv = np.asarray(interval_mask(jnp.asarray(arr), ivs))
            masked += int(arr.size - surv.sum())
    return {"total": float(total), "masked": float(masked),
            "masked_frac": masked / max(total, 1)}


# ----------------------------------------------------------- Algorithm 1
@dataclass
class CalibrationStep:
    interval: Interval
    layer: str
    accuracy: float


def calibrate_license(
    params: Any,
    eval_fn: Callable[[Any], float],
    target_accuracy: float,
    *,
    k_intervals: int = 10,
    tier_name: str = "custom",
    tolerance: float = 0.02,
    layer_order: Optional[List[str]] = None,
    exclude: Callable[[str], bool] = is_dynamics_param,
    interval_mode: str = "quantile",
    refine_steps: int = 0,
) -> Tuple[LicenseTier, List[CalibrationStep]]:
    """Algorithm 1 — prune the model based on desired accuracy.

    divide weight range into k equal intervals; for each interval, for each
    layer, cut weights in that interval; stop when accuracy of the pruned
    model is close to the target.  Returns the tier holding the CUT
    intervals per layer (the paper returns the *uncut* list; storing the cut
    list is equivalent and is what the Accuracy-table mask needs).

    ``interval_mode``: the paper's "equal-sized intervals" is ambiguous —
    "quantile" (default) makes intervals equal in POPULATION, giving smooth
    accuracy control (weights concentrate near 0, so equal-WIDTH intervals
    cut most of the model in the first step); "width" is the literal
    equal-width reading.

    ``refine_steps`` (beyond paper): Algorithm 1 is interval-quantized, so
    the final cut can overshoot the target by a whole interval's worth of
    accuracy.  With refine_steps > 0 the last interval's upper edge is
    bisected that many times, landing the achieved accuracy as close to
    the target as the model's accuracy curve allows.
    """
    flat = flatten_params(params)
    maskable = [n for n, a in flat.items() if not exclude(n) and a.ndim >= 2]
    if layer_order is not None:
        maskable = [n for n in layer_order if n in maskable]

    mags = np.concatenate([np.abs(np.asarray(flat[n])).reshape(-1) for n in maskable])
    hi = float(mags.max())
    if interval_mode == "quantile":
        qs = np.linspace(0.0, 1.0, k_intervals + 1)
        edges = np.quantile(mags, qs)
        edges[0], edges[-1] = 0.0, hi * (1 + 1e-6)
        edges = np.maximum.accumulate(edges)
    else:
        edges = np.linspace(0.0, hi * (1 + 1e-6), k_intervals + 1)

    cut: Dict[str, List[Interval]] = {n: [] for n in maskable}
    trace: List[CalibrationStep] = []
    current = dict(flat)

    # Ascending magnitude: cut least-important (smallest) intervals first,
    # mirroring gradual magnitude pruning (§3.5).
    done = False
    last_layer = None
    for i in range(k_intervals):
        iv = (float(edges[i]), float(edges[i + 1]))
        for layer in maskable:
            cut[layer].append(iv)
            current[layer] = np.asarray(mask_weight(jnp.asarray(current[layer]), [iv]))
            acc = float(eval_fn(unflatten_like(params, current)))
            trace.append(CalibrationStep(interval=iv, layer=layer, accuracy=acc))
            if acc <= target_accuracy + tolerance:
                done = True
                last_layer = layer
                break
        if done:
            break

    if done and refine_steps and trace and last_layer is not None:
        # bisect the final interval's upper edge on its layer
        lo_edge, hi_edge = cut[last_layer][-1]
        base = dict(current)
        base[last_layer] = np.asarray(flat[last_layer])
        # replay all cuts on this layer except the final one
        for iv in cut[last_layer][:-1]:
            base[last_layer] = np.asarray(
                mask_weight(jnp.asarray(base[last_layer]), [iv]))
        best_hi, lo, hi = hi_edge, lo_edge, hi_edge
        for _ in range(refine_steps):
            mid = 0.5 * (lo + hi)
            trial = np.asarray(mask_weight(jnp.asarray(base[last_layer]),
                                           [(lo_edge, mid)]))
            cand = dict(base)
            cand[last_layer] = trial
            acc = float(eval_fn(unflatten_like(params, cand)))
            trace.append(CalibrationStep(interval=(lo_edge, mid),
                                         layer=last_layer, accuracy=acc))
            if acc <= target_accuracy:
                best_hi, hi = mid, mid   # overshoot: shrink the cut
            else:
                lo = mid                 # undershoot: widen toward hi_edge
                best_hi = hi
        cut[last_layer][-1] = (lo_edge, float(best_hi))

    tier = LicenseTier(
        name=tier_name,
        masks={n: tuple(v) for n, v in cut.items() if v},
        accuracy=None,
    )
    if trace:
        # re-evaluate the final tier exactly
        final = apply_license(params, tier, exclude=exclude)
        tier = LicenseTier(name=tier.name, masks=tier.masks,
                           accuracy=float(eval_fn(final)))
    return tier, trace


def make_static_tiers(
    params: Any,
    eval_fn: Callable[[Any], float],
    tier_targets: Dict[str, float],
    *,
    k_intervals: int = 10,
) -> Dict[str, LicenseTier]:
    """Precompute the Accuracy-table ladder (static licensing, §3.5)."""
    tiers: Dict[str, LicenseTier] = {}
    for name, target in sorted(tier_targets.items(), key=lambda kv: -kv[1]):
        tier, _ = calibrate_license(
            params, eval_fn, target, k_intervals=k_intervals, tier_name=name
        )
        tiers[name] = tier
    return tiers
