"""Fault-tolerant wire seam between edge serving and the LicenseServer.

The §3.1.2 update protocol was written against a perfect in-process
network: every ``EdgeClient``/``UpdateStager``/gateway call reached the
:class:`~repro.core.protocol.LicenseServer` directly, any exception tore
the whole staged sync down, and "server unreachable" had no defined
behavior at all.  Edge deployments live with exactly the intermittent
connectivity the paper's setting implies, so every wire call now goes
through a :class:`Transport`:

* :class:`DirectTransport` — today's behavior: an in-process method
  call that never faults.  Server methods are looked up per call, so
  tests that monkeypatch e.g. ``server.fetch_update`` keep working.
* :class:`ChaosTransport` — deterministic, seed-scheduled fault
  injection: timeouts, mid-stream disconnects, latency spikes,
  duplicate deliveries, and payload corruption.  Only the *wire* is
  perturbed — server state is never damaged, and a corrupted payload
  never survives past the checksum check — so a fault schedule can
  change timing, retry counters, and lease state, never tokens.

Payload integrity rides the same seam: :func:`part_checksum` digests
one ``LayerDelta`` part's wire payload, the transport computes digests
at *send* and :func:`verify_parts` re-digests on *receipt*, so a
corrupted page raises :class:`PayloadCorruption` instead of being
applied.  :class:`RetryPolicy` (exponential backoff + deterministic
jitter + deadline, injectable clock/sleep) is the one retry loop every
wire caller shares.
"""
from __future__ import annotations

import copy
import random
import threading
import time
import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import numpy as np

__all__ = [
    "TransportError", "TransportTimeout", "TransportDisconnect",
    "PayloadCorruption", "part_checksum", "packet_checksum", "verify_parts",
    "RetryPolicy", "Transport", "DirectTransport", "ChaosTransport",
    "as_transport",
]


# ------------------------------------------------------------------ failures
class TransportError(RuntimeError):
    """Base class for transient wire failures — every subclass is safe
    to retry: either the request never reached the server (timeout) or
    re-issuing it is idempotent at the protocol level (the update query
    is a pure read; delta application is idempotent per entry)."""


class TransportTimeout(TransportError):
    """The request was lost *before* the server processed it: no
    server-side state advanced, the caller simply never got an answer."""


class TransportDisconnect(TransportError):
    """The connection died mid-stream: the server *did* process the call
    (an open cursor advanced past the lost parts) but the response never
    arrived.  The caller must resume from its last durable position, not
    merely re-issue the same fetch."""


class PayloadCorruption(TransportError):
    """A delivered payload failed its checksum — the bytes on the wire
    do not match what the server sent.  The payload must be discarded
    and re-fetched, never applied."""


# ----------------------------------------------------------------- checksums
def part_checksum(part: Any) -> int:
    """CRC32 of one ``LayerDelta`` part's wire payload (layer name,
    indices, and values/pages).  Computed at send and re-computed at
    receipt; a mismatch means the wire flipped bits."""
    crc = zlib.crc32(part.layer.encode())
    crc = zlib.crc32(np.ascontiguousarray(part.indices).tobytes(), crc)
    if part.chunks is not None:
        for blob in part.chunks:
            crc = zlib.crc32(blob, crc)
    else:
        crc = zlib.crc32(np.ascontiguousarray(part.values).tobytes(), crc)
    return crc & 0xFFFFFFFF


def packet_checksum(packet: Any) -> int:
    """Whole-``UpdatePacket`` digest: the per-part digests chained in
    order (order matters — parts apply sequentially)."""
    crc = 0
    for d in packet.deltas:
        crc = zlib.crc32(part_checksum(d).to_bytes(4, "little"), crc)
    return crc & 0xFFFFFFFF


def verify_parts(parts: Iterable[Any], digests: Iterable[int]) -> None:
    """Receive-side integrity check: re-digest each delivered part
    against the digest computed at send."""
    for i, (part, digest) in enumerate(zip(parts, digests)):
        got = part_checksum(part)
        if got != digest:
            raise PayloadCorruption(
                f"part {i} ({part.layer!r}): checksum {got:#010x} != "
                f"sent {digest:#010x}")


# --------------------------------------------------------------------- retry
@dataclass
class RetryPolicy:
    """Exponential backoff with deterministic jitter and a deadline.

    One policy instance wraps every wire call of a caller (stager,
    client, gateway): ``run(fn)`` re-invokes ``fn`` on
    :class:`TransportError` until it succeeds, ``max_attempts`` are
    spent, or the next backoff would cross ``deadline_s``.  ``clock``
    and ``sleep`` are injectable so tests and benchmarks run the policy
    without real waiting; jitter derives from ``(seed, attempt)``, never
    from a global RNG, so a retry schedule is reproducible.
    """

    max_attempts: int = 5
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    multiplier: float = 2.0
    jitter: float = 0.1          # +/- fraction of the backoff
    deadline_s: Optional[float] = None
    seed: int = 0
    clock: Callable[[], float] = time.monotonic
    sleep: Callable[[float], None] = time.sleep

    def delay(self, attempt: int) -> float:
        """Backoff before retry ``attempt`` (1-based), jittered
        deterministically into ``[d*(1-jitter), d*(1+jitter)]``."""
        d = min(self.max_delay_s,
                self.base_delay_s * self.multiplier ** (attempt - 1))
        if self.jitter:
            u = zlib.crc32(f"{self.seed}:{attempt}".encode()) / 0xFFFFFFFF
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return max(0.0, d)

    def run(self, fn: Callable[[], Any], *,
            retryable: Tuple[type, ...] = (TransportError,),
            on_retry: Optional[Callable[[int, BaseException, float],
                                        None]] = None) -> Any:
        """Call ``fn`` until success or the budget is spent; the final
        failure re-raises.  ``on_retry(attempt, exc, delay)`` fires
        before each backoff — the hook where callers count retries and
        emit ``sync_retry`` audit events."""
        start = self.clock()
        attempt = 0
        while True:
            try:
                return fn()
            except retryable as exc:
                attempt += 1
                if attempt >= self.max_attempts:
                    raise
                delay = self.delay(attempt)
                if (self.deadline_s is not None
                        and self.clock() - start + delay > self.deadline_s):
                    raise
                if on_retry is not None:
                    on_retry(attempt, exc, delay)
                if delay > 0.0:
                    self.sleep(delay)


# ----------------------------------------------------------------- transports
class Transport:
    """The wire seam: one instance fronts one ``LicenseServer``.

    Methods mirror the server's wire surface (``production_version``,
    ``open_update``, ``fetch_update``, ``handle_update``, ``tier``);
    subclasses perturb delivery by overriding :meth:`_call`.  Payload
    digests are computed at send inside the thunk and verified on
    receipt here, so every fetched part / pulled packet passes an
    integrity check regardless of transport."""

    def __init__(self, server: Any):
        self.server = server
        self.stats: Dict[str, int] = {
            "calls": 0, "faults": 0, "timeouts": 0, "disconnects": 0,
            "corruptions": 0, "duplicates": 0, "latency_spikes": 0,
        }

    # subclass seam: deliver one call (may fault, delay, or duplicate)
    def _call(self, op: str, thunk: Callable[[], Any]) -> Any:
        self.stats["calls"] += 1
        return thunk()

    # ---------------------------------------------------------- wire surface
    def production_version(self, model: str) -> Optional[int]:
        return self._call("production_version",
                          lambda: self.server.production_version(model))

    def open_update(self, model: str, client_version: Optional[int],
                    license_name: str = "full",
                    resume: Optional[Tuple[int, int]] = None) -> Any:
        if resume is None:      # plain call: monkeypatched servers keep working
            return self._call("open_update", lambda: self.server.open_update(
                model, client_version, license_name))
        return self._call("open_update", lambda: self.server.open_update(
            model, client_version, license_name, resume=resume))

    def fetch_update(self, cursor: Any, max_bytes: int = 1 << 20) -> List[Any]:
        def thunk():
            parts = self.server.fetch_update(cursor, max_bytes)
            return parts, [part_checksum(p) for p in parts]

        parts, digests = self._call("fetch_update", thunk)
        verify_parts(parts, digests)
        return parts

    def handle_update(self, model: str, client_version: Optional[int],
                      license_name: str = "full") -> Any:
        def thunk():
            packet = self.server.handle_update(model, client_version,
                                               license_name)
            return packet, packet_checksum(packet)

        packet, digest = self._call("handle_update", thunk)
        if packet_checksum(packet) != digest:
            raise PayloadCorruption(
                f"update packet {model}@{packet.to_version}: checksum "
                f"mismatch")
        return packet

    def tier(self, model: str, name: str) -> Any:
        return self._call("tier", lambda: self.server.tier(model, name))


class DirectTransport(Transport):
    """In-process delivery, never faults — the pre-transport behavior."""


def as_transport(server_or_transport: Any) -> Transport:
    """Accept either a raw ``LicenseServer`` or an already-built
    transport, so every wire API keeps taking plain servers."""
    if isinstance(server_or_transport, Transport):
        return server_or_transport
    return DirectTransport(server_or_transport)


def _corrupt_part(part: Any) -> Any:
    """A copy of ``part`` with one payload byte flipped (the wire's
    damage) — the original, and server state behind it, are untouched."""
    from repro.core.weightstore import LayerDelta

    if part.chunks is not None and part.chunks:
        chunks = list(part.chunks)
        blob = bytearray(chunks[0])
        if blob:
            blob[len(blob) // 2] ^= 0xFF
        chunks[0] = bytes(blob)
        return LayerDelta(layer=part.layer, shape=part.shape,
                          dtype=part.dtype, indices=part.indices,
                          chunks=chunks, chunk_elems=part.chunk_elems,
                          chunk_compressed=part.chunk_flags())
    vals = np.ascontiguousarray(np.asarray(part.values)).copy()
    raw = vals.view(np.uint8).reshape(-1)
    if raw.size:
        raw[raw.size // 2] ^= 0xFF
    return LayerDelta(layer=part.layer, shape=part.shape, dtype=part.dtype,
                      indices=part.indices, values=vals)


class ChaosTransport(Transport):
    """Deterministic, seed-scheduled fault injection at the wire seam.

    Every delivery decision is drawn from ``random.Random(f"{seed}:{op}:{n}")``
    where ``n`` is that op's call index — the schedule depends only on
    the seed and each op's own call sequence, never on thread
    interleaving or wall time, so a chaos run is reproducible (the
    background-fetch worker and the serving thread can share one
    instance).

    Per call, in order: a latency spike (``spike_rate`` /
    ``latency_spike_s``, via the injectable ``sleep``), then one of the
    weighted faults at ``fault_rate``:

    * ``timeout``    — request lost before the server sees it (no
      server-side effect) → :class:`TransportTimeout`;
    * ``disconnect`` — the server processes the call (a cursor
      advances!) but the response is lost → :class:`TransportDisconnect`;
    * ``corrupt``    — the payload arrives with a flipped byte; the
      send-side digest catches it → :class:`PayloadCorruption`
      (fetch/handle ops only — versionless ops degrade to timeout).

    Independently, ``dup_rate`` re-delivers the previous successful
    fetch batch verbatim (network duplicate): the cursor does not
    advance and the client re-applies an already-applied batch — which
    must be (and is) idempotent.
    """

    _PAYLOAD_OPS = ("fetch_update", "handle_update")

    def __init__(self, server: Any, *, seed: int = 0, fault_rate: float = 0.2,
                 timeout_weight: float = 1.0, disconnect_weight: float = 1.0,
                 corrupt_weight: float = 1.0, dup_rate: float = 0.0,
                 spike_rate: float = 0.0, latency_spike_s: float = 0.0,
                 sleep: Callable[[float], None] = time.sleep,
                 fault_ops: Optional[Iterable[str]] = None):
        super().__init__(server)
        self.seed = int(seed)
        self.fault_rate = float(fault_rate)
        self.weights = {"timeout": float(timeout_weight),
                        "disconnect": float(disconnect_weight),
                        "corrupt": float(corrupt_weight)}
        self.dup_rate = float(dup_rate)
        self.spike_rate = float(spike_rate)
        self.latency_spike_s = float(latency_spike_s)
        self.sleep = sleep
        self.fault_ops = None if fault_ops is None else frozenset(fault_ops)
        self._lock = threading.Lock()
        self._counts: Dict[str, int] = {}                   # guarded-by: _lock
        self._last_fetch: Optional[Tuple[List[Any], List[int]]] = None  # guarded-by: _lock

    def _decide(self, op: str):
        with self._lock:
            n = self._counts.get(op, 0)
            self._counts[op] = n + 1
        rng = random.Random(f"{self.seed}:{op}:{n}")
        spike = rng.random() < self.spike_rate
        dup = op == "fetch_update" and rng.random() < self.dup_rate
        fault = None
        if rng.random() < self.fault_rate:
            weights = dict(self.weights)
            if op not in self._PAYLOAD_OPS:
                # nothing to corrupt on a versionless/tier call
                weights["timeout"] += weights.pop("corrupt")
            kinds = [k for k, w in weights.items() if w > 0]
            fault = rng.choices(kinds, [weights[k] for k in kinds])[0]
        return rng, spike, dup, fault

    def _call(self, op: str, thunk: Callable[[], Any]) -> Any:
        self.stats["calls"] += 1
        if self.fault_ops is not None and op not in self.fault_ops:
            return thunk()
        rng, spike, dup, fault = self._decide(op)
        if spike and self.latency_spike_s > 0.0:
            self.stats["latency_spikes"] += 1
            self.sleep(self.latency_spike_s)
        if fault == "timeout":
            self.stats["faults"] += 1
            self.stats["timeouts"] += 1
            raise TransportTimeout(f"{op}: request timed out")
        if dup:
            with self._lock:
                last = copy.deepcopy(self._last_fetch)
            if last is not None:
                # duplicate delivery: the previous batch arrives again;
                # the server (and its cursor) never sees this call
                self.stats["duplicates"] += 1
                return last
        result = thunk()
        if fault == "disconnect":
            self.stats["faults"] += 1
            self.stats["disconnects"] += 1
            raise TransportDisconnect(f"{op}: connection lost mid-stream")
        if fault == "corrupt":
            # digests were computed from the pristine payload inside the
            # thunk; flip a byte in a COPY on the way out — the caller's
            # verify_parts/packet check turns this into PayloadCorruption
            if op == "fetch_update":
                parts, digests = result
                hot = [i for i, p in enumerate(parts) if p.nbytes > 0]
                if hot:
                    self.stats["faults"] += 1
                    self.stats["corruptions"] += 1
                    delivered = list(parts)
                    k = hot[rng.randrange(len(hot))]
                    delivered[k] = _corrupt_part(delivered[k])
                    result = (delivered, digests)
            elif op == "handle_update":
                packet, digest = result
                if packet.deltas:
                    self.stats["faults"] += 1
                    self.stats["corruptions"] += 1
                    deltas = list(packet.deltas)
                    k = rng.randrange(len(deltas))
                    deltas[k] = _corrupt_part(deltas[k])
                    from repro.core.weightstore import UpdatePacket

                    result = (UpdatePacket(model=packet.model,
                                           from_version=packet.from_version,
                                           to_version=packet.to_version,
                                           deltas=deltas), digest)
        if op == "fetch_update" and isinstance(result, tuple):
            snap = copy.deepcopy(result)
            with self._lock:
                self._last_fetch = snap
        return result
