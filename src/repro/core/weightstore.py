"""Versioned weight database — the paper's Fig. 4 schema on sqlite3.

Faithful reproduction of the paper's storage design:

* Tables ``model``, ``layer``, ``weight``, ``version``, ``accuracy``
  (§3.3, Fig. 4).  ``weight`` stores (layer_fk, version_fk, flat_index,
  value) — row per *non-zero changed* weight, so successive versions share
  unchanged entries (§3.1.2, §3.4).
* ``version.is_production`` mirrors the paper's Boolean status field; only
  one production version per model at a time.
* ``delta_since`` answers the client update query of §3.1.2 / §4.2: all
  weights created/updated after the client's version, across *skipped*
  intermediate patches, in one query.
* ``accuracy`` stores license tiers: per-layer magnitude-interval masks with
  the measured accuracy (§3.5) — static licensing is a lookup here.

Scale adaptation (DESIGN.md §2): row-per-weight is faithful but is O(1e10)
rows at 34B params.  Above ``row_limit`` parameters per layer the store
transparently switches that layer to *chunk mode*: the flattened tensor is
split into fixed-size pages, each page content-hashed; a new version stores
only pages whose hash changed.  Delta/checkout/rollback semantics are
identical — the unit of change is a page instead of a scalar.
"""
from __future__ import annotations

import hashlib
import json
import sqlite3
import time
import zlib
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pytree_io import flatten_params, unflatten_like

_SCHEMA = """
CREATE TABLE IF NOT EXISTS model (
    id INTEGER PRIMARY KEY,
    name TEXT UNIQUE NOT NULL,
    arch TEXT NOT NULL,
    created_at REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS layer (
    id INTEGER PRIMARY KEY,
    model_fk INTEGER NOT NULL REFERENCES model(id),
    name TEXT NOT NULL,
    layer_index INTEGER NOT NULL,
    shape TEXT NOT NULL,
    dtype TEXT NOT NULL,
    storage TEXT NOT NULL DEFAULT 'rows',   -- 'rows' | 'chunks'
    UNIQUE(model_fk, name)
);
CREATE TABLE IF NOT EXISTS version (
    id INTEGER PRIMARY KEY,
    model_fk INTEGER NOT NULL REFERENCES model(id),
    parent_fk INTEGER REFERENCES version(id),
    tag TEXT,
    message TEXT,
    is_major INTEGER NOT NULL DEFAULT 0,
    is_production INTEGER NOT NULL DEFAULT 0,
    created_at REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS weight (
    id INTEGER PRIMARY KEY,
    layer_fk INTEGER NOT NULL REFERENCES layer(id),
    version_fk INTEGER NOT NULL REFERENCES version(id),
    flat_index INTEGER NOT NULL,
    value REAL NOT NULL,
    created_at REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS weight_layer_version ON weight(layer_fk, version_fk);
CREATE TABLE IF NOT EXISTS weight_chunk (
    id INTEGER PRIMARY KEY,
    layer_fk INTEGER NOT NULL REFERENCES layer(id),
    version_fk INTEGER NOT NULL REFERENCES version(id),
    chunk_index INTEGER NOT NULL,
    hash TEXT NOT NULL,
    data BLOB NOT NULL,
    nbytes INTEGER NOT NULL,
    created_at REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS chunk_layer_version ON weight_chunk(layer_fk, version_fk);
CREATE TABLE IF NOT EXISTS accuracy (
    id INTEGER PRIMARY KEY,
    model_fk INTEGER NOT NULL REFERENCES model(id),
    version_fk INTEGER NOT NULL REFERENCES version(id),
    tier_name TEXT NOT NULL,
    accuracy REAL NOT NULL,
    masks TEXT NOT NULL,           -- JSON: {layer_pattern: [[lo, hi], ...]}
    created_at REAL NOT NULL,
    UNIQUE(model_fk, tier_name)
);
"""


@dataclass
class LayerDelta:
    """Sparse update for one layer: values at flat indices (or whole chunks).

    Chunk pages are encoded in the layer's ``dtype`` (decode with
    ``np.frombuffer(raw, dtype=d.dtype)``), and whether each page payload
    is zlib-compressed is carried *explicitly* in ``chunk_compressed`` —
    one flag per entry of ``chunks``.  Receivers must never sniff
    compression by attempting ``zlib.decompress``: raw pages can parse as
    valid zlib streams by coincidence and would be silently mangled.
    """

    layer: str
    shape: Tuple[int, ...]
    dtype: str
    indices: np.ndarray          # int64 flat indices (rows mode) or chunk ids
    values: Optional[np.ndarray] = None   # rows mode: scalar per index
    chunks: Optional[List[bytes]] = None  # chunks mode: raw page payloads
    chunk_elems: int = 0
    chunk_compressed: Optional[List[bool]] = None  # per-chunk zlib flag

    @property
    def nbytes(self) -> int:
        if self.chunks is not None:
            return int(sum(len(c) for c in self.chunks) + self.indices.nbytes)
        return int(self.indices.nbytes + self.values.nbytes)

    def chunk_flags(self) -> List[bool]:
        """Per-chunk compression flags (all-False when never set)."""
        if self.chunks is None:
            return []
        if self.chunk_compressed is None:
            return [False] * len(self.chunks)
        return list(self.chunk_compressed)

    def iter_pages(self):
        """Yield ``(chunk_index, page)`` per chunk, decoded in this
        delta's dtype under its explicit compression flags — the ONE
        place the wire-decode rule lives (consumers must never sniff
        zlib by trial-decompress)."""
        if self.chunks is None:
            return
        import zlib

        for ci, payload, comp in zip(self.indices, self.chunks,
                                     self.chunk_flags()):
            raw = zlib.decompress(payload) if comp else payload
            yield int(ci), np.frombuffer(raw, dtype=self.dtype)


@dataclass
class UpdatePacket:
    """Server -> client payload for one update request (§3.1.2)."""

    model: str
    from_version: Optional[int]
    to_version: int
    deltas: List[LayerDelta] = field(default_factory=list)

    @property
    def nbytes(self) -> int:
        return sum(d.nbytes for d in self.deltas)

    @property
    def num_entries(self) -> int:
        return int(sum(len(d.indices) for d in self.deltas))


class WeightStore:
    """sqlite3-backed versioned weight store (paper Fig. 4)."""

    # bumped to 2 when chunk pages switched from always-f32 to the
    # layer's registered dtype; see _check_chunk_encoding
    _FORMAT_VERSION = 2

    def __init__(
        self,
        path: str = ":memory:",
        *,
        row_limit: int = 262_144,
        chunk_elems: int = 65_536,
        compress_chunks: bool = True,
    ):
        self.conn = sqlite3.connect(path)
        self.conn.executescript(_SCHEMA)
        self.path = path
        self.row_limit = int(row_limit)
        self.chunk_elems = int(chunk_elems)
        self.compress_chunks = compress_chunks
        self._check_chunk_encoding()

    def _check_chunk_encoding(self) -> None:
        """Refuse to silently misread a pre-format-2 store.

        Format 1 encoded every chunk page as float32 regardless of the
        layer's dtype; format 2 encodes pages in the layer's own dtype.
        The two agree whenever every chunk-mode layer is float32 (the
        overwhelmingly common case), so such stores are stamped forward;
        a legacy store holding non-f32 chunk pages would be decoded as
        garbage and must be re-committed instead."""
        ver, = self.conn.execute("PRAGMA user_version").fetchone()
        if ver >= self._FORMAT_VERSION:
            return
        row = self.conn.execute(
            "SELECT l.name, l.dtype FROM layer l WHERE l.storage='chunks'"
            " AND l.dtype <> 'float32' AND EXISTS"
            " (SELECT 1 FROM weight_chunk c WHERE c.layer_fk=l.id) LIMIT 1"
        ).fetchone()
        if row is not None:
            raise RuntimeError(
                f"weight store {self.path!r} was written by format 1 "
                f"(chunk pages always float32) but layer {row[0]!r} is "
                f"registered as {row[1]!r}; re-commit the model with this "
                f"version to migrate — decoding would corrupt it")
        self.conn.execute(f"PRAGMA user_version={self._FORMAT_VERSION}")
        self.conn.commit()

    # ------------------------------------------------------------------ model
    def register_model(self, name: str, arch: str = "generic") -> int:
        cur = self.conn.execute(
            "INSERT OR IGNORE INTO model(name, arch, created_at) VALUES (?,?,?)",
            (name, arch, time.time()),
        )
        self.conn.commit()
        if cur.lastrowid:
            return cur.lastrowid
        return self._model_id(name)

    def _model_id(self, name: str) -> int:
        row = self.conn.execute("SELECT id FROM model WHERE name=?", (name,)).fetchone()
        if row is None:
            raise KeyError(f"unknown model {name!r}")
        return row[0]

    def _layer_id(self, model_id: int, name: str) -> Tuple[int, Tuple[int, ...], str, str]:
        row = self.conn.execute(
            "SELECT id, shape, dtype, storage FROM layer WHERE model_fk=? AND name=?",
            (model_id, name),
        ).fetchone()
        if row is None:
            raise KeyError(f"unknown layer {name!r}")
        return row[0], tuple(json.loads(row[1])), row[2], row[3]

    def _ensure_layers(self, model_id: int, flat: Dict[str, np.ndarray]) -> None:
        for i, (name, arr) in enumerate(flat.items()):
            storage = "chunks" if arr.size > self.row_limit else "rows"
            self.conn.execute(
                "INSERT OR IGNORE INTO layer(model_fk, name, layer_index, shape, dtype, storage)"
                " VALUES (?,?,?,?,?,?)",
                (model_id, name, i, json.dumps(list(arr.shape)), str(arr.dtype), storage),
            )

    # ---------------------------------------------------------------- commits
    def commit(
        self,
        model: str,
        params,
        *,
        parent: Optional[int] = None,
        tag: Optional[str] = None,
        message: str = "",
        major: bool = False,
        set_production: bool = True,
        store_zeros: bool = False,
    ) -> int:
        """Store a new version.  Only weights that changed vs ``parent`` get
        new rows (paper §3.1.2); pruned zeros are skipped unless
        ``store_zeros`` (paper §3.3: "only the nonzero weights")."""
        model_id = self._model_id(model) if self._exists(model) else self.register_model(model)
        flat = flatten_params(params)
        self._ensure_layers(model_id, flat)

        if parent is None:
            parent = self.production_version(model, missing_ok=True)
        parent_flat = (
            self._reconstruct(model_id, parent) if parent is not None and not major else {}
        )

        now = time.time()
        cur = self.conn.execute(
            "INSERT INTO version(model_fk, parent_fk, tag, message, is_major, created_at)"
            " VALUES (?,?,?,?,?,?)",
            (model_id, None if major else parent, tag, message, int(major), now),
        )
        version_id = cur.lastrowid

        for name, arr in flat.items():
            layer_id, _, dtype, storage = self._layer_id(model_id, name)
            old = parent_flat.get(name)
            if storage == "rows":
                flat_arr = np.asarray(arr, dtype=np.float32).reshape(-1)
                self._commit_rows(layer_id, version_id, flat_arr, old, store_zeros, now)
            else:
                # chunk pages are encoded in the layer's registered dtype so
                # every receiver can decode with LayerDelta.dtype (non-f32
                # layers used to be silently re-encoded as f32)
                flat_arr = np.asarray(arr, dtype=dtype).reshape(-1)
                self._commit_chunks(layer_id, version_id, flat_arr, old, now)

        if set_production:
            self._set_production(model_id, version_id)
        self.conn.commit()
        return version_id

    def _commit_rows(self, layer_id, version_id, flat_arr, old, store_zeros, now) -> None:
        if old is None:
            changed = np.arange(flat_arr.size, dtype=np.int64)
        else:
            changed = np.nonzero(flat_arr != old.reshape(-1))[0]
        if not store_zeros:
            changed = changed[flat_arr[changed] != 0.0]
            # a weight that *became* zero must still be recorded as a change
            if old is not None:
                zeroed = np.nonzero((flat_arr == 0.0) & (old.reshape(-1) != 0.0))[0]
                changed = np.union1d(changed, zeroed)
        rows = [
            (layer_id, version_id, int(i), float(flat_arr[i]), now) for i in changed
        ]
        self.conn.executemany(
            "INSERT INTO weight(layer_fk, version_fk, flat_index, value, created_at)"
            " VALUES (?,?,?,?,?)",
            rows,
        )

    def _commit_chunks(self, layer_id, version_id, flat_arr, old, now) -> None:
        ce = self.chunk_elems
        n_chunks = -(-flat_arr.size // ce)
        old_flat = None if old is None else old.reshape(-1)
        rows = []
        for ci in range(n_chunks):
            page = flat_arr[ci * ce : (ci + 1) * ce]
            if old_flat is not None:
                old_page = old_flat[ci * ce : (ci + 1) * ce]
                if page.size == old_page.size and np.array_equal(page, old_page):
                    continue
            payload = page.tobytes()
            if self.compress_chunks:
                payload = zlib.compress(payload, level=1)
            h = hashlib.sha1(payload).hexdigest()
            rows.append((layer_id, version_id, ci, h, payload, len(payload), now))
        self.conn.executemany(
            "INSERT INTO weight_chunk(layer_fk, version_fk, chunk_index, hash, data, nbytes,"
            " created_at) VALUES (?,?,?,?,?,?,?)",
            rows,
        )

    def _exists(self, model: str) -> bool:
        return (
            self.conn.execute("SELECT 1 FROM model WHERE name=?", (model,)).fetchone()
            is not None
        )

    # --------------------------------------------------------------- versions
    def history(self, model: str) -> List[dict]:
        model_id = self._model_id(model)
        rows = self.conn.execute(
            "SELECT id, parent_fk, tag, message, is_major, is_production, created_at"
            " FROM version WHERE model_fk=? ORDER BY id",
            (model_id,),
        ).fetchall()
        keys = ("id", "parent", "tag", "message", "is_major", "is_production", "created_at")
        return [dict(zip(keys, r)) for r in rows]

    def production_version(self, model: str, missing_ok: bool = False) -> Optional[int]:
        model_id = self._model_id(model)
        row = self.conn.execute(
            "SELECT id FROM version WHERE model_fk=? AND is_production=1", (model_id,)
        ).fetchone()
        if row is None:
            if missing_ok:
                return None
            raise KeyError(f"no production version for {model!r}")
        return row[0]

    def _set_production(self, model_id: int, version_id: int) -> None:
        self.conn.execute(
            "UPDATE version SET is_production=0 WHERE model_fk=?", (model_id,)
        )
        self.conn.execute(
            "UPDATE version SET is_production=1 WHERE id=?", (version_id,)
        )

    def rollback(self, model: str, version: int) -> None:
        """Paper §3.4: rollback = repoint the production flag."""
        model_id = self._model_id(model)
        row = self.conn.execute(
            "SELECT 1 FROM version WHERE id=? AND model_fk=?", (version, model_id)
        ).fetchone()
        if row is None:
            raise KeyError(f"version {version} does not belong to model {model!r}")
        self._set_production(model_id, version)
        self.conn.commit()

    def _ancestry(self, version_id: int) -> List[int]:
        """Root-first chain of versions ending at ``version_id``."""
        chain = []
        cur: Optional[int] = version_id
        while cur is not None:
            chain.append(cur)
            row = self.conn.execute(
                "SELECT parent_fk, is_major FROM version WHERE id=?", (cur,)
            ).fetchone()
            if row is None:
                raise KeyError(f"unknown version {cur}")
            parent, is_major = row
            cur = None if is_major else parent
        return chain[::-1]

    # --------------------------------------------------------------- checkout
    def checkout(self, model: str, version: Optional[int] = None, template=None):
        """Reconstruct full params at ``version`` (default: production).

        Paper §3.3: build a zeroed model layer-by-layer, then place stored
        values at their flattened indices; we replay the ancestor chain so
        minor versions inherit unchanged weights.
        """
        model_id = self._model_id(model)
        if version is None:
            version = self.production_version(model)
        flat = self._reconstruct(model_id, version)
        if template is not None:
            return unflatten_like(template, flat)
        return flat

    def _reconstruct(self, model_id: int, version_id: int) -> Dict[str, np.ndarray]:
        chain = self._ancestry(version_id)
        layers = self.conn.execute(
            "SELECT id, name, shape, dtype, storage FROM layer WHERE model_fk=?"
            " ORDER BY layer_index",
            (model_id,),
        ).fetchall()
        out: Dict[str, np.ndarray] = {}
        for layer_id, name, shape, dtype, storage in layers:
            shape = tuple(json.loads(shape))
            size = int(np.prod(shape)) if shape else 1
            # chunk pages are stored bit-exact in the layer's dtype —
            # accumulating them through f32 would round f64 layers; rows
            # values are sqlite REALs, f32 staging is the seed behavior
            buf = np.zeros(size,
                           dtype=dtype if storage == "chunks" else np.float32)
            touched = False
            for v in chain:
                if storage == "rows":
                    rows = self.conn.execute(
                        "SELECT flat_index, value FROM weight WHERE layer_fk=? AND version_fk=?",
                        (layer_id, v),
                    ).fetchall()
                    if rows:
                        touched = True
                        idx = np.fromiter((r[0] for r in rows), dtype=np.int64, count=len(rows))
                        val = np.fromiter((r[1] for r in rows), dtype=np.float32, count=len(rows))
                        buf[idx] = val
                else:
                    rows = self.conn.execute(
                        "SELECT chunk_index, data FROM weight_chunk"
                        " WHERE layer_fk=? AND version_fk=?",
                        (layer_id, v),
                    ).fetchall()
                    if rows:
                        touched = True
                        ce = self.chunk_elems
                        for ci, payload in rows:
                            raw = zlib.decompress(payload) if self.compress_chunks else payload
                            page = np.frombuffer(raw, dtype=dtype)
                            buf[ci * ce : ci * ce + page.size] = page
            if touched or True:  # layers with all-zero weights are legal (fully pruned)
                out[name] = buf.reshape(shape).astype(dtype, copy=False)
        return out

    # ------------------------------------------------------------------ delta
    def delta_since(
        self, model: str, client_version: Optional[int], target: Optional[int] = None
    ) -> UpdatePacket:
        """All weights changed after ``client_version`` up to ``target``
        (default: production) — one query across skipped patches (§4.2)."""
        model_id = self._model_id(model)
        if target is None:
            target = self.production_version(model)
        packet = UpdatePacket(model=model, from_version=client_version, to_version=target)
        if client_version == target:
            return packet

        chain = self._ancestry(target)
        if client_version is not None and client_version in chain:
            new_versions = chain[chain.index(client_version) + 1 :]
            full = False
        else:
            # client is on a different branch (or None): ship a full snapshot
            new_versions = chain
            full = True

        layers = self.conn.execute(
            "SELECT id, name, shape, dtype, storage FROM layer WHERE model_fk=?"
            " ORDER BY layer_index",
            (model_id,),
        ).fetchall()
        if full:
            flat = self._reconstruct(model_id, target)
            for layer_id, name, shape, dtype, storage in layers:
                # ship in the layer's own dtype: a full pull of a
                # chunk-mode f64/f16 layer must not round through f32
                arr = flat[name].reshape(-1)
                nz = np.nonzero(arr)[0]
                packet.deltas.append(
                    LayerDelta(
                        layer=name, shape=tuple(json.loads(shape)), dtype=dtype,
                        indices=nz.astype(np.int64), values=arr[nz],
                    )
                )
            return packet

        qmarks = ",".join("?" * len(new_versions))
        for layer_id, name, shape, dtype, storage in layers:
            shape_t = tuple(json.loads(shape))
            if storage == "rows":
                rows = self.conn.execute(
                    f"SELECT flat_index, value, version_fk FROM weight"
                    f" WHERE layer_fk=? AND version_fk IN ({qmarks}) ORDER BY version_fk",
                    (layer_id, *new_versions),
                ).fetchall()
                if not rows:
                    continue
                last: Dict[int, float] = {}
                for fi, val, _v in rows:  # later versions override earlier
                    last[fi] = val
                idx = np.array(sorted(last), dtype=np.int64)
                val = np.array([last[i] for i in idx], dtype=np.float32)
                packet.deltas.append(
                    LayerDelta(layer=name, shape=shape_t, dtype=dtype, indices=idx, values=val)
                )
            else:
                rows = self.conn.execute(
                    f"SELECT chunk_index, data, version_fk FROM weight_chunk"
                    f" WHERE layer_fk=? AND version_fk IN ({qmarks}) ORDER BY version_fk",
                    (layer_id, *new_versions),
                ).fetchall()
                if not rows:
                    continue
                last_c: Dict[int, bytes] = {}
                for ci, data, _v in rows:
                    last_c[ci] = data
                idx = np.array(sorted(last_c), dtype=np.int64)
                packet.deltas.append(
                    LayerDelta(
                        layer=name, shape=shape_t, dtype=dtype, indices=idx,
                        chunks=[last_c[int(i)] for i in idx], chunk_elems=self.chunk_elems,
                        chunk_compressed=[self.compress_chunks] * len(idx),
                    )
                )
        return packet

    # ------------------------------------------------------------- accounting
    def storage_bytes(self, model: str) -> Dict[str, int]:
        """Bytes attributable to this model's stored weights (paper Table 1).

        ``db_rows``: faithful accounting — each weight row costs
        index (8B) + value (paper: value storage depends on quantization;
        sqlite REAL is 8B, matching the paper's 64-bit baseline).
        ``payload``: pure payload bytes (indices + values / compressed pages).
        """
        model_id = self._model_id(model)
        n_rows, = self.conn.execute(
            "SELECT COUNT(*) FROM weight w JOIN layer l ON w.layer_fk=l.id"
            " WHERE l.model_fk=?",
            (model_id,),
        ).fetchone()
        chunk_bytes, = self.conn.execute(
            "SELECT COALESCE(SUM(c.nbytes),0) FROM weight_chunk c JOIN layer l"
            " ON c.layer_fk=l.id WHERE l.model_fk=?",
            (model_id,),
        ).fetchone()
        return {
            "weight_rows": int(n_rows),
            "row_bytes": int(n_rows) * 16,  # 8B flat_index + 8B REAL value
            "chunk_bytes": int(chunk_bytes),
            "payload": int(n_rows) * 16 + int(chunk_bytes),
        }

    # ------------------------------------------------------------- accuracies
    def register_tier(
        self, model: str, version: int, tier_name: str, accuracy: float,
        masks: Dict[str, Sequence[Tuple[float, float]]],
    ) -> None:
        model_id = self._model_id(model)
        self.conn.execute(
            "INSERT OR REPLACE INTO accuracy(model_fk, version_fk, tier_name, accuracy,"
            " masks, created_at) VALUES (?,?,?,?,?,?)",
            (model_id, version, tier_name, accuracy,
             json.dumps({k: [list(iv) for iv in v] for k, v in masks.items()}),
             time.time()),
        )
        self.conn.commit()

    def get_tier(self, model: str, tier_name: str) -> Tuple[float, Dict[str, list]]:
        model_id = self._model_id(model)
        row = self.conn.execute(
            "SELECT accuracy, masks FROM accuracy WHERE model_fk=? AND tier_name=?",
            (model_id, tier_name),
        ).fetchone()
        if row is None:
            raise KeyError(f"no tier {tier_name!r} for model {model!r}")
        return row[0], {k: [tuple(iv) for iv in v] for k, v in json.loads(row[1]).items()}

    def list_tiers(self, model: str) -> List[Tuple[str, float]]:
        model_id = self._model_id(model)
        rows = self.conn.execute(
            "SELECT tier_name, accuracy FROM accuracy WHERE model_fk=? ORDER BY accuracy DESC",
            (model_id,),
        ).fetchall()
        return [(r[0], r[1]) for r in rows]

    def close(self) -> None:
        self.conn.close()
