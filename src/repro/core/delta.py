"""Sparse weight-delta encode/apply (the low-latency-update hot path, §4.3).

The wire format is ``LayerDelta`` (indices + values / chunk pages) from
``weightstore``.  On-device application is a flat scatter; the jit path uses
``delta_apply`` from ``repro.kernels.ops`` (Pallas on TPU, jnp fallback).

Shard-aware distribution (beyond paper, DESIGN.md §2): ``shard_delta``
splits a delta by a host's flat-index range so each data-parallel host
fetches only the bytes its shard needs — turning the paper's single-device
update into a multi-host collective-free update.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.pytree_io import flatten_params, unflatten_like
from repro.core.weightstore import LayerDelta, UpdatePacket


def encode_delta(old_params: Any, new_params: Any) -> UpdatePacket:
    """Client-side / test helper: sparse diff of two pytrees."""
    old_flat = flatten_params(old_params)
    new_flat = flatten_params(new_params)
    packet = UpdatePacket(model="local", from_version=None, to_version=-1)
    for name, new in new_flat.items():
        old = old_flat[name]
        a = np.asarray(new, dtype=np.float32).reshape(-1)
        b = np.asarray(old, dtype=np.float32).reshape(-1)
        idx = np.nonzero(a != b)[0]
        if idx.size == 0:
            continue
        packet.deltas.append(
            LayerDelta(layer=name, shape=tuple(np.shape(new)), dtype=str(np.asarray(new).dtype),
                       indices=idx.astype(np.int64), values=a[idx])
        )
    return packet


def delta_to_dense(delta: LayerDelta) -> np.ndarray:
    """Materialize a LayerDelta into a dense update-or-zero buffer + mask.

    Chunk pages are decoded with the delta's own dtype and its explicit
    per-chunk compression flags (never sniffed — raw bytes that happen to
    parse as zlib must pass through untouched)."""
    size = int(np.prod(delta.shape)) if delta.shape else 1
    if delta.chunks is not None:
        buf = np.zeros(size, dtype=delta.dtype)
        ce = delta.chunk_elems
        for ci, page in delta.iter_pages():
            buf[ci * ce : ci * ce + page.size] = page
    else:
        buf = np.zeros(size, dtype=np.float32)
        buf[delta.indices] = delta.values
    return buf.reshape(delta.shape)


def apply_packet(params: Any, packet: UpdatePacket, *, use_kernel: bool = True,
                 donate: bool = False) -> Any:
    """Apply an update packet to local params (edge-device side, §3.1.2).

    ``donate=True`` lets the kernel consume its (freshly device-put) base
    buffer and scatter in place — the staged-update path applies many
    bounded parts against one staging copy, where cloning the layer per
    part would dominate."""
    flat = flatten_params(params)
    out = dict(flat)
    for d in packet.deltas:
        if d.layer not in flat:
            raise KeyError(f"delta for unknown layer {d.layer!r}")
        base = jnp.asarray(flat[d.layer]).reshape(-1)
        if d.chunks is not None:
            dense = jnp.asarray(delta_to_dense(d)).reshape(-1)
            # chunk pages overwrite whole ranges
            mask = np.zeros(base.shape[0], dtype=bool)
            ce = d.chunk_elems
            for ci in d.indices:
                mask[int(ci) * ce : (int(ci) + 1) * ce] = True
            new = jnp.where(jnp.asarray(mask), dense.astype(base.dtype), base)
        elif use_kernel:
            from repro.kernels import ops

            new = ops.delta_apply(base, jnp.asarray(d.indices),
                                  jnp.asarray(d.values, dtype=base.dtype),
                                  donate=donate)
        else:
            new = base.at[jnp.asarray(d.indices)].set(jnp.asarray(d.values, dtype=base.dtype))
        out[d.layer] = np.asarray(new).reshape(flat[d.layer].shape)
    return unflatten_like(params, out)


def shard_delta(packet: UpdatePacket, shard_ranges: Dict[str, Tuple[int, int]]) -> UpdatePacket:
    """Restrict a packet to one host's flat-index range per layer.

    ``shard_ranges[layer] = (start, stop)`` over the flattened tensor;
    layers absent from the map are shipped whole (replicated params).
    """
    out = UpdatePacket(model=packet.model, from_version=packet.from_version,
                       to_version=packet.to_version)
    for d in packet.deltas:
        rng = shard_ranges.get(d.layer)
        if rng is None:
            out.deltas.append(d)
            continue
        start, stop = rng
        if d.chunks is not None:
            ce = d.chunk_elems
            keep = [(i, c, f) for i, c, f in zip(d.indices, d.chunks,
                                                 d.chunk_flags())
                    if int(i) * ce < stop and (int(i) + 1) * ce > start]
            if not keep:
                continue
            out.deltas.append(LayerDelta(
                layer=d.layer, shape=d.shape, dtype=d.dtype,
                indices=np.array([i for i, _, _ in keep], dtype=np.int64),
                chunks=[c for _, c, _ in keep], chunk_elems=ce,
                chunk_compressed=[f for _, _, f in keep]))
        else:
            sel = (d.indices >= start) & (d.indices < stop)
            if not sel.any():
                continue
            out.deltas.append(LayerDelta(
                layer=d.layer, shape=d.shape, dtype=d.dtype,
                indices=d.indices[sel], values=d.values[sel]))
    return out
