"""Core: the paper's contribution — versioned weight storage, delta updates,
compression, and dynamic licensing — as composable JAX-side modules."""
from repro.core.compression import (
    CompressionStats,
    QuantizedTensor,
    SharedTensor,
    compress_pipeline,
    dequantize,
    magnitude_prune,
    prune_params,
    quantize_int8,
    unshare,
    weight_share,
)
from repro.core.delta import apply_packet, encode_delta, shard_delta
from repro.core.licensing import (
    FULL_TIER,
    LicenseTier,
    apply_license,
    calibrate_license,
    license_stats,
    make_static_tiers,
)
from repro.core.protocol import EdgeClient, LicenseServer
from repro.core.pytree_io import flatten_params, unflatten_like
from repro.core.weightstore import LayerDelta, UpdatePacket, WeightStore

__all__ = [
    "CompressionStats", "QuantizedTensor", "SharedTensor", "compress_pipeline",
    "dequantize", "magnitude_prune", "prune_params", "quantize_int8", "unshare",
    "weight_share", "apply_packet", "encode_delta", "shard_delta", "FULL_TIER",
    "LicenseTier", "apply_license", "calibrate_license", "license_stats",
    "make_static_tiers", "EdgeClient", "LicenseServer", "flatten_params",
    "unflatten_like", "LayerDelta", "UpdatePacket", "WeightStore",
]
