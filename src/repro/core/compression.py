"""Model-compression pipeline (paper §3.2, Fig. 3).

prune -> (fine-tune, done by the caller's training loop) -> quantize ->
weight-share.  All steps are pure JAX and jit-able; the pipeline returns
both compressed representations and accounting stats for the paper's
Table 1 reproduction.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Parameters whose magnitude encodes recurrence *dynamics* rather than a
# linear map.  Pruning/masking these can make an SSM non-contractive
# (DESIGN.md §4) — every compression / licensing entry point excludes them.
DYNAMICS_PARAM_KEYWORDS = ("A_log", "dt_bias", "a_param", "norm", "scale", "bias_embed")


def is_dynamics_param(name: str) -> bool:
    return any(k in name for k in DYNAMICS_PARAM_KEYWORDS)


# ------------------------------------------------------------------- pruning
def magnitude_threshold(w: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    """|w| value below which ``sparsity`` fraction of entries fall."""
    return jnp.quantile(jnp.abs(w.reshape(-1)).astype(jnp.float32), sparsity)


def magnitude_prune(w: jnp.ndarray, sparsity: float) -> jnp.ndarray:
    """Magnitude pruning [Han et al. 2016]: zero the smallest-|w| fraction."""
    thr = magnitude_threshold(w, sparsity)
    return jnp.where(jnp.abs(w) >= thr, w, jnp.zeros_like(w))


def prune_params(params: Any, sparsity: float, *, exclude: Callable[[str], bool] = is_dynamics_param) -> Any:
    """Per-layer magnitude pruning over a pytree, skipping dynamics params."""
    from repro.core.pytree_io import flatten_params, unflatten_like

    flat = flatten_params(params)
    out = {}
    for name, arr in flat.items():
        if exclude(name) or arr.ndim < 2:
            out[name] = arr
        else:
            out[name] = np.asarray(magnitude_prune(jnp.asarray(arr), sparsity))
    return unflatten_like(params, out)


# -------------------------------------------------------------- quantization
@dataclass(frozen=True)
class QuantizedTensor:
    """Symmetric int8 quantization with per-channel (axis 0 of the flattened
    2D view) scales — §3.2 "converting weights from 64-bit to 8-bit"."""

    codes: jnp.ndarray      # int8, same shape as the original
    scale: jnp.ndarray      # f32, broadcastable to codes
    shape: Tuple[int, ...]
    dtype: Any

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.shape)) + int(np.prod(self.scale.shape)) * 4


def quantize_int8(w: jnp.ndarray, *, per_channel: bool = True) -> QuantizedTensor:
    w32 = w.astype(jnp.float32)
    if per_channel and w.ndim >= 2:
        axes = tuple(range(1, w.ndim))
        amax = jnp.max(jnp.abs(w32), axis=axes, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(w32))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    codes = jnp.clip(jnp.round(w32 / scale), -127, 127).astype(jnp.int8)
    return QuantizedTensor(codes=codes, scale=scale, shape=tuple(w.shape), dtype=w.dtype)


def dequantize(q: QuantizedTensor) -> jnp.ndarray:
    return (q.codes.astype(jnp.float32) * q.scale).astype(q.dtype)


# ------------------------------------------------------------ weight sharing
@dataclass(frozen=True)
class SharedTensor:
    """Weight sharing [Deep Compression]: k-means codebook + per-entry index."""

    codebook: jnp.ndarray   # (k,) f32
    indices: jnp.ndarray    # uint8, same shape as original
    shape: Tuple[int, ...]
    dtype: Any

    @property
    def nbytes(self) -> int:
        # index matrix at ceil(log2 k) bits + codebook
        k = int(self.codebook.shape[0])
        bits = max(1, int(np.ceil(np.log2(max(k, 2)))))
        return int(np.prod(self.shape)) * bits // 8 + k * 4


def kmeans_1d(x: jnp.ndarray, k: int, iters: int = 25) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """1-D k-means via Lloyd iterations in ``lax.fori_loop`` (jit-able).

    Initialization is linear over [min, max] (Deep Compression's recommended
    linear init).  Empty clusters keep their previous centroid.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    lo, hi = jnp.min(flat), jnp.max(flat)
    init = lo + (hi - lo) * (jnp.arange(k, dtype=jnp.float32) + 0.5) / k

    def assign(centroids):
        return jnp.argmin(jnp.abs(flat[:, None] - centroids[None, :]), axis=1)

    def body(_, centroids):
        a = assign(centroids)
        sums = jax.ops.segment_sum(flat, a, num_segments=k)
        counts = jax.ops.segment_sum(jnp.ones_like(flat), a, num_segments=k)
        return jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), centroids)

    centroids = jax.lax.fori_loop(0, iters, body, init)
    return centroids, assign(centroids).astype(jnp.uint8)


def weight_share(w: jnp.ndarray, k: int = 32, iters: int = 25) -> SharedTensor:
    codebook, idx = kmeans_1d(w, k, iters)
    return SharedTensor(codebook=codebook, indices=idx.reshape(w.shape),
                        shape=tuple(w.shape), dtype=w.dtype)


def unshare(s: SharedTensor) -> jnp.ndarray:
    return s.codebook[s.indices.astype(jnp.int32)].astype(s.dtype)


# ---------------------------------------------------------------- pipeline
@dataclass
class CompressionStats:
    full_bytes: int
    pruned_nonzero: int
    pruned_bytes: int          # sparse: 8B index + value bytes per nonzero
    quantized_bytes: int       # sparse int8: 8B index + 1B code (+ scales)
    shared_bytes: int          # sparse shared: index + log2(k)-bit code
    sparsity: float


def compress_pipeline(
    params: Any,
    *,
    sparsity: float = 0.8,
    codebook_size: Optional[int] = 32,
    value_bytes_full: int = 8,   # the paper's pre-quant baseline is 64-bit
) -> Tuple[Any, Dict[str, QuantizedTensor], CompressionStats]:
    """Fig. 3 pipeline: prune -> quantize -> share.  Returns the pruned
    (dense, zeros in place) params for fine-tuning, the quantized per-layer
    tensors for storage/serving, and Table-1-style accounting."""
    from repro.core.pytree_io import flatten_params

    pruned = prune_params(params, sparsity)
    flat = flatten_params(pruned)

    total = int(sum(a.size for a in flat.values()))
    nonzero = int(sum(int(np.count_nonzero(a)) for a in flat.values()))

    quantized: Dict[str, QuantizedTensor] = {}
    shared_bytes = 0
    for name, arr in flat.items():
        q = quantize_int8(jnp.asarray(arr))
        quantized[name] = q
        if codebook_size:
            nz = int(np.count_nonzero(arr))
            bits = max(1, int(np.ceil(np.log2(max(codebook_size, 2)))))
            shared_bytes += nz * (8 + bits / 8) + codebook_size * 4
        else:
            shared_bytes += int(np.count_nonzero(arr)) * 9

    stats = CompressionStats(
        full_bytes=total * value_bytes_full,
        pruned_nonzero=nonzero,
        pruned_bytes=nonzero * (8 + value_bytes_full),
        quantized_bytes=nonzero * 9 + sum(int(np.prod(q.scale.shape)) * 4 for q in quantized.values()),
        shared_bytes=int(shared_bytes),
        sparsity=1.0 - nonzero / max(total, 1),
    )
    return pruned, quantized, stats
