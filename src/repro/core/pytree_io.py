"""Pytree <-> flat {layer_name: ndarray} conversion at the WeightStore boundary.

The paper's database schema (Fig. 4) is keyed by *layer name*; JAX params are
arbitrary pytrees.  We canonicalize with '/'-joined key paths so any model's
params round-trip through the store.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        elif isinstance(p, jax.tree_util.FlattenedIndexKey):
            parts.append(str(p.key))
        else:  # pragma: no cover - future key types
            parts.append(str(p))
    return "/".join(parts)


def flatten_params(params: Any) -> Dict[str, np.ndarray]:
    """Pytree -> ordered {path: np.ndarray}."""
    leaves = jax.tree_util.tree_leaves_with_path(params)
    return {_path_str(path): np.asarray(leaf) for path, leaf in leaves}


def unflatten_like(template: Any, flat: Dict[str, np.ndarray]) -> Any:
    """Rebuild a pytree with `template`'s structure from a flat dict."""
    paths_and_leaves = jax.tree_util.tree_leaves_with_path(template)
    treedef = jax.tree_util.tree_structure(template)
    new_leaves = []
    for path, leaf in paths_and_leaves:
        key = _path_str(path)
        if key not in flat:
            raise KeyError(f"missing layer {key!r} in store payload")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key!r}: store {arr.shape} vs template {np.shape(leaf)}"
            )
        new_leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
