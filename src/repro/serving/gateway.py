"""Licensed serving gateway: continuous batching over tier-keyed weight views.

This is the serving front end the ROADMAP's "heavy traffic" north star
needs on a single device: requests tagged with a ``LicenseTier`` stream
in, the :class:`~repro.serving.scheduler.Scheduler` groups them into
tier-homogeneous micro-batches, and every batch is served through a
**(tier, version)-keyed cache of masked weight views** — the paper's
one-stored-model-many-tiers claim (§3.5) amortized across requests
instead of paid per request.

Execution model
---------------
Two jitted functions, each compiled once per gateway:

* ``prefill``: by default (paged + reconstructible lane state)
  *left-aligned chunked* — every prompt keeps its true positions from 0
  and advances up to ``chunk_size`` tokens per prefill action, with
  chunk actions strictly interleaved against decode steps so no decode
  ever waits longer than one chunk; the per-lane variable-offset suffix
  step (``prefill_suffix_step``) is the chunk engine.  The legacy
  bucket path (``chunk_size=0``, and the fallback for ring/SSM lane
  state): ``vmap`` over ``max_batch`` lanes of a batch-1
  ``prefill_step`` with a fixed prompt bucket (``max_prompt``); short
  prompts are right-aligned with repeated-first-token padding (same
  trick as ``ServingEngine``).
* ``decode``: ``vmap`` over lanes of a batch-1 ``serve_step`` where the
  absolute position is *per lane* — this is what makes the batching
  continuous: lanes at different depths (different requests' positions)
  decode together, and a finished lane is refilled by the next prefill
  without draining the batch.

Both take the weight view as an argument, so one compilation serves
every tier and weight version.  By default each lane's logits feed a
**fused on-device sampling step** (``engine.sample_lane``) so a decode
step ships one token id per lane device->host instead of a full logits
row; ``fuse_sampling=False`` (or ``record_logits=True``) is the
return-logits escape hatch tests and the equivalence benchmark use.

Cache memory
------------
KV/SSM state lives in a shared pool.  Prefill gathers/scatters per-lane
views around the vmapped step; decode — the hot path — runs
**kernel-resident** by default (``kernel_decode``): one batched step
whose cache operands are the paged pool's physical block arrays, so
attention reads each cache byte once through the micro-batch's trimmed
block tables and the one new K/V token per lane is a block-indexed
scatter — no contiguous view of any sequence exists during decode.  Two
pool modes, selected by the ``paged`` config flag:

* ``paged=True`` (default): a :class:`~repro.serving.paging.PagedCachePool`
  — per-token KV leaves live as fixed-size physical blocks addressed
  through per-request block tables, so short and long requests share the
  pool without over-reserving, and ``max_lanes`` (concurrency) decouples
  from ``max_batch`` (vmap width).  Admission is gated on free *blocks*
  (plus a watermark); if decode exhausts the pool, the **youngest**
  running request is preempted back to the queue head (recompute-style —
  generation is deterministic per (seed, prompt, view), so the restart
  reproduces its tokens).  Models with no per-token cache (pure SSM)
  fall back to the contiguous pool automatically.
* ``paged=False``: the seed fixed-slab :class:`CachePool`, one
  ``capacity``-token lane per ``max_batch`` slot.

With paging, a **shared-prefix radix cache** (``serving/prefix.py``,
``prefix_cache=True`` default) retains finished prompts' block chains
per (tier, version) scope: a later request whose prompt shares a cached
prefix adopts those blocks by reference and prefills only the uncached
remainder; shared blocks are read-only — decode copy-on-writes a shared
tail block before its first write into it — and retained chains with no
live request are evicted LRU-first under allocation pressure.  Under
chunked prefill the radix keys are the TRUE token ids (left alignment
puts every prompt's positions at 0..len), so prompts of *different
lengths* sharing a system prefix share its KV blocks — the padded
bucket rows of the legacy path could only ever match same-bucket rows.

Licensing integration
---------------------
* float path: the view is ``apply_license(base, tier)`` — masking cost
  paid once per (tier, version), cached in :class:`TierViewCache`;
* int8 path (``quantized=True``): ONE int8 store serves every tier and
  the view is just the tier's packed license intervals, fused into the
  in-scan masked dequant (``kernels/masked_dequant`` semantics); with
  ``materialize_int8_views=True`` the gateway instead runs the fused
  masked-dequant kernel once per (tier, version) and caches the
  full-precision licensed view — trading memory for per-step speed on
  long decode streams.
* protocol: :meth:`LicensedGateway.from_server` boots the gateway from a
  ``LicenseServer`` via the §3.1.2 delta protocol (an internal
  ``EdgeClient`` holds the raw weights); :meth:`begin_sync` starts a
  *staged* pull (``serving/updates.py``) whose bounded
  fetch/apply/requantize/prewarm steps ride along with scheduler
  iterations and whose weights+tiers flip is one atomic step —
  :meth:`sync` is the blocking form of the same machinery.  Admission
  validates the tier (locally or against the server) and pins the
  request to the current version, so in-flight requests are never
  re-masked mid-generation; stale versions and their views are dropped
  once the last pinned request drains.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.transport import Transport, TransportError
from repro.serving.engine import (prefill_step, prefill_suffix_step,
                                  right_align, sample, sample_lane,
                                  serve_step, serve_step_paged)
from repro.serving.fleet import ModelSlot
from repro.serving.paging import cdiv
from repro.serving.scheduler import (GatewayRequest, RequestState,
                                     ScheduledAction)


def _pow2(n: int) -> int:
    """Smallest power of two >= n (bucketing for jit specialization)."""
    return 1 << max(0, int(n) - 1).bit_length()


def _finish_lane(logits, seed, n_out, temp, top_k, *, fused, with_rng,
                 with_topk):
    """One lane's epilogue: raw logits row, or the fused on-device sample."""
    if not fused:
        return logits
    key = jax.random.fold_in(jax.random.PRNGKey(seed), n_out)
    return sample_lane(logits, key, temp, top_k,
                       with_rng=with_rng, with_topk=with_topk)


@functools.lru_cache(maxsize=None)
def _compiled_steps(cfg: ModelConfig, fused: bool = False,
                    with_rng: bool = True, with_topk: bool = True):
    """Jitted lane-vmapped prefill/decode, shared by every gateway on the
    same (hashable, frozen) config — one compile per (config, shape,
    fused, rng, topk) key.  ``fused=True`` samples per lane on device and
    returns token ids; ``fused=False`` returns the raw logits rows.
    ``with_rng``/``with_topk`` specialize the fused sampler to the
    micro-batch (all-greedy batches skip the categorical, no-top-k
    batches skip the vocab sort) — at most 4 fused variants ever compile."""

    def _finish(logits, seed, n_out, temp, top_k):
        return _finish_lane(logits, seed, n_out, temp, top_k, fused=fused,
                            with_rng=with_rng, with_topk=with_topk)

    def _prefill_one(view_params, tokens, cache, seed, n_out, temp, top_k, li):
        logits, cache = prefill_step(view_params, cfg, tokens[None], cache,
                                     license_intervals=li)
        return _finish(logits[0], seed, n_out, temp, top_k), cache

    def _decode_one(view_params, tok, cache, pos, seed, n_out, temp, top_k, li):
        logits, cache = serve_step(view_params, cfg, tok[None, None], cache,
                                   pos, license_intervals=li)
        return _finish(logits[0], seed, n_out, temp, top_k), cache

    return (jax.jit(jax.vmap(_prefill_one,
                             in_axes=(None, 0, 0, 0, 0, 0, 0, None))),
            jax.jit(jax.vmap(_decode_one,
                             in_axes=(None, 0, 0, 0, 0, 0, 0, 0, None))))


@functools.lru_cache(maxsize=None)
def _compiled_paged_decode(cfg: ModelConfig, fused: bool = False,
                           with_rng: bool = True, with_topk: bool = True,
                           kernel: str = "off"):
    """Jitted *kernel-resident* decode step: one batched call over the
    micro-batch (not a per-lane vmap) whose cache operands are the paged
    pool's physical block arrays — attention reads each cache byte once
    through the (trimmed) block tables and writes the one new K/V token
    per lane through its block index.  No per-lane contiguous cache is
    ever materialized; only the constant-size lane state rides in and
    out.  One compilation per (config, used-table-width, sampling
    variant); widths are ``ceil(context / block_size)`` so at most
    ``blocks_per_lane`` widths ever compile per config."""

    def _finish(logits, seed, n_out, temp, top_k):
        return _finish_lane(logits, seed, n_out, temp, top_k, fused=fused,
                            with_rng=with_rng, with_topk=with_topk)

    def _step(view_params, toks, cache, tables, poss, seeds, nouts, temps,
              topks, li):
        rows, cache = serve_step_paged(view_params, cfg, toks[:, None],
                                       cache, tables, poss,
                                       license_intervals=li, kernel=kernel)
        return jax.vmap(_finish)(rows, seeds, nouts, temps, topks), cache

    # donate the cache operand: the pool's block arrays are updated IN
    # PLACE (absorb_decode adopts the outputs wholesale and the old
    # storage is dropped), so a step's one-token write never copies the
    # pool.  Without donation XLA would clone O(num_blocks) bytes per
    # step — more traffic than the gather/scatter path this replaces.
    # Backends without donation support (CPU) fall back to a copy.
    return jax.jit(_step, donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def _compiled_prefix_prefill(cfg: ModelConfig, fused: bool = False,
                             with_rng: bool = True, with_topk: bool = True):
    """Jitted lane-vmapped *suffix* prefill for prefix-cache hits.

    Per lane: ``tokens`` is the uncached tail of the prompt bucket padded
    on the right to the micro-batch's suffix width, ``pos`` the lane's
    cached-prefix length (the variable prefill offset), and ``last`` the
    row of the last real token (right padding means it is not row -1).
    One compilation per (config, suffix width, sampling variant); suffix
    widths are multiples of the block size minus nothing — at most
    ``prompt_blocks + 1`` distinct widths ever compile per config."""

    def _one(view_params, tokens, cache, pos, last, seed, n_out, temp,
             top_k, li):
        logits, cache = prefill_suffix_step(view_params, cfg, tokens[None],
                                            cache, pos,
                                            license_intervals=li)
        row = _finish_lane(logits[0, last], seed, n_out, temp, top_k,
                           fused=fused, with_rng=with_rng,
                           with_topk=with_topk)
        return row, cache

    return jax.jit(jax.vmap(_one,
                            in_axes=(None, 0, 0, 0, 0, 0, 0, 0, 0, None)))


class LicensedGateway:
    """Continuous-batching serving gateway with per-tier licensed views.

    Parameters
    ----------
    cfg, params:
        Model config and raw (float) weights, as for ``ServingEngine``.
    tiers:
        Name -> :class:`LicenseTier`; ``"full"`` is always available.
        Unknown tiers are also resolved against ``server`` when attached.
    quantized:
        Serve from ONE int8 store with license masks fused into the
        in-scan dequant (see ``serving/quantized.py``).
    already_quantized:
        ``params`` is already an int8 store (used by
        ``ServingEngine.gateway()``); implies ``quantized``.
    materialize_int8_views:
        int8 mode only: run the fused masked-dequant once per
        (tier, version) and cache full-precision licensed views.
    max_batch:
        Lanes per micro-batch (the vmap width).
    max_prompt:
        Maximum prompt length; longer prompts are rejected at admission.
        Under chunked prefill (default) every prompt is *left-aligned* —
        its absolute positions run from 0, independent of other lanes —
        so logits match an unpadded run of the same prompt.  Under the
        legacy bucket path (``chunk_size=0``) shorter prompts are
        right-aligned into the bucket with repeated-first-token padding,
        so absolute positions (and therefore logits) match a
        ``ServingEngine`` group padded to the same width instead.
    max_new_cap:
        Decode budget per lane; ``max_new_tokens`` is clamped to it.
    paged:
        Use the block-paged cache pool (default).  ``False`` selects the
        seed contiguous ``CachePool`` — the fallback config every
        pre-paging behavior maps onto.
    block_size / num_blocks / max_lanes / watermark_blocks:
        Paged-pool geometry.  ``num_blocks`` defaults to full
        provisioning (``max_lanes * ceil(capacity/block_size)`` — equal
        memory to the contiguous pool at ``max_lanes == max_batch``, and
        preemption-free); size it smaller to oversubscribe.  Admission
        requires ``watermark_blocks`` free blocks above a prefill's
        need, reserving decode-growth headroom.
    prefix_cache:
        Retain finished prompts' block chains in a (tier, version)-scoped
        radix cache (``serving/prefix.py``) and serve later requests'
        shared prefixes from them: prefill runs only on the uncached
        suffix (per-lane variable offsets), shared blocks are adopted by
        reference, and decode copy-on-writes a shared tail block before
        its first write into it.  Retained chains with no live request
        are evicted LRU-first whenever admission or decode growth needs
        blocks, so retention never shrinks the usable pool.  Paged mode
        only; auto-disabled (with ``prefix_cache=True`` silently inert)
        when any per-lane cache state is not a reconstructible position
        counter — SSM/RG-LRU state and sliding-window ring caches cannot
        be seeded from blocks.  ``False`` restores PR 2 behavior exactly.
    chunk_size:
        Left-aligned chunked prefill: each prefill action advances every
        PREFILLING lane by up to ``chunk_size`` prompt tokens, and chunk
        actions strictly alternate with decode steps — a long prompt
        never stalls in-flight decodes for more than one chunk, and the
        radix cache keys on true token ids so prefix reuse crosses
        prompt-length boundaries.  Default (None): the pool's
        ``block_size`` when supported (paged pool with reconstructible
        lane state — the ``prefix_cache`` condition), else 0.  ``0``
        forces the legacy right-aligned bucket prefill; an explicit
        positive value on an unsupported model raises.  Values above
        ``max_prompt`` are clamped.  Smaller chunks bound decode stalls
        tighter at the cost of more prefill step launches — this is the
        latency-SLO knob.
    kernel_decode:
        Kernel-resident paged decode (default auto).  Decode runs as ONE
        batched step whose cache operands are the pool's physical block
        arrays: attention reads each cache byte exactly once through the
        micro-batch's trimmed block tables, and the new K/V token is a
        block-indexed scatter — the per-step gather/scatter round trip of
        each lane's full logical cache disappears (it survives only for
        prefill, CoW copies, and the constant-size SSM/LRU lane state).
        Auto-disabled (clean fallback to gather/scatter decode) for
        sliding-window models, whose ring caches are per-lane state, and
        moot for pure-recurrent models (contiguous pool).  ``False``
        restores the PR 3 decode path exactly.
    decode_pallas:
        How the kernel-resident step reads the cache: ``"pallas"`` routes
        attention through the scalar-prefetch Pallas kernel
        (``kernels/paged_attention.py``), ``"interpret"`` the same kernel
        in interpret mode (CPU testing), ``"off"`` the pure-JAX
        block-gather fallback with identical semantics.  Default (None)
        picks "pallas" on TPU backends, "off" elsewhere.  int8-KV and
        MLA caches always use the fallback path.
    fuse_sampling:
        Sample per lane on device and return token ids (default).
        ``False`` is the return-logits escape hatch: logits rows come
        back to the host and are sampled there (identical tokens).
    record_logits:
        Keep each emitted step's logits row on the request
        (``req.logits_rows``) for equivalence tests; implies
        ``fuse_sampling=False``.
    """

    def __init__(self, cfg: ModelConfig, params: Any, **kw):
        # all serving state lives on a ModelSlot (serving/fleet.py) so a
        # FleetGateway can compose many models behind one loop; the
        # __getattr__/__setattr__ pair below forwards every slot
        # attribute, keeping this class's execution methods (and its
        # whole public surface) unchanged for single-model callers
        self.slot = ModelSlot(cfg, params, **kw)
        self.slot.gateway = self

    def __getattr__(self, name: str):
        # reached only when normal lookup fails: slot state (pool,
        # scheduler, views, stats, cfg, version, ...) resolves here
        slot = object.__getattribute__(self, "__dict__").get("slot")
        if slot is None:
            raise AttributeError(name)
        return getattr(slot, name)

    def __setattr__(self, name: str, value: Any) -> None:
        slot = self.__dict__.get("slot")
        if slot is not None and hasattr(slot, name):
            setattr(slot, name, value)
        else:
            object.__setattr__(self, name, value)

    def _note_retrace(self, family: str, key: Any) -> None:
        """Feed one jit-specialization key to the retracing sentinel
        (no-op unless the slot was built with ``sanitize=True``)."""
        if self.sanitizer is not None:
            self.sanitizer.retrace.note(family, key)

    def _steps(self, reqs: List[GatewayRequest]):
        """(prefill, decode) jitted pair specialized to this micro-batch's
        sampling needs; batches with no stochastic lane skip the
        categorical draw, batches with no top-k lane skip the sort."""
        if not self.fuse_sampling:
            self._note_retrace("steps", (False, False, False))
            return _compiled_steps(self.cfg, False)
        with_rng = any(r.temperature > 0 for r in reqs)
        with_topk = with_rng and any(r.top_k for r in reqs)
        self._note_retrace("steps", (True, with_rng, with_topk))
        return _compiled_steps(self.cfg, True, with_rng, with_topk)

    def _prefix_steps(self, reqs: List[GatewayRequest]):
        """Suffix-prefill jit specialized like :meth:`_steps`."""
        if not self.fuse_sampling:
            self._note_retrace("prefix_prefill", (False, False, False))
            return _compiled_prefix_prefill(self.cfg, False)
        with_rng = any(r.temperature > 0 for r in reqs)
        with_topk = with_rng and any(r.top_k for r in reqs)
        self._note_retrace("prefix_prefill", (True, with_rng, with_topk))
        return _compiled_prefix_prefill(self.cfg, True, with_rng, with_topk)

    def _paged_decode_step(self, reqs: List[GatewayRequest]):
        """Kernel-resident decode jit specialized like :meth:`_steps`."""
        if not self.fuse_sampling:
            self._note_retrace("paged_decode", (False, False, False))
            return _compiled_paged_decode(self.cfg, False,
                                          kernel=self.decode_pallas)
        with_rng = any(r.temperature > 0 for r in reqs)
        with_topk = with_rng and any(r.top_k for r in reqs)
        self._note_retrace("paged_decode", (True, with_rng, with_topk))
        return _compiled_paged_decode(self.cfg, True, with_rng, with_topk,
                                      kernel=self.decode_pallas)

    # ------------------------------------------------------------ weight views
    # (_resolve_tier / _materialize and the scheduler callbacks
    # _suffix_bucket / _suffix_bucket_fresh / _blocks_needed live on
    # ModelSlot — they are pure slot-state functions the slot wires into
    # its own TierViewCache and Scheduler at construction)
    def _refresh_server_tiers(self) -> None:
        """Re-pull tiers learned from the server.

        A redefined tier (an operator tightening 'free' on a live
        gateway) or a revoked one must not keep serving its old masks —
        but in-flight requests are never re-masked mid-generation, so
        the change is *deferred* until the tier's current requests
        drain.  While a revocation OR redefinition is pending, new
        admissions to the tier are rejected: nothing new may be served
        under the superseded masks, and with no new joiners the tier
        drains (and the change lands) in bounded time.

        Under a wire fault the refresh *defers* rather than fails: the
        current tiers keep serving (the DEGRADED-lease contract) and the
        stale flag re-runs this on the next lease restore."""
        touched = False
        for name in list(self._server_tiers):
            try:
                fresh = self.retry_policy.run(
                    lambda n=name: self._transport.tier(self.model, n),
                    on_retry=self._count_wire_retry)
                touched = True
            except KeyError:
                fresh = None                       # revoked server-side
                touched = True
            except TransportError:
                self._tiers_stale = True
                if touched:
                    self._lease_renew()
                self._apply_pending_tiers()
                return
            cur = self.tiers.get(name)
            if fresh is not None and cur is not None and fresh.masks == cur.masks:
                self._pending_tiers.pop(name, None)
                continue
            self._pending_tiers[name] = fresh
        if touched:
            self._lease_renew()
        self._tiers_stale = False
        self._apply_pending_tiers()

    def _tier_in_flight(self, name: str) -> bool:
        return (any(r.license == name for r in self.scheduler.waiting)
                or any(r.license == name for r in self.scheduler.running))

    def _apply_pending_tiers(self) -> None:
        for name, fresh in list(self._pending_tiers.items()):
            if self._tier_in_flight(name):
                continue                           # defer until drained
            if fresh is None:
                self.tiers.pop(name, None)
                self._server_tiers.discard(name)
                if self.obs:
                    self.audit.record("tier_revoke", model=self.model,
                                      tier=name)
            else:
                self.tiers[name] = fresh
                if self.obs:
                    self.audit.record("tier_redefine", model=self.model,
                                      tier=name,
                                      fingerprint=fresh.fingerprint())
            self.views.invalidate(tier=name)
            if self.prefix is not None:
                # cached blocks encode the old mask's activations
                self.prefix.drop_scope(tier=name)
            del self._pending_tiers[name]

    def view_for(self, tier: str, version: Optional[int] = None):
        """Licensed weight view for (tier, version) — cached."""
        return self.views.get(tier, self.version if version is None else version)

    # ------------------------------------------------------------ telemetry
    def _span(self, req: GatewayRequest, name: Optional[str],
              attrs: Optional[Dict[str, Any]] = None) -> None:
        """Close the request's open lifecycle span and begin ``name``
        (None = just close).  Per-request lifecycle phases (queue ->
        prefill -> decode) are sequential, never nested, so one slot per
        request suffices and every B gets its E."""
        if self.obs:
            if req._open_span is not None:
                self.tracer.end(req._open_span, req.rid)
            if name is not None:
                self.tracer.begin(name, req.rid, attrs)
        req._open_span = name

    def _note_admission(self, req: GatewayRequest) -> None:
        """Record a request leaving the queue for a lane: queue-wait
        histogram (first admission only — a restart's wait is preemption
        recovery, not admission wait), admit/restart instant, and the
        prefill lifecycle span."""
        if not self.obs:
            return
        now = self.clock()
        if req.preemptions == 0:
            self.h_queue.observe(now - req.submit_t)
            name = "admit"
        else:
            name = "restart"
        self.tracer.instant(name, req.rid,
                            {"tier": req.license, "version": req.version,
                             "lane": req.lane})
        self._span(req, "prefill", {"tier": req.license,
                                    "version": req.version})

    def _note_first_token(self, req: GatewayRequest, now: float) -> None:
        """First token of a (possibly restarted) prefill: TTFT is counted
        ONCE per request — a preemption clears ``first_token_t`` but not
        ``_ttft_done``, so the restart's re-emission never double-counts."""
        req.first_token_t = now
        if not self.obs:
            return
        if not req._ttft_done:
            req._ttft_done = True
            self.h_ttft.observe(now - req.submit_t)
        self._span(req, "decode")

    def render_prometheus(self) -> str:
        """Prometheus text exposition of every registered instrument."""
        return self.telemetry.render_prometheus()

    def chrome_trace(self) -> str:
        """This gateway's event tape as Chrome trace_event JSON."""
        return self.tracer.chrome_trace(
            process_name=self.model or "gateway")

    def audit_events(self, event: Optional[str] = None) -> List[Dict[str, Any]]:
        """The licensing audit stream (optionally filtered by event)."""
        return self.audit.events(event)

    # -------------------------------------------------------------- admission
    def _reject(self, req: GatewayRequest, error: str) -> GatewayRequest:
        req.state = RequestState.REJECTED
        req.error = error
        self.stats["rejected"] += 1
        if self.obs:
            self.tracer.instant("reject", req.rid,
                                {"tier": req.license, "reason": error})
        return req

    def submit(self, prompt, *, license: str = "full", max_new_tokens: int = 16,
               temperature: float = 0.0, top_k: int = 0,
               seed: int = 0, tenant: Optional[str] = None) -> GatewayRequest:
        """Admit one request: validate the tier, pin the weight version.
        ``tenant`` is carried for fleet accounting — quota enforcement
        itself lives in ``FleetGateway.submit`` (a standalone gateway
        records but never polices it)."""
        req = GatewayRequest(
            prompt=np.asarray(prompt, np.int32).reshape(-1),
            max_new_tokens=min(int(max_new_tokens), self.max_new_cap),
            license=license, model=self.model, tenant=tenant,
            # snap sub-epsilon temperatures to greedy: the fused sampler
            # clamps its divisor at 1e-6, so only the t <= 0 branch keeps
            # the fused and host paths token-identical down there
            temperature=0.0 if temperature <= 1e-6 else temperature,
            # top_k >= vocab truncates nothing; clamping keeps the host
            # sampler (lax.top_k needs k <= vocab) and the fused sampler
            # (clips its kth index) on identical behavior
            top_k=min(max(0, int(top_k)), self.cfg.padded_vocab), seed=seed,
        )
        if self.record_logits:
            req.logits_rows = []
        req.rid = self._next_rid
        self._next_rid += 1
        req.submit_t = self.clock()
        try:
            serve_as, lease_err = self._lease_admission(license)
            if lease_err is not None:
                raise KeyError(lease_err)
            if serve_as != license:
                # OFFLINE floor policy: serve the most restrictive
                # locally-known tier instead of an unverifiable grant
                if self.obs:
                    self.tracer.instant("lease_floor", req.rid,
                                        {"requested": license,
                                         "served_as": serve_as})
                license = serve_as
                req.license = serve_as
            if license in self._pending_tiers:
                # a pending revocation OR redefinition refuses admissions:
                # serving new requests under the superseded masks while
                # in-flight ones drain would let an observer see (old
                # tier, new version) — the mixed state the atomic flip
                # exists to rule out.  The tier drains in bounded time
                # precisely because nothing new joins it.
                verb = ("revoked" if self._pending_tiers[license] is None
                        else "redefined; retry once in-flight requests "
                             "drain")
                raise KeyError(f"license tier {license!r} is being {verb}")
            self._resolve_tier(license)
        except KeyError as e:
            return self._reject(req, str(e))
        if not 1 <= len(req.prompt) <= self.max_prompt:
            return self._reject(req, f"prompt length {len(req.prompt)} "
                                     f"outside [1, {self.max_prompt}]")
        if req.max_new_tokens < 1:
            return self._reject(req, "max_new_tokens < 1")
        if not -2**31 <= int(seed) < 2**31:
            # seeds ride the fused sampler as an int32 lane array; an
            # out-of-range one must bounce here, not crash the run() loop
            return self._reject(req, f"seed {seed} outside int32 range")
        req.version = self.version
        self.scheduler.submit(req)
        self.stats["admitted"] += 1
        if self.obs:
            self.tracer.instant(
                "submit", req.rid,
                {"tier": req.license, "version": req.version,
                 "model": self.model, "tenant": req.tenant,
                 "prompt_tokens": len(req.prompt),
                 "max_new_tokens": req.max_new_tokens})
            self._span(req, "queue")
        return req

    # ------------------------------------------------------------- scheduling
    def step(self, *, drive_stager: bool = True) -> Optional[ScheduledAction]:
        """Run ONE scheduler iteration (one prefill or decode micro-batch),
        plus — when a staged weight sync is active — ONE bounded stager
        step, so a version bump's work rides along with serving instead of
        ever stalling it.  A ``FleetGateway`` passes
        ``drive_stager=False`` and advances at most one slot's stager
        per fleet iteration itself."""
        act = self.scheduler.next_action()
        if act is not None:
            act.model = self.model
            t0 = self.clock() if self.obs else 0.0
            if act.kind == "prefill":
                if self.chunked:
                    self._run_chunked_prefill(act)
                else:
                    self._run_prefill(act)
            else:
                self._run_decode(act)
            if self.obs:
                t1 = self.clock()
                (self.h_prefill if act.kind == "prefill"
                 else self.h_decode).observe(t1 - t0)
                attrs: Dict[str, Any] = {"tier": act.tier,
                                         "version": act.version,
                                         "batch": len(act.requests)}
                if act.suffix_bucket is not None:
                    attrs["suffix_bucket"] = act.suffix_bucket
                self.tracer.complete("sched:" + act.kind, t0, t1,
                                     attrs=attrs)
                self.tracer.counter("queue_depth",
                                    len(self.scheduler.waiting))
                self.tracer.counter("running",
                                    len(self.scheduler.running))
                if self.paged:
                    self.tracer.counter("blocks_held",
                                        self.pool.allocator.num_held)
        if drive_stager and self._stager is not None and self._stager.active:
            try:
                self._stager.step()
            except TransportError:
                # retries exhausted: the stager aborted inside step()
                # (staged weights dropped, failure counted toward
                # quarantine) — serving continues on the current version
                pass
        if self._server is not None:
            self._lease_tick()
        if self.sanitizer is not None and act is not None:
            self.sanitizer.after_step(self)
        if act is None:
            return None
        # a decode whose whole batch was preempted executed nothing —
        # keep the trace invariant that every entry covers >= 1 request
        if act.requests:
            self.trace.append((act.kind, act.tier, act.version,
                               len(act.requests)))
        return act

    def run(self, max_steps: int = 1_000_000) -> List[GatewayRequest]:
        """Drain the queue; returns requests completed during this call.
        An active staged sync keeps stepping after the queue empties, so
        returning from ``run`` implies any begun version flip landed."""
        drained: List[GatewayRequest] = []
        self._drain_sink = drained
        try:
            for _ in range(max_steps):
                if self.step() is None and not self.sync_active:
                    if self.sanitizer is not None:
                        # queue and lanes are empty: anything still held
                        # must be reachable via the prefix tree
                        self.sanitizer.check_drained(self)
                    break
        finally:
            self._drain_sink = None
        return drained

    def _sampling_lanes(self, reqs, width: Optional[int] = None):
        """Per-lane (seed, n_generated, temperature, top_k) arrays for the
        fused sampler; padding lanes sample junk that is discarded.
        ``width`` defaults to ``max_batch``; the chunked-prefill path
        passes its trimmed vmap width."""
        width = self.max_batch if width is None else width
        seeds = np.zeros(width, np.int32)
        nouts = np.zeros(width, np.int32)
        temps = np.zeros(width, np.float32)
        topks = np.zeros(width, np.int32)
        for i, r in enumerate(reqs):
            seeds[i] = r.seed
            nouts[i] = len(r.out_tokens)
            temps[i] = r.temperature
            topks[i] = r.top_k
        return (jnp.asarray(seeds), jnp.asarray(nouts), jnp.asarray(temps),
                jnp.asarray(topks))

    def _alloc_blocks(self, n: int) -> List[int]:
        """Allocate ``n`` blocks, reclaiming retained prefix chains (LRU)
        if the free list alone can't cover it.  The scheduler's admission
        budget counts reclaimable blocks, so this must succeed for any
        admitted prefill.  Under a fleet the global byte budget is
        settled first: admission counted fleet-wide reclaimable bytes,
        so cross-slot eviction must be able to make strict room."""
        if self.fleet is not None:
            assert self.fleet._ensure_headroom(self, n), \
                "scheduler admitted past the fleet cache budget"
        got = self.pool.allocator.alloc(n)
        if got is None and self.prefix is not None:
            self.prefix.evict(n - self.pool.allocator.num_free)
            got = self.pool.allocator.alloc(n)
        assert got is not None, "scheduler admitted past the block budget"
        return got

    def _decref_block(self, b: int) -> None:
        """Drop one request reference, keeping the prefix cache's O(1)
        reclaimable counter exact: when exactly one reference survives
        and it is the tree's, the block just became evictable."""
        if self.pool.allocator.decref(b) == 1 and self.prefix is not None:
            self.prefix.note_release(b)

    def _release_blocks(self, req: GatewayRequest) -> None:
        """Drop the request's reference on every block it holds.  Private
        blocks return to the free list; blocks shared with the prefix
        cache (or another request) stay alive under the remaining refs —
        release, not free, is what makes retention safe."""
        for b in req.blocks:
            self._decref_block(b)
        req.blocks = []

    def _scatter_tables(self, tables: np.ndarray,
                        reqs: List[GatewayRequest]) -> np.ndarray:
        """Write-back tables with every *shared* block redirected to the
        null block.  Shared blocks are immutable: a prefix-cached prefill
        re-writes identical gathered bytes and the one recomputed token of
        a fully-matched prompt, decode re-writes untouched rows — all
        redundant, and redirecting them keeps retained chains bit-stable
        under concurrent readers (decode CoWs before any real write)."""
        out = tables.copy()
        alloc = self.pool.allocator
        n_cols = out.shape[1]              # chunked prefill trims columns
        for i, r in enumerate(reqs):
            for j, b in enumerate(r.blocks[:n_cols]):
                if alloc.refcount(b) > 1:
                    out[i, j] = self.pool.null_block
        return out

    def _run_prefill(self, act: ScheduledAction) -> None:
        view_params, li = self.views.get(act.tier, act.version)
        reqs = act.requests
        toks = right_align([r.prompt for r in reqs], self.max_prompt,
                           self.max_batch)
        seeds, nouts, temps, topks = self._sampling_lanes(reqs)
        # longest-cached-prefix lookup (before any allocation: matching
        # increfs the chains, so eviction under this batch's own pressure
        # can never free a block another lane is about to adopt).  The
        # prompt row is the *padded* bucket — identical rows mean identical
        # absolute positions, the condition for KV reuse under RoPE.
        scope = (act.tier, act.version)
        matches: List[Tuple[List[int], int]] = []
        if self.prefix is not None:       # paged-only by construction
            for i in range(len(reqs)):
                blocks, ntok = self.prefix.match(scope, toks[i])
                # always recompute >= 1 token: the first sampled token
                # needs the last prompt position's logits
                capped = min(ntok, self.max_prompt - 1)
                if capped == 0 and blocks:
                    # the cap zeroed a real match (max_prompt == 1): the
                    # chain is unusable — release the match's references
                    for b in blocks:
                        self._decref_block(b)
                    blocks = []
                matches.append((blocks, capped))
        hit = any(n > 0 for _, n in matches)
        if hit:
            lanes = [self.scheduler.start(r) for r in reqs]
            for r in reqs:
                self._note_admission(r)
            outs = self._run_prefix_prefill(
                act, toks, matches, lanes, view_params, li,
                (seeds, nouts, temps, topks))
        else:
            prefill, _ = self._steps(reqs)
            outs, lane_caches = prefill(view_params, jnp.asarray(toks),
                                        self._zero_lanes, seeds, nouts,
                                        temps, topks, li)
            lanes = [self.scheduler.start(r) for r in reqs]
            for r in reqs:
                self._note_admission(r)
            if self.paged:
                for r in reqs:
                    r.blocks = self._alloc_blocks(self._prefill_blocks)
                self._note_block_use()
                tables = self.pool.pad_tables([r.blocks for r in reqs],
                                              self.max_batch)
                self.pool.scatter(self.pool.pad_lanes(lanes, self.max_batch),
                                  tables, lane_caches)
            else:
                self.pool.scatter(self.pool.pad_lanes(lanes, self.max_batch),
                                  lane_caches)
            self.stats["prefill_lane_tokens"] += self.max_prompt * len(reqs)
        self.stats["max_running"] = max(self.stats["max_running"],
                                        len(self.scheduler.running))
        if self.prefix is not None:
            # donate the prompt chains (full blocks + partial tail) so the
            # next same-prefix request prefills only its suffix
            for i, r in enumerate(reqs):
                self.prefix.insert(scope, toks[i],
                                   r.blocks[: self._prefill_blocks])
        outs = np.asarray(outs)
        now = self.clock()
        for i, r in enumerate(reqs):
            r.pos = self.max_prompt
            self._note_first_token(r, now)
            if self.fuse_sampling:
                self._emit(r, tok=int(outs[i]))
            else:
                self._emit(r, logits_row=outs[i])
        self.stats["prefill_batches"] += 1
        if act.suffix_bucket is not None:
            self.bucket_batches[act.suffix_bucket] = \
                self.bucket_batches.get(act.suffix_bucket, 0) + 1

    def _run_prefix_prefill(self, act: ScheduledAction, toks: np.ndarray,
                            matches: List[Tuple[List[int], int]],
                            lanes: List[int], view_params, li, sampling):
        """Prefill a micro-batch with >= 1 prefix-cache hit: every lane
        runs only its uncached suffix, at its own offset, in one vmapped
        step.

        Lanes share one (static) suffix width ``W = max(suffix lens)``;
        a lane whose suffix is shorter is padded on the *right* (its
        writes land beyond the prompt in its own decode region, masked
        by ``len`` until decode overwrites them) and its last real row is
        selected per lane.  Adopted blocks enter the table by reference;
        write-back redirects every shared block to the null block, so
        retained chains are never mutated."""
        reqs = act.requests
        seeds, nouts, temps, topks = sampling
        suffix = [self.max_prompt - n for _, n in matches]
        w = max(suffix)
        sub = np.zeros((self.max_batch, w), np.int32)
        poss = np.zeros(self.max_batch, np.int32)
        lasts = np.zeros(self.max_batch, np.int32)
        for i, r in enumerate(reqs):
            blocks, ntok = matches[i]
            sub[i, : suffix[i]] = toks[i, ntok:]
            sub[i, suffix[i]:] = toks[i, -1]       # right pad: junk region
            poss[i] = ntok
            lasts[i] = suffix[i] - 1
            fresh = self._alloc_blocks(self._prefill_blocks - len(blocks))
            r.blocks = list(blocks) + fresh
            r.prefix_tokens = ntok
            self.stats["prefix_tokens_reused"] += ntok
            if self.obs and ntok:
                self.tracer.instant("prefix_hit", r.rid, {"tokens": ntok})
        self.stats["prefill_lane_tokens"] += w * len(reqs)
        self._note_block_use()
        lane_ids = self.pool.pad_lanes(lanes, self.max_batch)
        tables = self.pool.pad_tables([r.blocks for r in reqs],
                                      self.max_batch)
        caches = self.pool.gather(lane_ids, tables, fresh_lane_state=True)
        prefill = self._prefix_steps(reqs)
        outs, lane_caches = prefill(view_params, jnp.asarray(sub), caches,
                                    jnp.asarray(poss), jnp.asarray(lasts),
                                    seeds, nouts, temps, topks, li)
        # the step's len accounting saw only W suffix tokens; pin the
        # counters to the true logical fill before they reach the pool
        lane_caches = self.pool.override_counters(lane_caches,
                                                  self.max_prompt)
        self.pool.scatter(lane_ids, self._scatter_tables(tables, reqs),
                          lane_caches)
        return outs

    # ------------------------------------------------------ chunked prefill
    def _run_chunked_prefill(self, act: ScheduledAction) -> None:
        """One chunked-prefill action: admit newly scheduled requests
        (adopt cached prefix blocks, allocate the rest, park the cursor
        past the reused tokens), then advance every member one
        ``chunk_size`` chunk.  An admission runs its first chunk in the
        same action, so a prompt no longer than one chunk still reaches
        its first token in a single step — the legacy one-step-prefill
        latency."""
        if act.requests[0].state is not RequestState.PREFILLING:
            self._admit_chunked(act)
        self._run_prefill_chunk(act)

    def _admit_chunked(self, act: ScheduledAction) -> None:
        """Admission half of a chunked prefill: prefix-match every
        prompt on its TRUE token ids (left alignment gives every prompt
        absolute positions from 0, so different-length prompts sharing
        a prefix share its blocks — the cross-length reuse padded
        bucket rows ruled out), then allocate the uncached remainder.
        Matching runs for the whole batch BEFORE any allocation:
        matching increfs the chains, so this batch's own allocation
        pressure can never evict a block another lane is about to
        adopt."""
        scope = (act.tier, act.version)
        reqs = act.requests
        matches: List[Tuple[List[int], int]] = []
        for r in reqs:
            if self.prefix is not None:
                blocks, ntok = self.prefix.match(scope, r.prompt)
            else:
                blocks, ntok = [], 0
            # always recompute >= 1 token: the first sampled token needs
            # the last prompt position's logits
            capped = min(ntok, len(r.prompt) - 1)
            if capped == 0 and blocks:
                # the cap zeroed a real match (1-token prompt): the
                # chain is unusable — release the match's references
                for b in blocks:
                    self._decref_block(b)
                blocks = []
            matches.append((blocks, capped))
        bs = self.pool.block_size
        for r, (blocks, capped) in zip(reqs, matches):
            self.scheduler.start(r, prefilling=True)
            self._note_admission(r)
            if self.obs and capped:
                self.tracer.instant("prefix_hit", r.rid, {"tokens": capped})
            # a partial match adopts only FULL blocks (the radix tree
            # matches a partial tail only when it covers the whole
            # prompt), so the uncached suffix starts on a block boundary
            # and chunk writes never touch a shared block: aligned tails
            # are CoW-free by construction
            fresh = self._alloc_blocks(
                max(0, cdiv(len(r.prompt), bs) - len(blocks)))
            r.blocks = list(blocks) + fresh
            r.cursor = capped
            r.prefix_tokens = capped
            self.stats["prefix_tokens_reused"] += capped
        self._note_block_use()
        self.stats["prefill_batches"] += 1
        self.stats["max_running"] = max(self.stats["max_running"],
                                        len(self.scheduler.running))

    def _run_prefill_chunk(self, act: ScheduledAction) -> None:
        """Advance every member by one left-aligned chunk.

        All lanes share the static ``chunk_size`` width; a lane with
        fewer tokens left is right-padded with junk whose writes land
        past its real rows — scattered to the null block beyond its
        table, or into private rows that the next chunk / first decode
        write overwrites and the ``len`` counter masks until then.  A
        lane whose cursor reaches the prompt end emits its first token
        (the last chunk's selected row is the last prompt position's
        logits) and enters decode."""
        view_params, li = self.views.get(act.tier, act.version)
        reqs = act.requests
        w = self.chunk_size
        bs = self.pool.block_size
        # trim the vmap width and the gathered table to what THIS chunk
        # can touch: a chunk step must move O(context) bytes, not
        # O(max_batch * capacity), or one chunk stalls decode far longer
        # than one decode step and the interleaving SLO is fiction.
        # Pow2 buckets bound the number of jit specializations to
        # log2(max_batch) * log2(blocks_per_lane).
        b = min(self.max_batch, _pow2(len(reqs)))
        # cols must cover cursor + w INCLUDING junk pad rows: the linear
        # attend-cache write clamps out-of-range slots onto the last one,
        # and a junk row colliding with the chunk's final real token
        # would corrupt the K/V its own last query attends.  Covering
        # the junk keeps every pad write on a distinct slot strictly
        # past the real rows (causally unattended, scattered to null).
        need = max(cdiv(r.cursor + w, bs) for r in reqs)
        cols = min(self.pool.blocks_per_lane, _pow2(need))
        self._note_retrace("prefill_chunk", (b, cols))
        sub = np.zeros((b, w), np.int32)
        poss = np.zeros(b, np.int32)
        lasts = np.zeros(b, np.int32)
        fills = np.zeros(b, np.int32)
        valid = np.zeros(len(reqs), np.int32)
        for i, r in enumerate(reqs):
            v = min(w, len(r.prompt) - r.cursor)
            valid[i] = v
            sub[i, :v] = r.prompt[r.cursor: r.cursor + v]
            sub[i, v:] = int(r.prompt[-1])     # right pad: junk region
            poss[i] = r.cursor
            lasts[i] = v - 1
            fills[i] = r.cursor + v
        seeds, nouts, temps, topks = self._sampling_lanes(reqs, b)
        lane_ids = self.pool.pad_lanes([r.lane for r in reqs], b)
        tables = self.pool.pad_tables([r.blocks[:cols] for r in reqs], b,
                                      n_cols=cols)
        # per-lane counters are pinned to the true fill below, and the
        # attend-cache step masks positionally — fresh lane state is
        # correct for EVERY chunk, not just the first
        caches = self.pool.gather(lane_ids, tables, fresh_lane_state=True)
        prefill = self._prefix_steps(reqs)
        outs, lane_caches = prefill(view_params, jnp.asarray(sub), caches,
                                    jnp.asarray(poss), jnp.asarray(lasts),
                                    seeds, nouts, temps, topks, li)
        lane_caches = self.pool.override_counters(lane_caches,
                                                  jnp.asarray(fills))
        self.pool.scatter(lane_ids, self._scatter_tables(tables, reqs),
                          lane_caches)
        self.stats["prefill_lane_tokens"] += w * len(reqs)
        self.stats["prefill_chunks"] += 1
        outs = np.asarray(outs)
        now = self.clock()
        scope = (act.tier, act.version)
        for i, r in enumerate(reqs):
            r.cursor += int(valid[i])
            if self.obs:
                self.tracer.instant("prefill_chunk", r.rid,
                                    {"cursor": r.cursor,
                                     "tokens": int(valid[i])})
            if r.cursor < len(r.prompt):
                continue
            r.state = RequestState.RUNNING
            r.pos = len(r.prompt)
            self._note_first_token(r, now)
            if self.prefix is not None:
                # donate the TRUE-token chain (full blocks + partial
                # tail) so any future prompt sharing the prefix — at any
                # length — adopts it
                self.prefix.insert(scope, r.prompt, r.blocks)
            if self.fuse_sampling:
                self._emit(r, tok=int(outs[i]))
            else:
                self._emit(r, logits_row=outs[i])

    def _try_alloc_one(self) -> Optional[int]:
        """One block from the free list, reclaiming retained prefix chains
        if needed — never preempts.  None when the pool is truly full.
        Under a fleet, the global byte budget gates first: when no
        retained chain anywhere can be reclaimed to cover one more of
        this slot's blocks, report exhaustion — the caller's
        within-slot youngest-preemption frees this slot's own bytes
        (never another model's)."""
        if (self.fleet is not None
                and not self.fleet._ensure_headroom(self, 1)):
            return None
        got = self.pool.allocator.alloc(1)
        if got is None and self.prefix is not None and self.prefix.evict(1):
            got = self.pool.allocator.alloc(1)
        return got[0] if got is not None else None

    def _grow_one(self, r: GatewayRequest,
                  keep: List[GatewayRequest]) -> Optional[int]:
        """One block for ``r``, trying free list, then prefix-cache
        eviction, then youngest-first preemption.  Returns the block id,
        or None if ``r`` itself was preempted to make room."""
        while True:
            got = self._try_alloc_one()
            if got is not None:
                return got
            victim = self.scheduler.youngest_running()
            if victim is r and len(self.scheduler.running) == 1:
                raise RuntimeError(
                    "block pool exhausted by a single request")
            self._preempt(victim)
            if victim in keep:
                keep.remove(victim)
            if victim is r:
                return None

    def _grow_block_tables(self, reqs: List[GatewayRequest]) \
            -> List[GatewayRequest]:
        """Give every request the block its next decode write needs, and a
        *private* copy of it when the block is shared.

        On pool exhaustion, first evict retained (request-free) prefix
        chains LRU-first, then preempt the youngest running request
        (release its block references, requeue it at the queue head) and
        retry; a victim inside this micro-batch is dropped from it.
        Terminates because the pool holds at least one full request
        (constructor guard), every eviction/preemption strictly drops
        references, and the oldest running request is never chosen while
        others run.

        Copy-on-write: this step writes position ``pos`` into block
        ``pos // bs``.  If that block is shared — the prompt tail donated
        to (or adopted from) the prefix cache — the request gets a fresh
        block holding a device copy and swaps its table entry; the shared
        original stays pristine for its other holders.
        """
        keep = list(reqs)
        if self.prefix is not None:
            # reclaim the batch's whole shortfall — growth blocks plus a
            # copy per shared write target (potential CoW) — in ONE
            # eviction pass instead of one tree walk per block; only
            # mid-pass churn falls back to _try_alloc_one's evict(1)
            need = 0
            for r in keep:
                if r.state != RequestState.RUNNING:
                    continue
                tail = r.pos // self.pool.block_size
                need += max(0, tail + 1 - len(r.blocks))
                if tail < len(r.blocks) and \
                        self.pool.allocator.refcount(r.blocks[tail]) > 1:
                    need += 1
            shortfall = need - self.pool.allocator.num_free
            if shortfall > 0:
                self.prefix.evict(shortfall)
        for r in list(keep):
            if r.state != RequestState.RUNNING:
                continue                   # preempted earlier in this pass
            needed = r.pos // self.pool.block_size + 1
            while len(r.blocks) < needed:
                b = self._grow_one(r, keep)
                if b is None:
                    break                  # r was preempted
                r.blocks.append(b)
            if r.state != RequestState.RUNNING:
                continue
            tail = needed - 1              # block receiving this step's write
            if self.pool.allocator.refcount(r.blocks[tail]) > 1:
                # shared write target: prefer a private copy, but with no
                # spare block (fully provisioned pool) steal the tree's
                # reference back instead — forfeiting one tail's future
                # hits beats preempting a running request for a copy
                b = self._try_alloc_one()
                if b is None:
                    if (self.prefix is not None
                            and self.pool.allocator.refcount(
                                r.blocks[tail]) == 2
                            and self.prefix.forget_block(r.blocks[tail])):
                        continue           # unshared now: write in place
                    b = self._grow_one(r, keep)
                    if b is None:
                        continue           # r itself was preempted
                self.pool.copy_block(r.blocks[tail], b)
                self._decref_block(r.blocks[tail])
                r.blocks[tail] = b
                self.stats["cow_copies"] += 1
        self._note_block_use()
        return keep

    def _preempt(self, req: GatewayRequest) -> None:
        self._release_blocks(req)
        # the restart will re-emit these tokens; keep the counter equal to
        # tokens actually delivered
        self.stats["tokens_generated"] -= len(req.out_tokens)
        if self.obs:
            self._span(req, None)
            self.tracer.instant("preempt", req.rid,
                                {"tokens_lost": len(req.out_tokens)})
        self.scheduler.preempt(req)
        if self.obs:
            # back at the queue head: the lifecycle re-enters its queue
            # phase until re-admission emits a "restart"
            self._span(req, "queue")
        self.stats["preempted"] += 1

    def _note_block_use(self) -> None:
        self.stats["max_blocks_in_use"] = max(
            self.stats["max_blocks_in_use"], self.pool.allocator.num_held)

    def _run_decode(self, act: ScheduledAction) -> None:
        if self.paged:
            act.requests = self._grow_block_tables(act.requests)
            if not act.requests:
                return                     # whole batch preempted
            if self.sanitizer is not None:
                # post-CoW: every table entry live, write targets private
                self.sanitizer.check_decode_writes(act.requests, self.pool)
        view_params, li = self.views.get(act.tier, act.version)
        reqs = act.requests
        lanes = self.pool.pad_lanes([r.lane for r in reqs], self.max_batch)
        toks = np.zeros(self.max_batch, np.int32)
        poss = np.zeros(self.max_batch, np.int32)
        for i, r in enumerate(reqs):
            toks[i] = r.out_tokens[-1]
            poss[i] = r.pos
        seeds, nouts, temps, topks = self._sampling_lanes(reqs)
        if self.paged and self.kernel_decode:
            # kernel-resident path: the pool's block arrays ARE the cache
            # operands.  Tables are trimmed to the batch's used width, so
            # attention reads O(context) bytes once through the table;
            # the one new K/V token per lane is written through its block
            # index (the target is private — _grow_block_tables CoW'd a
            # shared tail before this step), and shared prefix blocks are
            # never write targets, so no null-redirect is needed.
            used = max(r.pos // self.pool.block_size + 1 for r in reqs)
            self._note_retrace("decode_width", used)
            tables = self.pool.pad_tables([r.blocks[:used] for r in reqs],
                                          self.max_batch, used)
            caches = self.pool.decode_cache(lanes)
            step = self._paged_decode_step(reqs)
            outs, caches = step(view_params, jnp.asarray(toks), caches,
                                jnp.asarray(tables), jnp.asarray(poss),
                                seeds, nouts, temps, topks, li)
            self.pool.absorb_decode(lanes, caches)
            self.stats["resident_decode_steps"] += 1
        else:
            if self.paged:
                tables = self.pool.pad_tables([r.blocks for r in reqs],
                                              self.max_batch)
                caches = self.pool.gather(lanes, tables)
            else:
                caches = self.pool.gather(lanes)
            _, decode = self._steps(reqs)
            outs, caches = decode(view_params, jnp.asarray(toks), caches,
                                  jnp.asarray(poss), seeds, nouts, temps,
                                  topks, li)
            if self.paged:
                # shared (prefix-cache) blocks are read-only: redirect
                # their redundant write-back to the null block (the write
                # target itself is always private — CoW'd above)
                wb = (self._scatter_tables(tables, reqs)
                      if self.prefix is not None else tables)
                self.pool.scatter(lanes, wb, caches)
            else:
                self.pool.scatter(lanes, caches)
        outs = np.asarray(outs)
        for i, r in enumerate(reqs):
            r.pos += 1
            if self.obs:
                self.tracer.instant("decode_step", r.rid, {"pos": r.pos})
            if self.fuse_sampling:
                self._emit(r, tok=int(outs[i]))
            else:
                self._emit(r, logits_row=outs[i])
        self.stats["decode_steps"] += 1

    def _emit(self, req: GatewayRequest, tok: Optional[int] = None,
              logits_row: Optional[np.ndarray] = None) -> None:
        """Append one token (sampled on host from ``logits_row`` when the
        fused path is off) and retire the request if it is finished."""
        if tok is None:
            if req.logits_rows is not None:
                req.logits_rows.append(np.asarray(logits_row, np.float32))
            if req.temperature <= 0:
                tok = int(np.argmax(logits_row))
            else:
                # host side top_k is concrete -> the static sample() path
                # (skips sample_lane's traced-k sort); same tokens either way
                key = jax.random.fold_in(jax.random.PRNGKey(req.seed),
                                         len(req.out_tokens))
                tok = int(sample(jnp.asarray(logits_row)[None], key,
                                 temperature=req.temperature,
                                 top_k=req.top_k)[0])
        req.out_tokens.append(tok)
        self.stats["tokens_generated"] += 1
        if self.obs:
            # inter-token gap: decode cadence only.  The first token has
            # no predecessor, and a preemption clears ``_last_tok_t`` —
            # the restart's recovery pause is not a decode gap.
            now = self.clock()
            if req._last_tok_t is not None:
                self.h_gap.observe(now - req._last_tok_t)
            req._last_tok_t = now
        if len(req.out_tokens) >= req.max_new_tokens:
            self.scheduler.finish(req)
            if self.obs:
                self._span(req, None)
                self.tracer.instant("finish", req.rid,
                                    {"tokens": len(req.out_tokens),
                                     "preemptions": req.preemptions,
                                     "blocks": len(req.blocks)})
            if self.paged:
                # release references, don't free: blocks the prefix cache
                # retains (the prompt chain) survive for future hits
                self._release_blocks(req)
            self.completed.append(req)
            if self._drain_sink is not None:
                self._drain_sink.append(req)
            self.stats["completed"] += 1
            if self.on_finish is not None:
                # fleet tenant accounting (inflight release + usage)
                self.on_finish(req)
            self._gc_versions()

    # ---------------------------------------------------------- weight updates
    def update_weights(self, params: Any, *, version: Optional[int] = None,
                       already_quantized: bool = False) -> int:
        """Install new base weights under a new version.

        In-flight requests stay pinned to their admitted version; new
        admissions pin the new one.  Views for versions no longer pinned
        are invalidated once their last request drains.
        """
        if self.quantized and not already_quantized:
            from repro.serving.quantized import quantize_serving_params

            params = quantize_serving_params(params)
        version = self.version + 1 if version is None else int(version)
        if version < self.version:
            raise ValueError(f"version {version} is older than the current "
                             f"version {self.version}")
        if version in self._weights:
            # overwriting a live version: views built from the old weights
            # must not survive the swap — nor cached prefix activations
            self.views.invalidate(version=version)
            if self.prefix is not None:
                self.prefix.drop_scope(version=version)
        prev = self.version
        self._weights[version] = params
        self.version = version
        if self.obs:
            self.audit.record("version_install", model=self.model,
                              from_version=prev, to_version=version)
        self._gc_versions()
        return version

    def _gc_versions(self) -> None:
        live = self.scheduler.pinned_versions() | {self.version}
        if self._staging_version is not None:
            # a staged sync pre-registers the incoming version (and may
            # have prewarmed its views) before any request pins it
            live.add(self._staging_version)
        for v in [v for v in self._weights if v not in live]:
            del self._weights[v]
            self.views.invalidate(version=v)
            if self.prefix is not None:
                self.prefix.drop_scope(version=v)
        if self._pending_tiers:
            self._apply_pending_tiers()

    # ------------------------------------------------------- protocol plumbing
    @classmethod
    def from_server(cls, cfg: ModelConfig, server, model: str, template: Any,
                    transport: Optional[Transport] = None,
                    retry: Any = None, **kw) -> "LicensedGateway":
        """Boot a gateway as an edge serving pod of ``server`` (Fig. 2).

        ``template`` is a zeroed params pytree; the full production
        snapshot is pulled through the §3.1.2 delta protocol, and
        :meth:`sync` keeps pulling increments from then on.  An explicit
        ``transport`` routes every wire call (boot pull included) through
        it — a ChaosTransport here exercises the whole path; ``retry``
        overrides the gateway's RetryPolicy."""
        from repro.core.protocol import EdgeClient

        client = EdgeClient(model, template, license_name="full")
        client.request_update(transport if transport is not None else server,
                              retry=retry)
        gw = cls(cfg, client.params, server=server, model=model,
                 version=client.version, transport=transport,
                 **({} if retry is None else {"retry_policy": retry}), **kw)
        gw._client = client
        return gw

    def _register_staging(self, version: int, params: Any) -> None:
        """Pre-register a staged version's serving params so its views can
        be prewarmed before the flip.  ``_gc_versions`` keeps the staging
        version alive even though nothing pins it yet."""
        if version in self._weights:
            # overwriting a live version's weights: views (and cached
            # prefix activations) built from the old bytes must not
            # survive into the prewarm
            self.views.invalidate(version=version)
            if self.prefix is not None:
                self.prefix.drop_scope(version=version)
        self._staging_version = version
        self._weights[version] = params

    def _install_staged(self, version: int) -> None:
        """The stager's atomic flip: bump the served version AND apply tier
        redefinitions published alongside it, in one step with no
        scheduler iteration in between.  Prewarmed views survive (no
        invalidation here); in-flight requests stay pinned to the version
        they were admitted under."""
        assert version == self._staging_version, (version,
                                                  self._staging_version)
        if version < self.version:
            raise ValueError(f"version {version} is older than the current "
                             f"version {self.version}")
        prev = self.version
        self.version = version
        self._staging_version = None
        if self.obs:
            # the ONE choke point every flip funnels through — staged
            # step()-driven syncs and blocking sync() alike — so the
            # audit stream shows exactly one version_flip per bump
            self.audit.record("version_flip", model=self.model,
                              from_version=prev, to_version=version)
        if self._server is not None:
            # tier redefinitions land with the bump — an admission never
            # sees (new tiers, old version) or (old tiers, new version)
            self._refresh_server_tiers()
        self._gc_versions()

    def begin_sync(self, server: Any = None, **stager_kw) -> bool:
        """Start a *staged* (non-blocking) sync against the license server.

        Returns True when a newer production version exists and a staging
        session began — subsequent :meth:`step` calls each carry one
        bounded unit of fetch/apply/requantize/prewarm work and the new
        version flips in atomically at a step boundary.  Returns False
        when the client is already current (tier-only redefinitions are
        applied immediately — there is no flip to couple them to).  A
        sync already in progress is left to finish (returns True).  A
        wire fault that outlives the retry budget during the probe
        returns False — the gateway keeps serving and the caller may
        try again later."""
        server = server or self._server
        if server is None or self._client is None:
            raise RuntimeError("gateway was not booted with from_server()")
        if self._stager is not None and self._stager.active:
            return True
        from repro.serving.updates import UpdateStager

        stager = UpdateStager(self, server, **stager_kw)
        try:
            if stager.begin():
                self._stager = stager
                return True
        except TransportError:
            pass
        return False

    def sync_step(self) -> Optional[str]:
        """Advance an active staged sync by one bounded unit (for callers
        driving the stager without scheduler traffic); returns the phase
        that executed, or None when no sync is active."""
        if self._stager is None or not self._stager.active:
            return None
        return self._stager.step()

    @property
    def sync_active(self) -> bool:
        return self._stager is not None and self._stager.active

    def sync(self, server: Any = None, **stager_kw) -> bool:
        """Pull newer production weights (and tier redefinitions) from the
        license server — blocking, but through the same staged machinery
        as :meth:`begin_sync`, so the weights + tier flip is atomic either
        way.

        Returns True if a new weight version was installed (and pinned for
        all subsequent admissions)."""
        flipped = False
        while self.sync_active:           # finish a staged sync first
            self._stager.step()
            flipped = True
        if not self.begin_sync(server, **stager_kw):
            return flipped
        while self.sync_active:
            self._stager.step()
        return True

    # ---------------------------------------------------------------- metrics
    def _tenant_breakdown(self) -> Dict[str, Dict[str, int]]:
        """Per-tenant usage on THIS slot: live requests (queued/running),
        tokens generated, cache blocks held, completions in the history
        window.  Tenant-less requests are not listed."""
        out: Dict[str, Dict[str, int]] = {}

        def _d(t: str) -> Dict[str, int]:
            return out.setdefault(t, {
                "inflight": 0, "queued": 0, "completed": 0,
                "tokens_generated": 0, "blocks_held": 0})

        for r in self.scheduler.running:
            if r.tenant is None:
                continue
            d = _d(r.tenant)
            d["inflight"] += 1
            d["blocks_held"] += len(r.blocks)
            d["tokens_generated"] += len(r.out_tokens)
        for r in self.scheduler.waiting:
            if r.tenant is None:
                continue
            d = _d(r.tenant)
            d["inflight"] += 1
            d["queued"] += 1
        for r in self.completed:
            if r.tenant is None:
                continue
            d = _d(r.tenant)
            d["completed"] += 1
            d["tokens_generated"] += len(r.out_tokens)
        return out

    def metrics(self) -> Dict[str, Any]:
        """Counters, queue-wait ages, pool occupancy, latency percentiles.
        ``oldest_wait_s``/``queue_wait_by_tier`` come from this slot's
        OWN scheduler queue — under a fleet each slot reports its own
        fairness ages, never another model's backlog."""
        out: Dict[str, Any] = dict(self.stats)
        out["model"] = self.model
        out["view_cache"] = self.views.stats()
        out["oldest_wait_s"] = self.scheduler.oldest_wait_s()
        out["queue_wait_by_tier"] = self.scheduler.queue_wait_by_tier()
        out["tenants"] = self._tenant_breakdown()
        out["cache_pool"] = {"paged": self.paged, **self.pool.stats()}
        out["decode_path"] = {"kernel_resident": self.kernel_decode,
                              "pallas": self.decode_pallas}
        out["staged_update"] = ({"active": False} if self._stager is None
                                else {"active": self._stager.active,
                                      **self._stager.stats()})
        out["chunked_prefill"] = {
            "enabled": self.chunked, "chunk_size": self.chunk_size,
            # prefill actions executed (one chunk each); decode steps
            # never wait longer than one of these
            "chunks": self.stats["prefill_chunks"]}
        out["admission_grouping"] = {
            # suffix-width bucketing is the LEGACY bucket-prefill
            # grouping; chunked mode admits per true prompt length
            "enabled": self.prefix is not None and not self.chunked,
            # prefill batches served per shared uncached-suffix width: a
            # full-match batch shows up under width 1, never padded to a
            # cold batch's max_prompt
            "batches_by_suffix_width": dict(self.bucket_batches)}
        out["lease"] = {
            "state": self._lease_state,
            "server_attached": self._server is not None,
            "ttl_s": self.lease_ttl_s,
            "grace_s": self.lease_grace_s,
            "policy": self.lease_policy,
            "renew_age_s": self.clock() - self._lease_renewed_t,
            "degraded_seconds_total": self.degraded_seconds_total(),
            "quarantined_versions": sorted(self.quarantined_versions),
            "pinned_views": len(self.scheduler.pinned_tier_versions()),
        }
        out["prefix_cache"] = {"enabled": self.prefix is not None}
        if self.prefix is not None:
            out["prefix_cache"].update(self.prefix.stats())
            out["prefix_cache"]["prefix_tokens_reused"] = \
                self.stats["prefix_tokens_reused"]
            out["prefix_cache"]["cow_copies"] = self.stats["cow_copies"]
        out["latency"] = {
            "ttft_s": self.h_ttft.summary(),
            "inter_token_s": self.h_gap.summary(),
            "queue_wait_s": self.h_queue.summary(),
            "step_prefill_s": self.h_prefill.summary(),
            "step_decode_s": self.h_decode.summary(),
            "stager_step_s": self.h_stager.summary(),
        }
        lats = [r.latency for r in self.completed if r.latency is not None]
        if lats:
            out["latency_p50_ms"] = float(np.percentile(lats, 50) * 1e3)
            out["latency_p99_ms"] = float(np.percentile(lats, 99) * 1e3)
        return out
