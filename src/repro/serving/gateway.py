"""Licensed serving gateway: continuous batching over tier-keyed weight views.

This is the serving front end the ROADMAP's "heavy traffic" north star
needs on a single device: requests tagged with a ``LicenseTier`` stream
in, the :class:`~repro.serving.scheduler.Scheduler` groups them into
tier-homogeneous micro-batches, and every batch is served through a
**(tier, version)-keyed cache of masked weight views** — the paper's
one-stored-model-many-tiers claim (§3.5) amortized across requests
instead of paid per request.

Execution model
---------------
Two jitted functions, each compiled once per gateway:

* ``prefill``: ``vmap`` over ``max_batch`` lanes of a batch-1
  ``prefill_step`` with a fixed prompt bucket (``max_prompt``); short
  prompts are right-aligned with repeated-first-token padding (same
  trick as ``ServingEngine``).
* ``decode``: ``vmap`` over lanes of a batch-1 ``serve_step`` where the
  absolute position is *per lane* — this is what makes the batching
  continuous: lanes at different depths (different requests' positions)
  decode together, and a finished lane is refilled by the next prefill
  without draining the batch.

Both take the weight view as an argument, so one compilation serves
every tier and weight version.  By default each lane's logits feed a
**fused on-device sampling step** (``engine.sample_lane``) so a decode
step ships one token id per lane device->host instead of a full logits
row; ``fuse_sampling=False`` (or ``record_logits=True``) is the
return-logits escape hatch tests and the equivalence benchmark use.

Cache memory
------------
KV/SSM state lives in a shared pool gathered/scattered around each
micro-batch.  Two pool modes, selected by the ``paged`` config flag:

* ``paged=True`` (default): a :class:`~repro.serving.paging.PagedCachePool`
  — per-token KV leaves live as fixed-size physical blocks addressed
  through per-request block tables, so short and long requests share the
  pool without over-reserving, and ``max_lanes`` (concurrency) decouples
  from ``max_batch`` (vmap width).  Admission is gated on free *blocks*
  (plus a watermark); if decode exhausts the pool, the **youngest**
  running request is preempted back to the queue head (recompute-style —
  generation is deterministic per (seed, prompt, view), so the restart
  reproduces its tokens).  Models with no per-token cache (pure SSM)
  fall back to the contiguous pool automatically.
* ``paged=False``: the seed fixed-slab :class:`CachePool`, one
  ``capacity``-token lane per ``max_batch`` slot.

Licensing integration
---------------------
* float path: the view is ``apply_license(base, tier)`` — masking cost
  paid once per (tier, version), cached in :class:`TierViewCache`;
* int8 path (``quantized=True``): ONE int8 store serves every tier and
  the view is just the tier's packed license intervals, fused into the
  in-scan masked dequant (``kernels/masked_dequant`` semantics); with
  ``materialize_int8_views=True`` the gateway instead runs the fused
  masked-dequant kernel once per (tier, version) and caches the
  full-precision licensed view — trading memory for per-step speed on
  long decode streams.
* protocol: :meth:`LicensedGateway.from_server` boots the gateway from a
  ``LicenseServer`` via the §3.1.2 delta protocol (an internal
  ``EdgeClient`` holds the raw weights); :meth:`sync` pulls newer
  production weights and bumps the gateway's weight version.  Admission
  validates the tier (locally or against the server) and pins the
  request to the current version, so in-flight requests are never
  re-masked mid-generation; stale versions and their views are dropped
  once the last pinned request drains.
"""
from __future__ import annotations

import functools
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.licensing import FULL_TIER, LicenseTier, apply_license
from repro.models import model as model_lib
from repro.serving.engine import (prefill_step, right_align, sample,
                                  sample_lane, serve_step)
from repro.serving.paging import NoPagedLeavesError, PagedCachePool, cdiv
from repro.serving.scheduler import (CachePool, GatewayRequest, RequestState,
                                     ScheduledAction, Scheduler, TierViewCache)


@functools.lru_cache(maxsize=None)
def _compiled_steps(cfg: ModelConfig, fused: bool = False,
                    with_rng: bool = True, with_topk: bool = True):
    """Jitted lane-vmapped prefill/decode, shared by every gateway on the
    same (hashable, frozen) config — one compile per (config, shape,
    fused, rng, topk) key.  ``fused=True`` samples per lane on device and
    returns token ids; ``fused=False`` returns the raw logits rows.
    ``with_rng``/``with_topk`` specialize the fused sampler to the
    micro-batch (all-greedy batches skip the categorical, no-top-k
    batches skip the vocab sort) — at most 4 fused variants ever compile."""

    def _finish(logits, seed, n_out, temp, top_k):
        if not fused:
            return logits
        key = jax.random.fold_in(jax.random.PRNGKey(seed), n_out)
        return sample_lane(logits, key, temp, top_k,
                           with_rng=with_rng, with_topk=with_topk)

    def _prefill_one(view_params, tokens, cache, seed, n_out, temp, top_k, li):
        logits, cache = prefill_step(view_params, cfg, tokens[None], cache,
                                     license_intervals=li)
        return _finish(logits[0], seed, n_out, temp, top_k), cache

    def _decode_one(view_params, tok, cache, pos, seed, n_out, temp, top_k, li):
        logits, cache = serve_step(view_params, cfg, tok[None, None], cache,
                                   pos, license_intervals=li)
        return _finish(logits[0], seed, n_out, temp, top_k), cache

    return (jax.jit(jax.vmap(_prefill_one,
                             in_axes=(None, 0, 0, 0, 0, 0, 0, None))),
            jax.jit(jax.vmap(_decode_one,
                             in_axes=(None, 0, 0, 0, 0, 0, 0, 0, None))))


class LicensedGateway:
    """Continuous-batching serving gateway with per-tier licensed views.

    Parameters
    ----------
    cfg, params:
        Model config and raw (float) weights, as for ``ServingEngine``.
    tiers:
        Name -> :class:`LicenseTier`; ``"full"`` is always available.
        Unknown tiers are also resolved against ``server`` when attached.
    quantized:
        Serve from ONE int8 store with license masks fused into the
        in-scan dequant (see ``serving/quantized.py``).
    already_quantized:
        ``params`` is already an int8 store (used by
        ``ServingEngine.gateway()``); implies ``quantized``.
    materialize_int8_views:
        int8 mode only: run the fused masked-dequant once per
        (tier, version) and cache full-precision licensed views.
    max_batch:
        Lanes per micro-batch (the vmap width).
    max_prompt:
        Prompt bucket; longer prompts are rejected at admission.  Shorter
        prompts are right-aligned into the bucket with repeated-first-token
        padding, so absolute positions (and therefore logits) match a
        ``ServingEngine`` group padded to the same width — not an
        unpadded shorter run.
    max_new_cap:
        Decode budget per lane; ``max_new_tokens`` is clamped to it.
    paged:
        Use the block-paged cache pool (default).  ``False`` selects the
        seed contiguous ``CachePool`` — the fallback config every
        pre-paging behavior maps onto.
    block_size / num_blocks / max_lanes / watermark_blocks:
        Paged-pool geometry.  ``num_blocks`` defaults to full
        provisioning (``max_lanes * ceil(capacity/block_size)`` — equal
        memory to the contiguous pool at ``max_lanes == max_batch``, and
        preemption-free); size it smaller to oversubscribe.  Admission
        requires ``watermark_blocks`` free blocks above a prefill's
        need, reserving decode-growth headroom.
    fuse_sampling:
        Sample per lane on device and return token ids (default).
        ``False`` is the return-logits escape hatch: logits rows come
        back to the host and are sampled there (identical tokens).
    record_logits:
        Keep each emitted step's logits row on the request
        (``req.logits_rows``) for equivalence tests; implies
        ``fuse_sampling=False``.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        tiers: Optional[Dict[str, LicenseTier]] = None,
        quantized: bool = False,
        already_quantized: bool = False,
        materialize_int8_views: bool = False,
        max_batch: int = 8,
        max_prompt: int = 32,
        max_new_cap: int = 64,
        paged: bool = True,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        max_lanes: Optional[int] = None,
        watermark_blocks: int = 0,
        fuse_sampling: bool = True,
        record_logits: bool = False,
        view_capacity: int = 8,
        version: int = 1,
        server: Any = None,
        model: str = "model",
        history: int = 10_000,
    ):
        self.cfg = cfg
        self.quantized = quantized or already_quantized
        self.materialize_int8_views = materialize_int8_views
        if self.quantized and not already_quantized:
            from repro.serving.quantized import quantize_serving_params

            params = quantize_serving_params(params)
        self.max_batch = int(max_batch)
        self.max_prompt = int(max_prompt)
        self.max_new_cap = int(max_new_cap)
        self.capacity = self.max_prompt + self.max_new_cap

        self.version = int(version)
        self._weights: Dict[int, Any] = {self.version: params}
        self.tiers: Dict[str, LicenseTier] = dict(tiers or {})
        self.tiers.setdefault("full", FULL_TIER)
        self.views = TierViewCache(self._materialize, capacity=view_capacity)

        self.record_logits = bool(record_logits)
        self.fuse_sampling = bool(fuse_sampling) and not self.record_logits
        self.paged = bool(paged)
        if self.paged:
            self.max_lanes = int(max_lanes or self.max_batch)
            bpl = cdiv(self.capacity, int(block_size))
            try:
                self.pool = PagedCachePool(
                    cfg, self.max_lanes, self.capacity, int(block_size),
                    int(num_blocks) if num_blocks is not None
                    else self.max_lanes * bpl)
            except NoPagedLeavesError:
                # no per-token cache leaves (pure-recurrent model, or a
                # sliding window below the pool capacity caps every
                # attention cache): per-lane state is constant-size, so
                # paging has nothing to page — fall back to the slab
                self.paged = False
        if self.paged:
            self._prefill_blocks = max(
                1, cdiv(self.max_prompt, self.pool.block_size))
            if (self.pool.num_blocks - int(watermark_blocks)
                    < self._prefill_blocks):
                raise ValueError(
                    f"watermark_blocks={watermark_blocks} leaves no room to "
                    f"admit a prefill ({self._prefill_blocks} blocks of "
                    f"{self.pool.num_blocks}) — the gateway would accept "
                    f"requests and never schedule them")
            self.scheduler = Scheduler(
                self.max_lanes, self.max_batch,
                allocator=self.pool.allocator,
                prefill_blocks=self._prefill_blocks,
                watermark_blocks=int(watermark_blocks))
            zero_cap = self.pool.padded_capacity
        else:
            self.max_lanes = self.max_batch
            self.pool = CachePool(cfg, self.max_batch, self.capacity)
            self.scheduler = Scheduler(self.max_batch, self.max_batch)
            zero_cap = self.capacity
        lane0 = model_lib.init_cache(cfg, 1, zero_cap)  # pristine batch-1 cache
        self._zero_lanes = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.max_batch, *x.shape)),
            lane0,
        )

        self._server = server
        self.model = model
        self._client = None           # EdgeClient when booted from a server
        self._server_tiers: set = set()  # tier names learned from the server
        # tier updates deferred while their requests are in flight;
        # value None = pending revocation
        self._pending_tiers: Dict[str, Optional[LicenseTier]] = {}

        self._next_rid = 0
        # bounded: a long-lived gateway must not grow host memory with
        # every request served; metrics percentiles cover this window
        self.completed: "deque[GatewayRequest]" = deque(maxlen=history)
        self.trace: "deque[Tuple[str, str, Optional[int], int]]" = \
            deque(maxlen=history)
        self._drain_sink: Optional[List[GatewayRequest]] = None
        self.stats: Dict[str, int] = {
            "admitted": 0, "rejected": 0, "completed": 0,
            "prefill_batches": 0, "decode_steps": 0, "tokens_generated": 0,
            "preempted": 0, "max_running": 0, "max_blocks_in_use": 0,
        }

        # build the jit pair for the common case (all-greedy when fused);
        # _steps() dispatches per micro-batch, sharing the lru entries
        # across gateway instances over the same config
        if self.fuse_sampling:
            _compiled_steps(cfg, True, False, False)
        else:
            _compiled_steps(cfg, False)

    def _steps(self, reqs: List[GatewayRequest]):
        """(prefill, decode) jitted pair specialized to this micro-batch's
        sampling needs; batches with no stochastic lane skip the
        categorical draw, batches with no top-k lane skip the sort."""
        if not self.fuse_sampling:
            return _compiled_steps(self.cfg, False)
        with_rng = any(r.temperature > 0 for r in reqs)
        with_topk = with_rng and any(r.top_k for r in reqs)
        return _compiled_steps(self.cfg, True, with_rng, with_topk)

    # ------------------------------------------------------------ weight views
    def _resolve_tier(self, name: str) -> LicenseTier:
        tier = self.tiers.get(name)
        if tier is None and self._server is not None:
            try:
                tier = self._server.tier(self.model, name)
                self.tiers[name] = tier
                self._server_tiers.add(name)
            except KeyError:
                tier = None
        if tier is None:
            raise KeyError(f"unknown license tier {name!r}")
        return tier

    def _refresh_server_tiers(self) -> None:
        """Re-pull tiers learned from the server.

        A redefined tier (an operator tightening 'free' on a live
        gateway) or a revoked one must not keep serving its old masks —
        but in-flight requests are never re-masked mid-generation, so
        the change is *deferred* until the tier's current requests
        drain.  While a revocation is pending, new admissions to the
        tier are rejected."""
        for name in list(self._server_tiers):
            try:
                fresh = self._server.tier(self.model, name)
            except KeyError:
                fresh = None                       # revoked server-side
            cur = self.tiers.get(name)
            if fresh is not None and cur is not None and fresh.masks == cur.masks:
                self._pending_tiers.pop(name, None)
                continue
            self._pending_tiers[name] = fresh
        self._apply_pending_tiers()

    def _tier_in_flight(self, name: str) -> bool:
        return (any(r.license == name for r in self.scheduler.waiting)
                or any(r.license == name for r in self.scheduler.running))

    def _apply_pending_tiers(self) -> None:
        for name, fresh in list(self._pending_tiers.items()):
            if self._tier_in_flight(name):
                continue                           # defer until drained
            if fresh is None:
                self.tiers.pop(name, None)
                self._server_tiers.discard(name)
            else:
                self.tiers[name] = fresh
            self.views.invalidate(tier=name)
            del self._pending_tiers[name]

    def _materialize(self, tier_name: str, version: Optional[int]):
        """Build the (params, intervals) view served to one (tier, version)."""
        tier = self._resolve_tier(tier_name)
        base = self._weights[version]
        if not self.quantized:
            return apply_license(base, tier), None
        if self.materialize_int8_views:
            from repro.serving.quantized import materialize_licensed_view

            return materialize_licensed_view(base, tier, self.cfg.dtype), None
        from repro.serving.quantized import tier_intervals

        return base, tier_intervals(tier)

    def view_for(self, tier: str, version: Optional[int] = None):
        """Licensed weight view for (tier, version) — cached."""
        return self.views.get(tier, self.version if version is None else version)

    # -------------------------------------------------------------- admission
    def submit(self, prompt, *, license: str = "full", max_new_tokens: int = 16,
               temperature: float = 0.0, top_k: int = 0,
               seed: int = 0) -> GatewayRequest:
        """Admit one request: validate the tier, pin the weight version."""
        req = GatewayRequest(
            prompt=np.asarray(prompt, np.int32).reshape(-1),
            max_new_tokens=min(int(max_new_tokens), self.max_new_cap),
            license=license,
            # snap sub-epsilon temperatures to greedy: the fused sampler
            # clamps its divisor at 1e-6, so only the t <= 0 branch keeps
            # the fused and host paths token-identical down there
            temperature=0.0 if temperature <= 1e-6 else temperature,
            # top_k >= vocab truncates nothing; clamping keeps the host
            # sampler (lax.top_k needs k <= vocab) and the fused sampler
            # (clips its kth index) on identical behavior
            top_k=min(max(0, int(top_k)), self.cfg.padded_vocab), seed=seed,
        )
        if self.record_logits:
            req.logits_rows = []
        req.rid = self._next_rid
        self._next_rid += 1
        req.submit_t = time.perf_counter()
        try:
            if self._pending_tiers.get(license, "") is None:
                raise KeyError(f"license tier {license!r} is being revoked")
            self._resolve_tier(license)
        except KeyError as e:
            req.state = RequestState.REJECTED
            req.error = str(e)
            self.stats["rejected"] += 1
            return req
        if not 1 <= len(req.prompt) <= self.max_prompt:
            req.state = RequestState.REJECTED
            req.error = (f"prompt length {len(req.prompt)} outside "
                         f"[1, {self.max_prompt}]")
            self.stats["rejected"] += 1
            return req
        if req.max_new_tokens < 1:
            req.state = RequestState.REJECTED
            req.error = "max_new_tokens < 1"
            self.stats["rejected"] += 1
            return req
        if not -2**31 <= int(seed) < 2**31:
            # seeds ride the fused sampler as an int32 lane array; an
            # out-of-range one must bounce here, not crash the run() loop
            req.state = RequestState.REJECTED
            req.error = f"seed {seed} outside int32 range"
            self.stats["rejected"] += 1
            return req
        req.version = self.version
        self.scheduler.submit(req)
        self.stats["admitted"] += 1
        return req

    # ------------------------------------------------------------- scheduling
    def step(self) -> Optional[ScheduledAction]:
        """Run ONE scheduler iteration (one prefill or decode micro-batch)."""
        act = self.scheduler.next_action()
        if act is None:
            return None
        if act.kind == "prefill":
            self._run_prefill(act)
        else:
            self._run_decode(act)
        # a decode whose whole batch was preempted executed nothing —
        # keep the trace invariant that every entry covers >= 1 request
        if act.requests:
            self.trace.append((act.kind, act.tier, act.version,
                               len(act.requests)))
        return act

    def run(self, max_steps: int = 1_000_000) -> List[GatewayRequest]:
        """Drain the queue; returns requests completed during this call."""
        drained: List[GatewayRequest] = []
        self._drain_sink = drained
        try:
            for _ in range(max_steps):
                if self.step() is None:
                    break
        finally:
            self._drain_sink = None
        return drained

    def _sampling_lanes(self, reqs):
        """Per-lane (seed, n_generated, temperature, top_k) arrays for the
        fused sampler; padding lanes sample junk that is discarded."""
        seeds = np.zeros(self.max_batch, np.int32)
        nouts = np.zeros(self.max_batch, np.int32)
        temps = np.zeros(self.max_batch, np.float32)
        topks = np.zeros(self.max_batch, np.int32)
        for i, r in enumerate(reqs):
            seeds[i] = r.seed
            nouts[i] = len(r.out_tokens)
            temps[i] = r.temperature
            topks[i] = r.top_k
        return (jnp.asarray(seeds), jnp.asarray(nouts), jnp.asarray(temps),
                jnp.asarray(topks))

    def _run_prefill(self, act: ScheduledAction) -> None:
        view_params, li = self.views.get(act.tier, act.version)
        reqs = act.requests
        toks = right_align([r.prompt for r in reqs], self.max_prompt,
                           self.max_batch)
        seeds, nouts, temps, topks = self._sampling_lanes(reqs)
        prefill, _ = self._steps(reqs)
        outs, lane_caches = prefill(view_params, jnp.asarray(toks),
                                    self._zero_lanes, seeds, nouts,
                                    temps, topks, li)
        lanes = [self.scheduler.start(r) for r in reqs]
        self.stats["max_running"] = max(self.stats["max_running"],
                                        len(self.scheduler.running))
        if self.paged:
            for r in reqs:
                got = self.pool.allocator.alloc(self._prefill_blocks)
                assert got is not None, \
                    "scheduler admitted past the block budget"
                r.blocks = got
            self._note_block_use()
            tables = self.pool.pad_tables([r.blocks for r in reqs],
                                          self.max_batch)
            self.pool.scatter(self.pool.pad_lanes(lanes, self.max_batch),
                              tables, lane_caches)
        else:
            self.pool.scatter(self.pool.pad_lanes(lanes, self.max_batch),
                              lane_caches)
        outs = np.asarray(outs)
        now = time.perf_counter()
        for i, r in enumerate(reqs):
            r.pos = self.max_prompt
            r.first_token_t = now
            if self.fuse_sampling:
                self._emit(r, tok=int(outs[i]))
            else:
                self._emit(r, logits_row=outs[i])
        self.stats["prefill_batches"] += 1

    def _grow_block_tables(self, reqs: List[GatewayRequest]) \
            -> List[GatewayRequest]:
        """Give every request the block its next decode write needs.

        On pool exhaustion, preempt the youngest running request (free its
        blocks, requeue it at the queue head) and retry; a victim inside
        this micro-batch is dropped from it.  Terminates because the pool
        holds at least one full request (constructor guard) and the
        oldest running request is never chosen while others run.
        """
        keep = list(reqs)
        for r in list(keep):
            if r.state != RequestState.RUNNING:
                continue                   # preempted earlier in this pass
            needed = r.pos // self.pool.block_size + 1
            while len(r.blocks) < needed:
                got = self.pool.allocator.alloc(1)
                if got is not None:
                    r.blocks.extend(got)
                    continue
                victim = self.scheduler.youngest_running()
                if victim is r and len(self.scheduler.running) == 1:
                    raise RuntimeError(
                        "block pool exhausted by a single request")
                self._preempt(victim)
                if victim in keep:
                    keep.remove(victim)
                if victim is r:
                    break
        self._note_block_use()
        return keep

    def _preempt(self, req: GatewayRequest) -> None:
        if req.blocks:
            self.pool.allocator.free(req.blocks)
            req.blocks = []
        # the restart will re-emit these tokens; keep the counter equal to
        # tokens actually delivered
        self.stats["tokens_generated"] -= len(req.out_tokens)
        self.scheduler.preempt(req)
        self.stats["preempted"] += 1

    def _note_block_use(self) -> None:
        self.stats["max_blocks_in_use"] = max(
            self.stats["max_blocks_in_use"], self.pool.allocator.num_held)

    def _run_decode(self, act: ScheduledAction) -> None:
        if self.paged:
            act.requests = self._grow_block_tables(act.requests)
            if not act.requests:
                return                     # whole batch preempted
        view_params, li = self.views.get(act.tier, act.version)
        reqs = act.requests
        lanes = self.pool.pad_lanes([r.lane for r in reqs], self.max_batch)
        toks = np.zeros(self.max_batch, np.int32)
        poss = np.zeros(self.max_batch, np.int32)
        for i, r in enumerate(reqs):
            toks[i] = r.out_tokens[-1]
            poss[i] = r.pos
        seeds, nouts, temps, topks = self._sampling_lanes(reqs)
        if self.paged:
            tables = self.pool.pad_tables([r.blocks for r in reqs],
                                          self.max_batch)
            caches = self.pool.gather(lanes, tables)
        else:
            caches = self.pool.gather(lanes)
        _, decode = self._steps(reqs)
        outs, caches = decode(view_params, jnp.asarray(toks), caches,
                              jnp.asarray(poss), seeds, nouts, temps,
                              topks, li)
        if self.paged:
            self.pool.scatter(lanes, tables, caches)
        else:
            self.pool.scatter(lanes, caches)
        outs = np.asarray(outs)
        for i, r in enumerate(reqs):
            r.pos += 1
            if self.fuse_sampling:
                self._emit(r, tok=int(outs[i]))
            else:
                self._emit(r, logits_row=outs[i])
        self.stats["decode_steps"] += 1

    def _emit(self, req: GatewayRequest, tok: Optional[int] = None,
              logits_row: Optional[np.ndarray] = None) -> None:
        """Append one token (sampled on host from ``logits_row`` when the
        fused path is off) and retire the request if it is finished."""
        if tok is None:
            if req.logits_rows is not None:
                req.logits_rows.append(np.asarray(logits_row, np.float32))
            if req.temperature <= 0:
                tok = int(np.argmax(logits_row))
            else:
                # host side top_k is concrete -> the static sample() path
                # (skips sample_lane's traced-k sort); same tokens either way
                key = jax.random.fold_in(jax.random.PRNGKey(req.seed),
                                         len(req.out_tokens))
                tok = int(sample(jnp.asarray(logits_row)[None], key,
                                 temperature=req.temperature,
                                 top_k=req.top_k)[0])
        req.out_tokens.append(tok)
        self.stats["tokens_generated"] += 1
        if len(req.out_tokens) >= req.max_new_tokens:
            self.scheduler.finish(req)
            if self.paged and req.blocks:
                self.pool.allocator.free(req.blocks)
                req.blocks = []
            self.completed.append(req)
            if self._drain_sink is not None:
                self._drain_sink.append(req)
            self.stats["completed"] += 1
            self._gc_versions()

    # ---------------------------------------------------------- weight updates
    def update_weights(self, params: Any, *, version: Optional[int] = None,
                       already_quantized: bool = False) -> int:
        """Install new base weights under a new version.

        In-flight requests stay pinned to their admitted version; new
        admissions pin the new one.  Views for versions no longer pinned
        are invalidated once their last request drains.
        """
        if self.quantized and not already_quantized:
            from repro.serving.quantized import quantize_serving_params

            params = quantize_serving_params(params)
        version = self.version + 1 if version is None else int(version)
        if version < self.version:
            raise ValueError(f"version {version} is older than the current "
                             f"version {self.version}")
        if version in self._weights:
            # overwriting a live version: views built from the old weights
            # must not survive the swap
            self.views.invalidate(version=version)
        self._weights[version] = params
        self.version = version
        self._gc_versions()
        return version

    def _gc_versions(self) -> None:
        live = self.scheduler.pinned_versions() | {self.version}
        for v in [v for v in self._weights if v not in live]:
            del self._weights[v]
            self.views.invalidate(version=v)
        if self._pending_tiers:
            self._apply_pending_tiers()

    # ------------------------------------------------------- protocol plumbing
    @classmethod
    def from_server(cls, cfg: ModelConfig, server, model: str, template: Any,
                    **kw) -> "LicensedGateway":
        """Boot a gateway as an edge serving pod of ``server`` (Fig. 2).

        ``template`` is a zeroed params pytree; the full production
        snapshot is pulled through the §3.1.2 delta protocol, and
        :meth:`sync` keeps pulling increments from then on.
        """
        from repro.core.protocol import EdgeClient

        client = EdgeClient(model, template, license_name="full")
        client.request_update(server)
        gw = cls(cfg, client.params, server=server, model=model,
                 version=client.version, **kw)
        gw._client = client
        return gw

    def sync(self, server: Any = None) -> bool:
        """Pull newer production weights (and tier redefinitions) from the
        license server.

        Returns True if a new weight version was installed (and pinned for
        all subsequent admissions)."""
        server = server or self._server
        if server is None or self._client is None:
            raise RuntimeError("gateway was not booted with from_server()")
        self._refresh_server_tiers()
        before = self._client.version
        self._client.request_update(server)
        if self._client.version == before:
            return False
        self.update_weights(self._client.params, version=self._client.version)
        return True

    # ---------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, Any]:
        """Counters, queue-wait ages, pool occupancy, latency percentiles."""
        out: Dict[str, Any] = dict(self.stats)
        out["view_cache"] = self.views.stats()
        out["oldest_wait_s"] = self.scheduler.oldest_wait_s()
        out["queue_wait_by_tier"] = self.scheduler.queue_wait_by_tier()
        out["cache_pool"] = {"paged": self.paged, **self.pool.stats()}
        lats = [r.latency for r in self.completed if r.latency is not None]
        if lats:
            out["latency_p50_ms"] = float(np.percentile(lats, 50) * 1e3)
            out["latency_p99_ms"] = float(np.percentile(lats, 99) * 1e3)
        return out
