"""Quantized licensed serving (beyond-paper §Perf).

The paper's licensing masks weights in the DB and ships a *separate* weight
view per tier (mask-at-load).  Here ONE int8 weight store serves every
tier: block weights are kept as (codes int8, scale f32) and dequantized
*inside* the layer scan with the license's magnitude intervals fused into
the dequant — the semantics of ``kernels/masked_dequant`` (the Pallas
kernel is the TPU drop-in; the jnp form here lowers through XLA fusion).

Wins vs mask-at-load:
  * weight HBM reads are int8 — ~2x less than bf16, 4x less than f32;
  * a new tier costs ZERO extra weight memory (masks are 8 floats);
  * the licensed view can't leak: full-precision weights never exist in
    the serving process.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.licensing import LicenseTier
from repro.kernels.ops import MAX_INTERVALS, pack_intervals

# leaves excluded from quantization (precision- or structure-critical)
_SKIP = ("norm", "bias", "router", "conv", "A_log", "dt_bias", "D_skip",
         "a_param", "tok", "lm_head", "scale")


def _eligible(name: str, leaf) -> bool:
    short = name.split("/")[-1]
    if any(k in short for k in _SKIP):
        return False
    if not hasattr(leaf, "ndim"):
        return False
    # unit-stacked weights are (U, in, out[, ...]); plain 2-D under units are
    # stacked biases — leave those alone
    if "units/" in name:
        return leaf.ndim >= 3
    return "tail/" in name and leaf.ndim >= 2


def _quantize_leaf(w) -> Dict[str, jnp.ndarray]:
    """Per-output-channel symmetric int8 of one eligible weight: scale
    reduces over the second-to-last dim (the contraction dim of every
    block matmul)."""
    w = jnp.asarray(w).astype(jnp.float32)
    amax = jnp.max(jnp.abs(w), axis=-2, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    codes = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return {"codes": codes, "scale": scale}


def quantize_serving_params(params: Any) -> Any:
    """Same-structure tree; eligible weights become {"codes","scale"} dicts."""
    from repro.core.pytree_io import _path_str

    def q(path, leaf):
        name = _path_str(path)
        if not _eligible(name, leaf):
            return leaf
        return _quantize_leaf(leaf)

    return jax.tree_util.tree_map_with_path(q, params)


def requantize_layers(qparams: Any, new_flat: Dict[str, Any],
                      touched: Sequence[str]) -> Any:
    """Incremental requantize: rebuild the int8 store with ONLY ``touched``
    layers re-derived from ``new_flat`` (flat name -> new float array, as
    produced by ``core.pytree_io.flatten_params``); every other leaf is
    reused by reference from ``qparams``.

    This is the staged-update path's bounded alternative to
    ``quantize_serving_params`` over the whole tree: a delta touching k
    layers costs O(k) quantizations, and the stager can thread a batch of
    layer names per scheduler step.  Leaf eligibility is decided by what
    the *existing* store quantized (same names, same shapes across
    versions), so the rebuilt tree always matches the full requantize
    bit-for-bit."""
    from repro.core.pytree_io import _path_str

    want = set(touched)

    def q(path, leaf):
        name = _path_str(path)
        if name not in want:
            return leaf
        new = new_flat[name]
        return _quantize_leaf(new) if is_qleaf(leaf) else new

    return jax.tree_util.tree_map_with_path(q, qparams, is_leaf=is_qleaf)


def is_qleaf(leaf) -> bool:
    return isinstance(leaf, dict) and "codes" in leaf and "scale" in leaf


def dequant_leaf(leaf, lo: Optional[jnp.ndarray], hi: Optional[jnp.ndarray],
                 dtype) -> jnp.ndarray:
    """Fused dequant + license-interval mask (ref semantics of the
    ``masked_dequant`` Pallas kernel, applied per layer-scan slice)."""
    if not is_qleaf(leaf):
        return leaf
    w = leaf["codes"].astype(jnp.float32) * leaf["scale"]
    if lo is not None:
        mag = jnp.abs(w)
        dead = jnp.zeros(w.shape, bool)
        for i in range(MAX_INTERVALS):
            dead = dead | ((mag >= lo[i]) & (mag < hi[i]))
        w = jnp.where(dead, 0.0, w)
    return w.astype(dtype)


def dequant_tree(tree: Any, license_intervals, dtype) -> Any:
    lo, hi = (None, None) if license_intervals is None else license_intervals
    return jax.tree_util.tree_map(
        lambda l: dequant_leaf(l, lo, hi, dtype), tree, is_leaf=is_qleaf
    )


def materialize_licensed_view(qparams: Any, tier: Optional[LicenseTier],
                              dtype) -> Any:
    """Run the fused masked-dequant ONCE, returning a full-precision
    licensed view of the int8 store.

    This is the gateway's ``materialize_int8_views`` path: a long decode
    stream re-pays the in-scan dequant every step, so for hot tiers it
    can be cheaper to burn the HBM for a materialized view amortized
    across the whole (tier, version) lifetime.  2-D weight slices go
    through ``kernels.ops.masked_dequant`` (the Pallas kernel on TPU,
    its interpret/ref form on CPU); stacked leaves are dequantized
    slice-by-slice along their leading unit/expert axes.
    """
    from repro.kernels import ops

    li = tier_intervals(tier)
    if li is None:
        ivs = []
    else:
        lo, hi = (np.asarray(a) for a in li)
        ivs = [(float(l), float(h)) for l, h in zip(lo, hi) if h > l]

    def dq(leaf):
        if not is_qleaf(leaf):
            return leaf
        codes, scale = leaf["codes"], leaf["scale"]
        if codes.ndim == 2:
            return ops.masked_dequant(codes, scale, ivs, out_dtype=dtype)
        lead = codes.shape[:-2]
        r, c = codes.shape[-2:]
        flat_c = codes.reshape((-1, r, c))
        flat_s = jnp.broadcast_to(scale, (*lead, 1, c)).reshape((-1, 1, c))
        slices = [ops.masked_dequant(flat_c[i], flat_s[i], ivs, out_dtype=dtype)
                  for i in range(flat_c.shape[0])]
        return jnp.stack(slices).reshape((*lead, r, c))

    return jax.tree_util.tree_map(dq, qparams, is_leaf=is_qleaf)


def tier_intervals(tier: Optional[LicenseTier]) -> Optional[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Pack a tier's '*'-pattern intervals for the fused dequant path.

    The in-scan dequant applies one global interval set (per-layer patterns
    would need per-unit interval tensors — supported by stacking, omitted
    for brevity); '*' tiers are the common production case."""
    if tier is None or not tier.masks:
        return None
    ivs = list(tier.masks.get("*", ()))
    for pat, v in tier.masks.items():
        if pat != "*":
            ivs.extend(v)
    if not ivs:
        return None
    return pack_intervals(ivs)
