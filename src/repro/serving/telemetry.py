"""Serving-wide metrics registry: counters, gauges, fixed-bucket histograms.

The gateway/fleet ``metrics()`` dicts are instantaneous snapshots — no
history, no percentiles, no exposition format an operator's scrape loop
can ingest.  This module is the zero-dependency registry every serving
layer registers its instruments with:

* :class:`Counter` — monotone event count.  Most serving counters are
  *pull*-backed (``fn=``): the hot path keeps bumping its plain
  ``stats`` dict and the counter reads it at export time, so
  instrumentation adds **zero** cost to the paths it observes.
* :class:`Gauge` — instantaneous level (pool occupancy, queue depth),
  normally ``fn``-backed for the same reason.
* :class:`Histogram` — fixed-bucket latency distribution with
  ``p50``/``p90``/``p99`` accessors.  ``observe`` is O(log buckets)
  (a bisect + one bincount bump), the only *push*-model instrument —
  this is the always-on cost the telemetry benchmark bounds at <3%
  of decode throughput.
* :class:`Telemetry` — the registry: get-or-create instruments keyed by
  ``(name, labels)``, dynamic-label *collectors* (per-tenant series
  whose label set is unknown at registration), a structured
  :meth:`~Telemetry.snapshot`, and Prometheus text exposition via
  :meth:`~Telemetry.render_prometheus`.

Every instrument family renders once (``# HELP``/``# TYPE`` headers
deduplicated across label sets), so a :class:`FleetGateway` sharing one
registry across N model slots — each slot's instruments labeled
``{"model": name}`` — exports a single well-formed scrape page.

``GATEWAY_METRICS_KEYS``/``FLEET_METRICS_KEYS`` are the declared
``metrics()`` schemas: the lint test flattens live ``metrics()`` output
into dotted paths and rejects any key not declared here, so ad-hoc
unregistered keys cannot silently reappear (and the single-gateway
schema is asserted verbatim inside the fleet's per-model section).

Everything is injectable-clock (``clock=``) and has an ``enabled``
switch: ``enabled=False`` turns every push-path record into an early
return, which is what ``benchmarks/telemetry_bench.py`` compares
against to assert the <3% overhead bound.
"""
from __future__ import annotations

import json
import math
import time
from bisect import bisect_left
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

__all__ = [
    "Counter", "Gauge", "Histogram", "Telemetry",
    "DEFAULT_LATENCY_BUCKETS", "GATEWAY_METRICS_KEYS", "FLEET_METRICS_KEYS",
    "FLEET_MODEL_EXTRA_KEYS",
    "flatten_metric_keys",
    "validate_gateway_metrics", "validate_fleet_metrics",
]

# Seconds.  Sub-100µs steps up through minute-scale queue waits; chosen
# once so every latency histogram (TTFT, inter-token gap, queue wait,
# step duration, stager stall) shares comparable bucket edges.
DEFAULT_LATENCY_BUCKETS: Tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0,
)

LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[Dict[str, str]]) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _escape(v: str) -> str:
    return v.replace("\\", r"\\").replace('"', r'\"').replace("\n", r"\n")


def _render_labels(labels: LabelKey, extra: str = "") -> str:
    parts = [f'{k}="{_escape(v)}"' for k, v in labels]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


class Counter:
    """Monotone counter.  ``fn``-backed counters read an external value
    at export time (zero hot-path cost); push counters use :meth:`inc`."""

    kind = "counter"
    __slots__ = ("name", "labels", "help", "_value", "_fn")

    def __init__(self, name: str, labels: LabelKey = (), help: str = "",
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.labels = labels
        self.help = help
        self._value = 0
        self._fn = fn

    def inc(self, n: float = 1) -> None:
        self._value += n

    @property
    def value(self) -> float:
        return self._fn() if self._fn is not None else self._value


class Gauge:
    """Instantaneous level.  ``fn``-backed (evaluated at export) or
    :meth:`set` directly."""

    kind = "gauge"
    __slots__ = ("name", "labels", "help", "_value", "_fn")

    def __init__(self, name: str, labels: LabelKey = (), help: str = "",
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.labels = labels
        self.help = help
        self._value = 0.0
        self._fn = fn

    def set(self, v: float) -> None:
        self._value = v

    @property
    def value(self) -> float:
        return self._fn() if self._fn is not None else self._value


class Histogram:
    """Fixed-bucket histogram with percentile accessors.

    ``observe`` is a bisect over the (static) upper edges plus one
    counter bump — O(log buckets), no allocation — cheap enough to sit
    on the decode emit path.  Percentiles interpolate linearly inside
    the winning bucket (the +Inf bucket reports the last finite edge),
    which is the standard Prometheus ``histogram_quantile`` estimate
    computed client-side.
    """

    kind = "histogram"
    __slots__ = ("name", "labels", "help", "buckets", "counts", "sum",
                 "count", "enabled")

    def __init__(self, name: str, buckets: Sequence[float] =
                 DEFAULT_LATENCY_BUCKETS, labels: LabelKey = (),
                 help: str = "", enabled: bool = True):
        if list(buckets) != sorted(buckets) or len(set(buckets)) != len(buckets):
            raise ValueError(f"histogram buckets must be strictly "
                             f"increasing: {buckets}")
        self.name = name
        self.labels = labels
        self.help = help
        self.buckets = tuple(float(b) for b in buckets)
        self.counts = [0] * (len(self.buckets) + 1)   # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0
        self.enabled = enabled

    def observe(self, v: float) -> None:
        if not self.enabled:
            return
        self.counts[bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    def percentile(self, p: float) -> float:
        """Interpolated percentile, 0 <= p <= 100; 0.0 when empty."""
        if self.count == 0:
            return 0.0
        rank = p / 100.0 * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            lo = self.buckets[i - 1] if i > 0 else 0.0
            hi = (self.buckets[i] if i < len(self.buckets)
                  else self.buckets[-1])
            if cum + c >= rank:
                frac = (rank - cum) / c
                return lo + (hi - lo) * min(1.0, max(0.0, frac))
            cum += c
        return self.buckets[-1]

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p90(self) -> float:
        return self.percentile(90)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    def summary(self) -> Dict[str, float]:
        return {"count": self.count, "sum": self.sum, "p50": self.p50,
                "p90": self.p90, "p99": self.p99}


class Telemetry:
    """The registry: get-or-create instruments, snapshot, exposition.

    One ``Telemetry`` can be shared across serving layers (a fleet
    shares one across all model slots; each slot labels its instruments
    ``{"model": ...}``).  ``enabled=False`` disables every *push*
    instrument created through this registry (histogram observes become
    no-ops) — pull-backed counters/gauges are free either way.
    """

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter,
                 enabled: bool = True):
        self.clock = clock
        self.enabled = bool(enabled)
        # insertion-ordered: families render in registration order
        self._instruments: "Dict[Tuple[str, LabelKey], Any]" = {}
        self._collectors: List[Callable[[], Iterable[Tuple]]] = []
        self._declared: set = set()

    # ------------------------------------------------------------ instruments
    def _get(self, cls, name: str, labels, help: str, **kw):
        key = (name, _label_key(labels))
        inst = self._instruments.get(key)
        if inst is None:
            inst = cls(name, labels=key[1], help=help, **kw)
            self._instruments[key] = inst
        elif not isinstance(inst, cls):
            raise ValueError(f"instrument {name!r} already registered as "
                             f"{inst.kind}")
        return inst

    def counter(self, name: str, *, labels: Optional[Dict[str, str]] = None,
                help: str = "",
                fn: Optional[Callable[[], float]] = None) -> Counter:
        return self._get(Counter, name, labels, help, fn=fn)

    def gauge(self, name: str, *, labels: Optional[Dict[str, str]] = None,
              help: str = "",
              fn: Optional[Callable[[], float]] = None) -> Gauge:
        return self._get(Gauge, name, labels, help, fn=fn)

    def histogram(self, name: str, *,
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS,
                  labels: Optional[Dict[str, str]] = None,
                  help: str = "") -> Histogram:
        return self._get(Histogram, name, labels, help, buckets=buckets,
                         enabled=self.enabled)

    def register_collector(
            self, fn: Callable[[], Iterable[Tuple]]) -> None:
        """Register a dynamic-series source evaluated at export time.

        ``fn`` yields ``(name, kind, help, labels_dict, value)`` tuples —
        the escape hatch for label sets unknown at registration (e.g.
        one gauge per live tenant)."""
        self._collectors.append(fn)

    def adopt(self, other: "Telemetry") -> None:
        """Merge another registry's instruments and collectors into this
        one (fleet ``attach`` of a standalone gateway).  Colliding
        (name, labels) keys are an error — slots are label-disjoint by
        model name, so a collision means two slots claimed one series."""
        if other is self:
            return
        for key, inst in other._instruments.items():
            if key in self._instruments:
                raise ValueError(f"instrument collision on adopt: {key}")
            self._instruments[key] = inst
        self._collectors.extend(other._collectors)
        self._declared |= other._declared

    # ---------------------------------------------------------- metrics() lint
    def declare(self, *paths: str) -> None:
        """Declare ``metrics()`` key paths as registered (see
        :func:`repro.analysis.metrics.unregistered_metric_keys`)."""
        self._declared.update(paths)

    @property
    def declared(self) -> frozenset:
        return frozenset(self._declared)

    # -------------------------------------------------------------- snapshot
    def _families(self) -> "Dict[str, List[Any]]":
        fams: "Dict[str, List[Any]]" = {}
        for inst in self._instruments.values():
            fams.setdefault(inst.name, []).append(inst)
        for coll in self._collectors:
            for name, kind, help_, labels, value in coll():
                inst = (Counter if kind == "counter" else Gauge)(
                    name, labels=_label_key(labels), help=help_)
                inst._value = value
                fams.setdefault(name, []).append(inst)
        return fams

    def snapshot(self) -> Dict[str, Any]:
        """Structured-JSON view of every registered series."""
        out: Dict[str, Any] = {}
        for name, insts in self._families().items():
            fam = {"type": insts[0].kind, "help": insts[0].help, "series": []}
            for inst in insts:
                series: Dict[str, Any] = {"labels": dict(inst.labels)}
                if inst.kind == "histogram":
                    series.update(inst.summary())
                    series["buckets"] = [
                        {"le": le, "count": c}
                        for le, c in zip(list(inst.buckets) + ["+Inf"],
                                         inst.counts)]
                else:
                    series["value"] = inst.value
                fam["series"].append(series)
            out[name] = fam
        return out

    # ------------------------------------------------------------- prometheus
    def render_prometheus(self) -> str:
        """Prometheus text exposition (format 0.0.4) for every series."""
        lines: List[str] = []
        for name, insts in self._families().items():
            if insts[0].help:
                lines.append(f"# HELP {name} {insts[0].help}")
            lines.append(f"# TYPE {name} {insts[0].kind}")
            for inst in insts:
                if inst.kind == "histogram":
                    cum = 0
                    for le, c in zip(list(inst.buckets) + [math.inf],
                                     inst.counts):
                        cum += c
                        le_s = "+Inf" if le == math.inf else repr(float(le))
                        le_lbl = 'le="' + le_s + '"'
                        lines.append(
                            f"{name}_bucket"
                            f"{_render_labels(inst.labels, le_lbl)} {cum}")
                    lines.append(f"{name}_sum{_render_labels(inst.labels)}"
                                 f" {inst.sum}")
                    lines.append(f"{name}_count{_render_labels(inst.labels)}"
                                 f" {inst.count}")
                else:
                    v = inst.value
                    v_s = repr(float(v)) if isinstance(v, float) else str(v)
                    lines.append(
                        f"{name}{_render_labels(inst.labels)} {v_s}")
        return "\n".join(lines) + "\n"


# ---------------------------------------------------------- metrics() schemas
# The declared key schema of LicensedGateway.metrics().  ``.*`` marks a
# map with dynamic keys (tier names, tenant names, bucket widths); the
# lint test accepts any leaf under it.  A NEW metrics() key must be
# added here (and documented in docs/OBSERVABILITY.md) or the lint test
# fails — that is the point: no unregistered ad-hoc keys.
GATEWAY_METRICS_KEYS: Tuple[str, ...] = (
    # flat counters (ModelSlot.stats)
    "admitted", "rejected", "completed", "prefill_batches", "decode_steps",
    "resident_decode_steps", "tokens_generated", "preempted", "max_running",
    "max_blocks_in_use", "prefill_lane_tokens", "prefix_tokens_reused",
    "cow_copies", "prefill_chunks", "quota_rejections",
    "sync_retries", "sync_timeouts", "sync_quarantines",
    "model",
    # nested sections
    "view_cache.hits", "view_cache.misses", "view_cache.evictions",
    "view_cache.invalidations", "view_cache.entries",
    "oldest_wait_s", "queue_wait_by_tier.*", "tenants.*",
    "cache_pool.*", "decode_path.kernel_resident", "decode_path.pallas",
    "staged_update.*", "lease.*",
    "chunked_prefill.enabled", "chunked_prefill.chunk_size",
    "chunked_prefill.chunks",
    "admission_grouping.enabled", "admission_grouping.batches_by_suffix_width.*",
    "prefix_cache.*",
    # completion-latency percentiles (present once >= 1 request completed)
    "latency_p50_ms", "latency_p99_ms",
    # telemetry histograms (always present): p50/p90/p99/count/sum per axis
    "latency.ttft_s.*", "latency.inter_token_s.*", "latency.queue_wait_s.*",
    "latency.step_prefill_s.*", "latency.step_decode_s.*",
    "latency.stager_step_s.*",
)

# Fleet-section schema; each models.<name> section is the single-gateway
# schema above plus the fleet extensions listed here.
FLEET_METRICS_KEYS: Tuple[str, ...] = (
    "fleet.models", "fleet.steps", "fleet.cache_budget_bytes",
    "fleet.cache_used_bytes", "fleet.cache_reclaimable_bytes",
    "fleet.tokens_generated", "fleet.completed", "fleet.quota_rejections",
    "fleet.oldest_wait_s",
    "tenants.*",
)

# keys a fleet adds ON TOP of the single-gateway schema in models.<name>
FLEET_MODEL_EXTRA_KEYS: Tuple[str, ...] = ("tokens_per_s",)


def flatten_metric_keys(d: Any, prefix: str = "") -> List[str]:
    """Dotted leaf paths of a nested metrics dict."""
    if not isinstance(d, dict):
        return [prefix] if prefix else []
    if not d:
        return [prefix] if prefix else []
    out: List[str] = []
    for k, v in d.items():
        path = f"{prefix}.{k}" if prefix else str(k)
        out.extend(flatten_metric_keys(v, path))
    return out


def validate_gateway_metrics(metrics: Dict[str, Any],
                             extra: Iterable[str] = ()) -> None:
    """Assert ``metrics`` carries exactly the single-gateway schema.

    Checks both directions: no unregistered keys (modulo ``extra``, the
    fleet's documented per-model additions), and every non-wildcard,
    non-conditional declared key present — the schema-drift guard shared
    by the standalone-gateway test and the fleet per-model test.  The
    set-difference primitives live in :mod:`repro.analysis.metrics`
    (imported lazily: analysis depends on this module for
    ``flatten_metric_keys``)."""
    from repro.analysis.metrics import (missing_metric_keys,
                                        unregistered_metric_keys)

    unknown = unregistered_metric_keys(
        metrics, list(GATEWAY_METRICS_KEYS) + list(extra))
    assert not unknown, f"unregistered metrics() keys: {unknown}"
    missing = missing_metric_keys(
        metrics, GATEWAY_METRICS_KEYS,
        # conditional keys and configuration-dependent sections
        optional=("latency_p50_ms", "latency_p99_ms", "tenants.",
                  "queue_wait_by_tier.",
                  "admission_grouping.batches_by_suffix_width.*"))
    assert not missing, f"metrics() keys missing from schema: {missing}"


def validate_fleet_metrics(metrics: Dict[str, Any]) -> None:
    """Assert the fleet ``metrics()`` schema — including the unification
    guarantee: every ``models.<name>`` section passes the EXACT
    single-gateway check (plus the documented fleet extras), so one
    dashboard/parser serves standalone and fleet deployments alike."""
    from repro.analysis.metrics import (missing_metric_keys,
                                        unregistered_metric_keys)

    assert set(metrics) == {"fleet", "models", "tenants"}, \
        f"fleet metrics sections: {sorted(metrics)}"
    unknown = unregistered_metric_keys(
        {"fleet": metrics["fleet"], "tenants": metrics["tenants"]},
        FLEET_METRICS_KEYS)
    assert not unknown, f"unregistered fleet metrics() keys: {unknown}"
    missing = missing_metric_keys(
        {"fleet": metrics["fleet"]},
        [d for d in FLEET_METRICS_KEYS if not d.endswith(".*")])
    assert not missing, f"fleet metrics() keys missing: {missing}"
    for name, m in metrics["models"].items():
        validate_gateway_metrics(m, extra=FLEET_MODEL_EXTRA_KEYS)
        for k in FLEET_MODEL_EXTRA_KEYS:
            assert k in m, f"models[{name!r}] missing fleet extra {k!r}"


def dump_json(obj: Any) -> str:
    return json.dumps(obj, indent=2, sort_keys=False, default=str)
