"""Staged weight sync: version bumps that never stall a decode step.

``LicensedGateway.sync()`` used to pull the whole §3.1.2 update packet
and run ``update_weights()`` synchronously on the serving thread — the
full delta-apply, an optional whole-model requantize, and the view
invalidation all landed between two scheduler steps, and the first
admission at the new version then paid a cold view materialization on
top.  :class:`UpdateStager` splits that work into small, *bounded* steps
the gateway interleaves with its scheduler iterations:

```
poll ──▶ STAGE ──▶ REQUANT ──▶ PREWARM ──▶ FLIP
         (fetch one ≤max_step_bytes part      (int8 path: re-quantize
          from the server's UpdateCursor       ≤requant_layers_per_step
          and delta-apply it into the          TOUCHED layers per step,
          staging copy — kernels/delta_apply   reusing every untouched
          scatters in place)                   leaf of the live store)
                               (materialize the TierViewCache entry of
                                one currently-hot tier per step at the
                                NEW version, before anything serves it)
                                              (one atomic step: bump the
                                               gateway/client version AND
                                               apply tier redefinitions
                                               published alongside it)
```

Invariants the stager preserves:

* **Serving state is untouched until the flip.**  The staging params are
  a private copy (``apply_packet`` is copy-on-apply; the in-place kernel
  consumes only staging buffers); in-flight requests stay pinned to
  their admitted version throughout and produce bit-identical tokens to
  an update-free run.
* **Bounded work per step.**  A STAGE step transfers + applies at most
  ``max_step_bytes`` of delta (one indivisible chunk page may
  overshoot).  The layer being patched is held RESIDENT on device
  across its parts — uploaded once when its first part arrives,
  scattered into in place (``delta_apply`` donation), downloaded once
  when the cursor moves past it — so a step's total traffic is the
  delta bytes plus at most the layer-boundary transfers, never
  2×layer-bytes per part.  A REQUANT step re-quantizes at most
  ``requant_layers_per_step`` layers; a PREWARM step builds one tier
  view.  No step ever performs the full delta-apply or a whole-model
  requantize (the quantized fallback to a full requantize exists only
  for a gateway whose version diverged from its edge client's —
  impossible through the ``sync`` API).  Server-side, the masking of
  shipped values is equally per-part (``fetch_update``); only the §4.2
  delta query itself runs at ``begin`` — and the begin step is timed
  like any other scheduler step in the update benchmark.
* **Atomic flip.**  Tier redefinitions published together with the
  version bump go live in the same stager step that installs the new
  weights — an admission between any two scheduler steps sees either
  (old tiers, old version) or (new tiers, new version), never a mix.
  A redefined tier still serving in-flight requests at the flip defers
  (they are never re-masked mid-generation) and refuses NEW admissions
  until it drains — like a pending revocation — so the deferred window
  admits nothing under (old masks, new version).  (Tier-only changes,
  with no version bump, still apply immediately at ``begin`` — there is
  no flip to couple them to.)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.analysis import lockstep
from repro.core.pytree_io import flatten_params, unflatten_like
from repro.core.transport import (PayloadCorruption, RetryPolicy, Transport,
                                  TransportError, TransportTimeout,
                                  as_transport)
from repro.serving.tracing import STAGER_TID

# the cursor-protocol fields whose ownership moves with the fetch
# worker (see the guarded-by annotations in __init__ and
# repro.analysis.lockstep for the dynamic check)
_WORKER_FIELDS = ("_cursor", "_pos", "_cursor_dead")


class _ReopenRequired(Exception):
    """Internal worker→serving-thread signal: the cursor is dead (a
    disconnect or corrupted delivery) and reopening it needs the §4.2
    delta query — sqlite, which is bound to the serving thread.  Never
    escapes the stager."""


@functools.cache
def _page_update():
    """Jitted, buffer-donating contiguous page write: the staging buffer
    is consumed and the page lands in place on backends with donation
    support (elsewhere it degrades to one device-side copy per page —
    still never a host round trip).  ``start`` is a traced scalar, so one
    compilation serves every page offset of a (layer, page) shape pair."""
    import jax

    return jax.jit(
        lambda buf, page, start: jax.lax.dynamic_update_slice(
            buf, page, (start,)),
        donate_argnums=(0,))


class UpdateStager:
    """Incremental ``sync()``: fetch → stage → requantize → prewarm → flip.

    One stager serves one update session; the gateway constructs it in
    :meth:`LicensedGateway.begin_sync` and advances it one :meth:`step`
    per scheduler iteration (or in a tight loop for the blocking
    ``sync()``).  ``stats()`` exports the per-step accounting the update
    benchmark asserts its bounds on.
    """

    def __init__(self, gateway: Any, server: Any, *,
                 max_step_bytes: int = 256 << 10,
                 requant_layers_per_step: int = 2,
                 background_fetch: bool = True,
                 fetch_depth: int = 2,
                 transport: Optional[Transport] = None,
                 retry: Optional[RetryPolicy] = None,
                 join_timeout_s: float = 5.0):
        self.gw = gateway
        # every wire call goes through a Transport; ``server`` may be a
        # raw LicenseServer or a Transport over one.  When the gateway
        # was booted against the same server, its transport (and any
        # chaos schedule on it) is reused so one seam governs the sync.
        if transport is not None:
            self.transport = transport
        elif isinstance(server, Transport):
            self.transport = server
        else:
            gwt = getattr(gateway, "_transport", None)
            self.transport = (gwt if gwt is not None and gwt.server is server
                              else as_transport(server))
        self.server = self.transport.server
        self.retry = (retry if retry is not None
                      else getattr(gateway, "retry_policy", None)
                      or RetryPolicy())
        self.join_timeout_s = float(join_timeout_s)
        self.max_step_bytes = int(max_step_bytes)
        self.requant_layers_per_step = int(requant_layers_per_step)
        # true background fetch: the wire transfer (server.fetch_update
        # — pure in-memory cursor slicing + masking, no sqlite) runs on
        # a worker thread so wire time overlaps compute; the APPLY stays
        # on the serving thread, the flip stays at a step boundary.  The
        # worker stays at most ``fetch_depth`` parts-batches ahead
        # (bounded queue), so staging memory stays bounded too.
        self.background_fetch = bool(background_fetch)
        self.fetch_depth = max(1, int(fetch_depth))
        self._fetch_thread = None
        self._fetch_queue = None
        self._fetch_stop = None
        self.phase = "idle"
        self.to_version: Optional[int] = None
        self._cursor = None  # guarded-by: owner(__init__, begin, _reopen, abort, _flip)
        self._staged: Any = None          # staging copy of the raw params
        self._staged_q: Any = None        # staging int8 store (quantized path)
        self._touched: Set[str] = set()   # layer names the delta touched
        self._requant_queue: List[str] = []
        self._prewarm_queue: List[str] = []
        # fault-tolerance state: the last durably-applied cursor
        # position (the resume token), wire bytes accumulated across
        # reopened sessions, and whether the current cursor may have
        # advanced past parts the client never received
        self._pos: Tuple[int, int] = (0, 0)  # guarded-by: owner(__init__, begin, _fetch_parts)
        self._wire_bytes = 0  # guarded-by: owner(__init__, begin, _reopen)
        self._cursor_dead = False  # guarded-by: owner(__init__, begin, _reconnect, _fetch_parts)
        self.stats_: Dict[str, Any] = {
            "steps": 0, "parts_applied": 0, "bytes_applied": 0,
            "max_step_bytes_applied": 0, "layers_requantized": 0,
            "views_prewarmed": 0, "flips": 0,
            "retries": 0, "resumes": 0, "corrupt_parts": 0,
            "fetch_workers_leaked": 0,
        }

    # ------------------------------------------------------------------ state
    @property
    def active(self) -> bool:
        return self.phase not in ("idle", "done", "failed")

    def stats(self) -> Dict[str, Any]:
        out = dict(self.stats_)
        out["phase"] = self.phase
        out["to_version"] = self.to_version
        out["layers_touched"] = len(self._touched)
        out["max_step_bytes_bound"] = self.max_step_bytes
        out["background_fetch"] = self.background_fetch
        out["wire"] = dict(self.transport.stats)
        return out

    # ------------------------------------------------------------------ begin
    def begin(self) -> bool:
        """Poll the server.  Returns True when a staged update session
        started (a newer production version exists); False when the
        client is current — in which case tier-only redefinitions are
        applied immediately, since there is no version flip to join —
        or when the newer version is quarantined (repeated failed syncs
        toward it; serving continues on the current version).  Wire
        faults retry under the policy; exhaustion raises
        ``TransportError`` (``begin_sync`` turns that into "no sync
        started, keep serving")."""
        gw, client = self.gw, self.gw._client
        # cheap poll first: a no-op sync must not pay the §4.2 delta
        # query or leave an empty session in the server's audit log
        prod = self._wire(lambda: self.transport.production_version(gw.model))
        if prod == client.version:
            gw._refresh_server_tiers()
            self.phase = "done"
            return False
        if prod in gw.quarantined_versions:
            self.phase = "done"
            return False
        cursor = self._wire(lambda: self.transport.open_update(
            gw.model, client.version, client.license_name))
        if cursor.to_version == client.version:   # raced: moved back to us
            gw._refresh_server_tiers()
            self.phase = "done"
            return False
        if cursor.to_version in gw.quarantined_versions:
            self.phase = "done"
            return False
        if cursor.to_version < gw.version:
            raise ValueError(
                f"server production version {cursor.to_version} is older "
                f"than the gateway's current version {gw.version}")
        self._cursor = cursor
        self.to_version = cursor.to_version
        self._pos = cursor.tell()
        self._wire_bytes = 0
        self._cursor_dead = False
        # flat staging view: untouched layers stay the client's own (np)
        # arrays by reference; a touched layer is uploaded once, patched
        # in place on device part-by-part, and downloaded once when the
        # cursor moves past it (_finalize_layer)
        self._flat = dict(flatten_params(client.params))
        self._pending_layer: Optional[str] = None
        self._pending_buf = None
        self._staged = None               # assembled when the cursor drains
        self._touched = set()
        # incremental requant reuses the live int8 store's untouched
        # leaves; that store must correspond to the client's version
        # (always true through the sync API — update_weights() bypassing
        # the client is the only way to diverge, and then we requantize
        # everything in one fallback step)
        self._requant_base = (gw._weights.get(gw.version)
                              if gw.quantized and gw.version == client.version
                              else None)
        self.phase = "stage"
        if gw.obs:
            gw.audit.record("sync_begin", model=gw.model,
                            from_version=client.version,
                            to_version=cursor.to_version)
        if self.background_fetch:
            self._start_fetch_worker()
        return True

    # ------------------------------------------------------------ wire faults
    def _note_retry(self, attempt: int, exc: BaseException,
                    delay: float) -> None:
        """Per-retry accounting hook (runs on whichever thread made the
        wire call): stager counters, slot counters, and the
        ``sync_retry`` audit event."""
        self.stats_["retries"] += 1
        if isinstance(exc, PayloadCorruption):
            self.stats_["corrupt_parts"] += 1
        gw = self.gw
        gw._count_wire_retry(attempt, exc, delay,
                             to_version=self.to_version)

    def _wire(self, fn):
        """One wire call under the retry policy; success renews the
        license lease."""
        result = self.retry.run(fn, on_retry=self._note_retry)
        self.gw._lease_renew()
        return result

    def _reopen(self) -> None:
        """Reconnect after a lost or corrupted delivery: the dead
        cursor may have advanced past parts this client never received,
        so it is abandoned (its session log entry stays — an abandoned
        stream is still audit-visible) and a fresh session is opened,
        seeked to the last durably-applied position.  The delta query
        is deterministic, so the resumed row ranges line up exactly."""
        gw, client = self.gw, self.gw._client
        lockstep.checkpoint("stager.reopen",
                            touches=("_cursor", "_wire_bytes"))
        old, self._cursor = self._cursor, None
        if old is not None:
            self._wire_bytes += old.fetched_bytes
        cursor = self.transport.open_update(gw.model, client.version,
                                            client.license_name,
                                            resume=self._pos)
        if cursor.to_version != self.to_version:
            # the server moved on mid-sync: resuming would splice two
            # different deltas — not transient, abort the session
            raise RuntimeError(
                f"server production version moved {self.to_version} -> "
                f"{cursor.to_version} mid-sync; aborting this session")
        self._cursor = cursor
        self.stats_["resumes"] += 1

    def _reconnect(self) -> None:
        """Serving-thread reopen: clears the dead-cursor flag once the
        fresh session is seeked into place."""
        self._reopen()
        self._cursor_dead = False

    def _fetch_parts(self, allow_reopen: bool = True,
                     ) -> Tuple[List[Any], bool]:
        """One bounded parts batch off the wire, surviving faults: a
        failed delivery retries under the policy, resuming from the
        last durable cursor position instead of tearing the sync down.
        Returns ``(parts, done)``.  Runs on the fetch worker when
        background fetch is on, on the serving thread otherwise — it is
        the only mutator of cursor/position state while fetching.

        ``allow_reopen=False`` (the worker): a dead cursor raises
        :class:`_ReopenRequired` instead of reopening, because the
        reopen runs the sqlite-backed delta query and sqlite connections
        are bound to the serving thread.  Timeouts (the cursor never
        moved) still retry in place — pure in-memory work."""

        lockstep.checkpoint("stager.fetch_parts", touches=_WORKER_FIELDS)

        def attempt():
            if self._cursor_dead:
                if not allow_reopen:
                    raise _ReopenRequired()
                self._reconnect()
            try:
                return self.transport.fetch_update(self._cursor,
                                                   self.max_step_bytes)
            except TransportTimeout:
                # the request never reached the server: the cursor is
                # intact, a plain retry re-issues the same fetch
                raise
            except TransportError:
                # a disconnect may have advanced the cursor past lost
                # parts; a corrupt delivery did advance it — both resume
                # via a reopen seeked to _pos
                self._cursor_dead = True
                raise

        parts = self.retry.run(attempt, on_retry=self._note_retry)
        # durable position: everything up to here is about to be applied
        # locally (apply cannot fault — it is host/device work)
        lockstep.checkpoint("stager.advance_pos", touches=("_pos",))
        self._pos = self._cursor.tell()
        self.gw._lease_renew()
        return parts, self._cursor.done

    # ------------------------------------------------------- background fetch
    def _start_fetch_worker(self) -> None:
        """Spawn the wire-transfer worker: it loops ``fetch_update``
        against the (private, in-memory) cursor and hands each bounded
        parts batch through a depth-limited queue.  Only the *transfer*
        is off-thread — the delta APPLY consumes the queue on the
        serving thread inside :meth:`_step_stage`, so device state is
        still touched by exactly one thread."""
        import queue
        import threading

        q: "queue.Queue" = queue.Queue(maxsize=self.fetch_depth)
        stop = threading.Event()

        def _loop() -> None:
            try:
                while not stop.is_set():
                    # timeouts retry in place here; a dead cursor
                    # (disconnect/corruption) hands off to the serving
                    # thread, which owns the sqlite-backed reopen
                    try:
                        parts, done = self._fetch_parts(allow_reopen=False)
                    except _ReopenRequired:
                        while not stop.is_set():
                            try:
                                q.put(("reconnect", None, False),
                                      timeout=0.05)
                                return
                            except queue.Full:
                                continue
                        return
                    while not stop.is_set():
                        try:
                            q.put(("parts", parts, done), timeout=0.05)
                            break
                        except queue.Full:
                            continue
                    if done:
                        return
            except BaseException as exc:  # noqa: BLE001 — relayed to step()
                # surface the failure on the serving thread: _step_stage
                # re-raises it, step() aborts the session (the standard
                # teardown), and the exception propagates to the caller
                while not stop.is_set():
                    try:
                        q.put(("error", exc, True), timeout=0.05)
                        return
                    except queue.Full:
                        continue

        self._fetch_queue = q
        self._fetch_stop = stop
        self._fetch_thread = threading.Thread(
            target=_loop, name="update-stager-fetch", daemon=True)
        # the handoff point: from here until the join in
        # _stop_fetch_worker, cursor state belongs to the worker
        lockstep.transfer_ownership(_WORKER_FIELDS, "worker")
        self._fetch_thread.start()

    def _stop_fetch_worker(self) -> bool:
        """Tear the worker down (idempotent): signal stop, unblock any
        pending put by draining, join.  Returns False — and records the
        leak in ``stats()`` — when the worker is still alive after
        ``join_timeout_s``: a live worker may still be writing cursor
        and staging state, so callers on the flip path must FAIL the
        sync rather than proceed (the old code silently ignored the
        join timeout and flipped anyway)."""
        if self._fetch_thread is None:
            return True
        import queue

        self._fetch_stop.set()
        try:
            while True:
                self._fetch_queue.get_nowait()
        except queue.Empty:
            pass
        self._fetch_thread.join(timeout=self.join_timeout_s)
        leaked = self._fetch_thread.is_alive()
        if leaked:
            self.stats_["fetch_workers_leaked"] += 1
        else:
            # join is the visibility barrier: cursor state is the
            # serving thread's again.  A LEAKED worker keeps ownership —
            # any serve-side touch after a failed join is the exact
            # hazard the lockstep checker exists to flag.
            lockstep.transfer_ownership(_WORKER_FIELDS, "serve")
        self._fetch_thread = None
        self._fetch_queue = None
        self._fetch_stop = None
        return not leaked

    # ------------------------------------------------------------------- step
    def step(self) -> Optional[str]:
        """Run ONE bounded unit of staging work; returns the phase that
        executed (None when the stager is idle/done).

        A step that raises ABORTS the session first (staging state torn
        down, the pre-registered version and any prewarmed views dropped,
        ``active`` becomes False) and then re-raises: the gateway keeps
        serving on its current version and a later ``begin_sync`` opens a
        fresh cursor from scratch, so a failed stage can neither wedge
        the serving loop nor flip a partially-applied update in."""
        if not self.active:
            return None
        phase = self.phase
        self.stats_["steps"] += 1
        gw = self.gw
        t0 = gw.clock() if gw.obs else 0.0
        try:
            if phase == "stage":
                self._step_stage()
            elif phase == "requant":
                self._step_requant()
            elif phase == "prewarm":
                self._step_prewarm()
            elif phase == "flip":
                self._flip()
        except BaseException:
            self.abort()
            raise
        if gw.obs:
            t1 = gw.clock()
            gw.h_stager.observe(t1 - t0)
            gw.tracer.complete("stager:" + phase, t0, t1, tid=STAGER_TID,
                               attrs={"to_version": self.to_version})
        return phase

    def abort(self) -> None:
        """Tear down an in-progress session (no-op once done/failed).
        Everything staged is private until the flip, so aborting is just
        dropping it — plus unregistering the pre-registered version if
        prewarm had begun (only when the flip has not already happened:
        a failure *inside* the flip after the version bump must not
        yank the now-live weights)."""
        if not self.active:
            return
        self._stop_fetch_worker()
        gw = self.gw
        if self.to_version is not None \
                and gw._staging_version == self.to_version:
            gw._weights.pop(self.to_version, None)
            gw.views.invalidate(version=self.to_version)
            if gw.prefix is not None:
                gw.prefix.drop_scope(version=self.to_version)
            gw._staging_version = None
        self._cursor = None
        self._staged = self._staged_q = None
        self._pending_layer = None
        self._pending_buf = None
        if gw.obs:
            gw.audit.record("sync_abort", model=gw.model,
                            phase=self.phase, to_version=self.to_version)
        if self.to_version is not None:
            gw._note_sync_failure(self.to_version)
        self.phase = "failed"

    def _apply_part(self, part) -> None:
        """Apply one fetched part to the resident staging buffer of its
        layer: sparse (index, value) rows go through the in-place
        ``delta_apply`` scatter kernel; a chunk page is a *contiguous*
        run, so it is a donated ``dynamic_update_slice`` — no scatter
        needed (the scatter-as-compare kernel is built for sparse
        deltas; page-dense updates would pay O(tiles × page) compares)."""
        import jax.numpy as jnp

        from repro.kernels import ops

        if part.layer not in self._flat:
            raise KeyError(f"delta for unknown layer {part.layer!r}")
        if self._pending_layer is not None and self._pending_layer != part.layer:
            self._finalize_layer()
        if self._pending_layer is None:
            self._pending_layer = part.layer
            self._pending_buf = jnp.asarray(self._flat[part.layer]).reshape(-1)
        buf = self._pending_buf
        if part.chunks is not None:
            ce = part.chunk_elems
            for ci, page in part.iter_pages():
                buf = _page_update()(buf,
                                     jnp.asarray(page).astype(buf.dtype),
                                     np.int32(ci * ce))
        elif len(part.indices):
            buf = ops.delta_apply(buf, jnp.asarray(part.indices),
                                  jnp.asarray(part.values).astype(buf.dtype),
                                  donate=True)
        self._pending_buf = buf

    def _finalize_layer(self) -> None:
        name = self._pending_layer
        self._flat[name] = np.asarray(self._pending_buf).reshape(
            self._flat[name].shape)
        self._pending_layer = None
        self._pending_buf = None

    def _step_stage(self) -> None:
        lockstep.checkpoint("stager.stage")
        if self._fetch_thread is not None:
            # the wire transfer already happened (or is happening) on the
            # worker; a blocking get here is never slower than the
            # synchronous fetch it replaces, and is usually a no-wait hit
            kind, payload, done = self._fetch_queue.get()
            if kind == "error":
                raise payload
            if kind == "reconnect":
                # the worker exited on a dead cursor: reopen it here
                # (the sqlite-bound delta query) and restart the worker
                # — this stager step's bounded unit IS the reconnect
                if not self._stop_fetch_worker():
                    raise RuntimeError(
                        "background fetch worker failed to stop during "
                        "reconnect")
                self.retry.run(self._reconnect, on_retry=self._note_retry)
                self.gw._lease_renew()
                self._start_fetch_worker()
                return
            parts = payload
        else:
            parts, done = self._fetch_parts()
        if parts:
            for part in parts:
                self._apply_part(part)
            got = int(sum(p.nbytes for p in parts))
            self.stats_["parts_applied"] += len(parts)
            self.stats_["bytes_applied"] += got
            self.stats_["max_step_bytes_applied"] = max(
                self.stats_["max_step_bytes_applied"], got)
            self._touched.update(p.layer for p in parts)
        if done:
            # worker (if any) has exited on its own: ``done`` rode the
            # queue with the final batch, so cursor fields read from the
            # serving thread from here on (fetched_bytes at the flip)
            # are past the last worker write
            if not self._stop_fetch_worker():
                raise RuntimeError(
                    "background fetch worker failed to stop; refusing to "
                    "flip with a live worker still writing")
            if self._pending_layer is not None:
                self._finalize_layer()
            # assemble the staged tree: touched layers are the patched
            # buffers, untouched leaves the client's arrays by reference
            self._staged = unflatten_like(self.gw._client.params, self._flat)
            if self.gw.quantized:
                self._requant_queue = sorted(self._touched)
                self._staged_q = self._requant_base
                self.phase = "requant"
            else:
                self._enter_prewarm()

    def _step_requant(self) -> None:
        from repro.serving.quantized import (quantize_serving_params,
                                             requantize_layers)

        if self._requant_base is None:
            # diverged gateway (see begin): full requantize, one step
            self._staged_q = quantize_serving_params(self._staged)
            self._requant_queue = []
        else:
            batch = self._requant_queue[:self.requant_layers_per_step]
            del self._requant_queue[:len(batch)]
            self._staged_q = requantize_layers(self._staged_q, self._flat,
                                               batch)
            self.stats_["layers_requantized"] += len(batch)
        if not self._requant_queue:
            self._enter_prewarm()

    def _enter_prewarm(self) -> None:
        gw = self.gw
        serving = self._staged_q if gw.quantized else self._staged
        gw._register_staging(self.to_version, serving)
        # hot tiers from scheduler occupancy: the tiers serving traffic
        # now are the ones whose first new-version admission would pay a
        # cold view build.  Tiers pending revocation are skipped, and the
        # queue is capped at the view cache's SPARE slots: prewarming
        # must never LRU-evict a view (in-flight pinned requests decode
        # through the old-version entries; evicting one buys a cold
        # rebuild mid-generation — the very stall staging removes).
        # hot_tiers() is busiest-first, so any cap keeps the tiers whose
        # warm view matters most; with no spare slots prewarm is skipped
        # and the first admission builds its view as before.
        spare = gw.views.capacity - len(gw.views)
        self._prewarm_queue = [
            t for t in gw.scheduler.hot_tiers()
            if not (t in gw._pending_tiers and gw._pending_tiers[t] is None)
        ][: max(0, spare)]
        self.phase = "prewarm"
        if not self._prewarm_queue:
            self.phase = "flip"

    def _step_prewarm(self) -> None:
        gw = self.gw
        if len(gw.views) >= gw.views.capacity:
            # an admission since _enter_prewarm filled the spare slots:
            # stop rather than LRU-evict a live view (the remaining
            # tiers build their views cold on first admission, as before)
            self._prewarm_queue = []
        else:
            tier = self._prewarm_queue.pop(0)
            try:
                gw.views.get(tier, self.to_version)
                self.stats_["views_prewarmed"] += 1
            except KeyError:
                pass                      # tier vanished mid-staging
        if not self._prewarm_queue:
            self.phase = "flip"

    def _flip(self) -> None:
        """Atomic install: new weights + tier redefinitions in one step."""
        gw, client = self.gw, self.gw._client
        lockstep.checkpoint("stager.flip",
                            touches=("_cursor", "_wire_bytes"))
        gw._install_staged(self.to_version)
        client.params = self._staged
        client.version = self.to_version
        client.bytes_downloaded += self._wire_bytes + self._cursor.fetched_bytes
        client.updates += 1
        self.stats_["flips"] += 1
        gw._note_sync_success(self.to_version)
        self._cursor = None
        self._staged = self._staged_q = None
        self.phase = "done"
