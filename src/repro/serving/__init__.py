"""Serving layer: single-stream engine + continuous-batching gateway.

``ServingEngine`` (engine.py) is the seed's static-batch server;
``LicensedGateway`` (gateway.py) is the iteration-level scheduler that
streams tier-tagged requests through (tier, version)-keyed masked
weight views.  Host-side scheduling primitives live in scheduler.py;
the block-paged KV pool (``BlockAllocator``/``PagedCachePool``) the
gateway serves from by default lives in paging.py, the
(tier, version)-scoped shared-prefix radix cache (``PrefixCache``)
that lets same-prefix prompts skip redundant prefill lives in
prefix.py, and the staged weight-sync state machine (``UpdateStager``)
that flips license-server version bumps in without stalling a decode
step lives in updates.py.  Fleet serving (fleet.py) composes N
per-model ``ModelSlot``\\ s behind one ``FleetGateway`` loop under a
global cache-byte budget, with per-tenant entitlements/quotas/rate
limits enforced by a ``TenantRegistry``.  Observability (telemetry.py
+ tracing.py): a ``Telemetry`` metrics registry (Prometheus text
exposition), a ``TraceRecorder`` request-lifecycle tape (Chrome
trace_event export), and an ``AuditLog`` licensing ledger — see
docs/OBSERVABILITY.md.
"""
from repro.serving.engine import (Request, ServingEngine, prefill_chunk_step,
                                  prefill_step, prefill_suffix_step, sample,
                                  sample_lane, serve_step, stack_lane_caches)
from repro.serving.fleet import FleetGateway, ModelSlot, TenantRegistry
from repro.serving.gateway import LicensedGateway
from repro.serving.paging import BlockAllocator, PagedCachePool
from repro.serving.prefix import PrefixCache
from repro.serving.scheduler import (CachePool, GatewayRequest, RequestState,
                                     ScheduledAction, Scheduler, TierViewCache)
from repro.serving.telemetry import (Counter, Gauge, Histogram, Telemetry,
                                     validate_fleet_metrics,
                                     validate_gateway_metrics)
from repro.serving.tracing import (AuditLog, TraceRecorder,
                                   merge_chrome_traces, validate_chrome_trace)
from repro.serving.updates import UpdateStager

__all__ = [
    "Request", "ServingEngine", "prefill_step", "prefill_suffix_step",
    "prefill_chunk_step", "stack_lane_caches",
    "sample", "sample_lane", "serve_step", "LicensedGateway",
    "GatewayRequest", "RequestState", "ScheduledAction", "Scheduler",
    "CachePool", "PagedCachePool", "BlockAllocator", "PrefixCache",
    "TierViewCache", "UpdateStager",
    "FleetGateway", "ModelSlot", "TenantRegistry",
    "Counter", "Gauge", "Histogram", "Telemetry",
    "TraceRecorder", "AuditLog", "merge_chrome_traces",
    "validate_chrome_trace", "validate_gateway_metrics",
    "validate_fleet_metrics",
]
