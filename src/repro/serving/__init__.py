from repro.serving.engine import Request, ServingEngine, prefill_step, sample, serve_step

__all__ = ["Request", "ServingEngine", "prefill_step", "sample", "serve_step"]
