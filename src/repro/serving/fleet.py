"""Multi-model, multi-tenant fleet serving.

The paper's "one deployment, many licensed variants" story, pushed to a
*fleet*: one serving binary hosting several heterogeneous models at
once, each with its own licensing ladder, sharing device cache memory
under one global budget, with per-tenant entitlements and quotas
enforced at the door.  Three layers:

* :class:`ModelSlot` — everything one served model owns: config, weight
  versions, the tier view cache, the paged (or contiguous) cache pool,
  the prefix cache, the scheduler, the staged-update hook points, and
  the serving stats.  This is the state that used to live flat on
  ``LicensedGateway``; the gateway now *wraps* a slot (attribute
  delegation), so every single-model behavior is unchanged while a
  fleet can compose N slots.
* :class:`TenantRegistry` — per-tenant (model, tier) entitlements,
  concurrent-request quotas, and token-bucket rate limits.  Checked
  twice: at ``submit`` (entitlement + concurrency + rate) and again at
  batch formation (entitlement only — a tenant revoked while its
  request queued must not reach a lane; a request already *decoding*
  completes, consistent with the gateway's never-re-masked-mid-
  generation rule for tier redefinitions).
* :class:`FleetGateway` — N slots behind one submit/step/run loop.
  Each scheduler iteration runs ONE slot's micro-batch (round-robin
  over slots with work) and advances at most ONE slot's active update
  stager, so weight syncs ride along without ever stacking N stager
  steps onto a single serving iteration.

Global cache budget
-------------------
Heterogeneous models disagree about what a "block" costs — a 3B GQA
transformer's 16-token block is orders of magnitude bigger than a
130M hybrid's — so the fleet budget is denominated in **bytes**
(``PagedCachePool.block_bytes`` is the per-slot exchange rate).  The
budget gates, it does not partition: any slot may use any fraction of
it, but admission takes ``min(local pool budget, global headroom)``
(wired through ``Scheduler.global_budget``) so one hot model cannot
admit past what the fleet has left.  Retained prefix chains anywhere
in the fleet count as *reclaimable* headroom — allocation evicts them
(the requesting slot's own chains first, then other slots', LRU within
each) before giving up.  When decode growth finds no headroom even
after reclaiming, the slot falls back to its own youngest-preemption;
preemption never crosses slots — evicting another model's requests to
grow your own would *be* the cross-model starvation the budget exists
to prevent.  Pure-recurrent models fall back to the contiguous
``CachePool`` whose memory is fixed at construction; they sit outside
the block budget (nothing to admit or reclaim block-wise).
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.sanitize import ServingSanitizer, sanitize_from_env
from repro.configs.base import ModelConfig
from repro.core.licensing import FULL_TIER, LicenseTier, apply_license
from repro.core.transport import (DirectTransport, RetryPolicy, Transport,
                                  TransportDisconnect, TransportError,
                                  TransportTimeout)
from repro.models import model as model_lib
from repro.serving.engine import right_align
from repro.serving.paging import NoPagedLeavesError, PagedCachePool, cdiv
from repro.serving.prefix import PrefixCache
from repro.serving.scheduler import (CachePool, GatewayRequest, RequestState,
                                     Scheduler, TierViewCache)
from repro.serving.telemetry import (FLEET_METRICS_KEYS,
                                     GATEWAY_METRICS_KEYS, Telemetry)
from repro.serving.tracing import AuditLog, TraceRecorder, merge_chrome_traces


class ModelSlot:
    """Per-model serving state: one config's pool + views + scheduler.

    Owns everything :class:`~repro.serving.gateway.LicensedGateway` used
    to keep flat on itself — the gateway delegates attribute access
    here, so ``gw.pool``, ``gw.stats``, ``gw.scheduler`` … all resolve
    to the slot.  A :class:`FleetGateway` composes many slots; a
    standalone gateway owns exactly one.  Constructor parameters are
    documented on ``LicensedGateway`` (they are the same knobs).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: Any,
        *,
        tiers: Optional[Dict[str, LicenseTier]] = None,
        quantized: bool = False,
        already_quantized: bool = False,
        materialize_int8_views: bool = False,
        max_batch: int = 8,
        max_prompt: int = 32,
        max_new_cap: int = 64,
        paged: bool = True,
        block_size: int = 16,
        num_blocks: Optional[int] = None,
        max_lanes: Optional[int] = None,
        watermark_blocks: int = 0,
        prefix_cache: bool = True,
        chunk_size: Optional[int] = None,
        kernel_decode: Optional[bool] = None,
        decode_pallas: Optional[str] = None,
        fuse_sampling: bool = True,
        record_logits: bool = False,
        view_capacity: int = 8,
        version: int = 1,
        server: Any = None,
        model: str = "model",
        history: int = 10_000,
        telemetry: Any = True,
        clock: Optional[Callable[[], float]] = None,
        transport: Optional[Transport] = None,
        retry_policy: Optional[RetryPolicy] = None,
        lease_ttl_s: float = 60.0,
        lease_grace_s: float = 300.0,
        lease_policy: str = "reject",
        lease_floor_tier: Optional[str] = None,
        quarantine_after: int = 3,
        sanitize: Optional[bool] = None,
    ):
        self.cfg = cfg
        # observability substrate first: the scheduler takes the clock,
        # and every layer below records through these.  ``telemetry``
        # accepts True (own registry), False (everything off — the
        # benchmark's baseline arm), or a shared Telemetry (a fleet
        # passes its own so all slots export one scrape page).
        self.clock = clock if clock is not None else time.perf_counter
        if isinstance(telemetry, Telemetry):
            self.telemetry = telemetry
        else:
            self.telemetry = Telemetry(clock=self.clock,
                                       enabled=bool(telemetry))
        self.obs = self.telemetry.enabled
        self.tracer = TraceRecorder(clock=self.clock, enabled=self.obs)
        self.audit = AuditLog(clock=self.clock, enabled=self.obs)
        self.quantized = quantized or already_quantized
        self.materialize_int8_views = materialize_int8_views
        if self.quantized and not already_quantized:
            from repro.serving.quantized import quantize_serving_params

            params = quantize_serving_params(params)
        self.max_batch = int(max_batch)
        self.max_prompt = int(max_prompt)
        self.max_new_cap = int(max_new_cap)
        self.capacity = self.max_prompt + self.max_new_cap

        self.version = int(version)
        self._weights: Dict[int, Any] = {self.version: params}
        self.tiers: Dict[str, LicenseTier] = dict(tiers or {})
        self.tiers.setdefault("full", FULL_TIER)
        self.views = TierViewCache(self._materialize, capacity=view_capacity)

        self.record_logits = bool(record_logits)
        self.fuse_sampling = bool(fuse_sampling) and not self.record_logits
        self.paged = bool(paged)
        if self.paged:
            self.max_lanes = int(max_lanes or self.max_batch)
            bpl = cdiv(self.capacity, int(block_size))
            try:
                self.pool = PagedCachePool(
                    cfg, self.max_lanes, self.capacity, int(block_size),
                    int(num_blocks) if num_blocks is not None
                    else self.max_lanes * bpl)
            except NoPagedLeavesError:
                # no per-token cache leaves (pure-recurrent model, or a
                # sliding window below the pool capacity caps every
                # attention cache): per-lane state is constant-size, so
                # paging has nothing to page — fall back to the slab
                self.paged = False
        # kernel-resident decode: supported whenever every attention
        # cache is paged — a sliding window below the pool capacity turns
        # attention caches into per-lane ring state the batched step
        # cannot address by block, so those models keep gather/scatter
        supported = self.paged and cfg.window == 0
        self.kernel_decode = (supported if kernel_decode is None
                              else bool(kernel_decode) and supported)
        if decode_pallas is None:
            decode_pallas = ("pallas" if jax.default_backend() == "tpu"
                             else "off")
        if decode_pallas not in ("off", "pallas", "interpret"):
            raise ValueError(f"decode_pallas={decode_pallas!r} not in "
                             f"('off', 'pallas', 'interpret')")
        self.decode_pallas = decode_pallas
        if self.paged:
            self._prefill_blocks = max(
                1, cdiv(self.max_prompt, self.pool.block_size))
            if (self.pool.num_blocks - int(watermark_blocks)
                    < self._prefill_blocks):
                raise ValueError(
                    f"watermark_blocks={watermark_blocks} leaves no room to "
                    f"admit a prefill ({self._prefill_blocks} blocks of "
                    f"{self.pool.num_blocks}) — the gateway would accept "
                    f"requests and never schedule them")
            # prompt-prefix reuse needs every non-paged leaf reconstructible
            # (position counters); float per-lane state can't be block-seeded
            self.prefix = (
                PrefixCache(self.pool.allocator, self.pool.block_size)
                if prefix_cache and self.pool.prefix_cacheable else None)
            # left-aligned chunked prefill: prompts advance chunk_size
            # tokens per prefill action, strictly interleaved with decode
            # steps.  It needs every per-lane non-paged cache leaf to be
            # a reconstructible position counter — the same condition as
            # prefix caching — so ring/SSM lane state opts the model out.
            chunk_ok = self.pool.prefix_cacheable
            if chunk_size is None:
                self.chunk_size = self.pool.block_size if chunk_ok else 0
            else:
                self.chunk_size = int(chunk_size)
                if self.chunk_size > 0 and not chunk_ok:
                    raise ValueError(
                        "chunked prefill needs reconstructible per-lane "
                        "cache state (the prefix_cache condition); this "
                        "model keeps ring/SSM lane state — pass "
                        "chunk_size=0 or leave it None")
            if self.chunk_size > 0:
                self.chunk_size = min(self.chunk_size, self.max_prompt)
            self.chunked = self.chunk_size > 0
            self.scheduler = Scheduler(
                self.max_lanes, self.max_batch,
                allocator=self.pool.allocator,
                prefill_blocks=(0 if self.chunked
                                else self._prefill_blocks),
                watermark_blocks=int(watermark_blocks),
                reclaimable=(self.prefix.reclaimable
                             if self.prefix is not None else None),
                suffix_bucket=(self._suffix_bucket
                               if self.prefix is not None
                               and not self.chunked else None),
                suffix_revalidate=(self._suffix_bucket_fresh
                                   if self.prefix is not None
                                   and not self.chunked else None),
                chunked=self.chunked,
                blocks_needed=(self._blocks_needed
                               if self.chunked else None),
                clock=self.clock)
            zero_cap = self.pool.padded_capacity
        else:
            if chunk_size:
                raise ValueError(
                    "chunked prefill requires the paged pool")
            self.chunk_size = 0
            self.chunked = False
            self.max_lanes = self.max_batch
            self.pool = CachePool(cfg, self.max_batch, self.capacity)
            self.scheduler = Scheduler(self.max_batch, self.max_batch,
                                       clock=self.clock)
            self.prefix = None
            zero_cap = self.capacity
        # opt-in runtime sanitizers (docs/ANALYSIS.md): shadow block
        # lifecycle + retracing sentinel.  Attached HERE — before any
        # block traffic — so the shadow sees every allocation.
        if sanitize is None:
            sanitize = sanitize_from_env()
        self.sanitizer = ServingSanitizer() if sanitize else None
        if self.sanitizer is not None:
            rt = self.sanitizer.retrace
            # sampling-variant families: unfused (1 key) or fused
            # (rng/topk on demand, <= 3 keys)
            for fam in ("steps", "prefix_prefill", "paged_decode"):
                rt.bound(fam, 4)
            if self.paged:
                self.sanitizer.attach_allocator(self.pool.allocator)
                bpl = self.pool.blocks_per_lane
                # chunked prefill pow2-buckets both axes:
                # b in {1,2,..,max_batch}, cols in {1,2,..,pow2(bpl)}
                rt.bound("prefill_chunk",
                         (self.max_batch.bit_length() + 1)
                         * (bpl.bit_length() + 2))
                # decode tables are trimmed to the batch's exact used
                # width (unbucketed by design): bounded by the lane cap
                rt.bound("decode_width", bpl)
        lane0 = model_lib.init_cache(cfg, 1, zero_cap)  # pristine batch-1 cache
        self._zero_lanes = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.max_batch, *x.shape)),
            lane0,
        )

        if transport is not None and server is None:
            server = transport.server
        self._server = server
        # every wire call to the license server goes through the
        # transport seam; a raw server gets the pass-through wrapper
        if transport is not None:
            self._transport: Optional[Transport] = transport
        elif isinstance(server, Transport):
            self._transport = server
            self._server = server.server
        else:
            self._transport = (DirectTransport(server)
                               if server is not None else None)
        self.retry_policy = (retry_policy if retry_policy is not None
                             else RetryPolicy())
        # license lease: grants are fresh for ttl after the last
        # successful server exchange; past that the slot serves DEGRADED
        # (pinned views only, no new server grants) until grace runs out,
        # then OFFLINE applies ``lease_policy`` at admission
        if lease_policy not in ("reject", "floor"):
            raise ValueError(f"lease_policy={lease_policy!r} not in "
                             f"('reject', 'floor')")
        self.lease_ttl_s = float(lease_ttl_s)
        self.lease_grace_s = float(lease_grace_s)
        self.lease_policy = lease_policy
        self.lease_floor_tier = lease_floor_tier
        self._lease_state = "healthy"
        self._lease_renewed_t = self.clock()  # guarded-by: owner(__init__, _lease_renew)
        self._lease_degraded_since: Optional[float] = None
        self._degraded_seconds = 0.0
        self._lease_recheck_t: Optional[float] = None
        self._tiers_stale = False     # refresh deferred by a wire fault
        # version quarantine: consecutive failed syncs per target version
        self.quarantine_after = int(quarantine_after)
        self._sync_failures: Dict[int, int] = {}
        self.quarantined_versions: set = set()
        self.model = model
        self._client = None           # EdgeClient when booted from a server
        self._server_tiers: set = set()  # tier names learned from the server
        # tier updates deferred while their requests are in flight;
        # value None = pending revocation
        self._pending_tiers: Dict[str, Optional[LicenseTier]] = {}
        # staged weight sync (serving/updates.py): the active stager (one
        # bounded step interleaved per scheduler step) and the version it
        # is pre-registering weights/views under before the flip
        self._stager = None
        self._staging_version: Optional[int] = None

        # fleet wiring (None when the slot serves standalone): the
        # wrapping gateway, the composing FleetGateway, and the finish
        # hook the fleet uses for tenant accounting
        self.gateway: Any = None
        self.fleet: Any = None
        self.on_finish: Optional[Callable[[GatewayRequest], None]] = None

        self._next_rid = 0
        # bounded: a long-lived gateway must not grow host memory with
        # every request served; metrics percentiles cover this window
        self.completed: "deque[GatewayRequest]" = deque(maxlen=history)
        self.trace: "deque[Tuple[str, str, Optional[int], int]]" = \
            deque(maxlen=history)
        self._drain_sink: Optional[List[GatewayRequest]] = None
        self.stats: Dict[str, int] = {
            "admitted": 0, "rejected": 0, "completed": 0,
            "prefill_batches": 0, "decode_steps": 0,
            "resident_decode_steps": 0, "tokens_generated": 0,
            "preempted": 0, "max_running": 0, "max_blocks_in_use": 0,
            # prefix-cache accounting: lane-tokens actually run through the
            # prefill step (the FLOPs axis the bench compares), prompt
            # tokens served from retained blocks, and copy-on-write copies
            "prefill_lane_tokens": 0, "prefix_tokens_reused": 0,
            "cow_copies": 0,
            # chunked prefill: prefill actions executed (one chunk each)
            "prefill_chunks": 0,
            # tenant enforcement: requests bounced by entitlement /
            # concurrency / rate-limit checks (submit OR admission)
            "quota_rejections": 0,
            # fault tolerance: wire retries across all sync/tier calls,
            # the subset whose cause was a timeout/disconnect, and
            # versions quarantined after repeated failed syncs
            "sync_retries": 0, "sync_timeouts": 0, "sync_quarantines": 0,
        }
        # prefix-aware admission: prefill batches served per suffix-width
        # bucket (the grouping decision, exported via metrics())
        self.bucket_batches: Dict[int, int] = {}

        # build the jit pair for the common case (all-greedy when fused);
        # _steps() dispatches per micro-batch, sharing the lru entries
        # across gateway instances over the same config
        from repro.serving.gateway import _compiled_steps

        if self.fuse_sampling:
            _compiled_steps(cfg, True, False, False)
        else:
            _compiled_steps(cfg, False)

        self._register_telemetry()
        # seed the audit ledger: the tiers this slot can serve from birth
        if self.obs:
            for name in self.tiers:
                self.audit.record("tier_grant", model=self.model, tier=name,
                                  version=self.version, source="config")

    # ---------------------------------------------------------- observability
    def _register_telemetry(self) -> None:
        """Register this slot's instruments (all labeled by model name).

        Counters and gauges are *pull*-backed: they read the ``stats``
        dict / scheduler / pool at export time, so the serving hot path
        pays nothing for them.  Only the latency histograms are push
        instruments — a bisect + bincount bump each, the cost the
        telemetry benchmark bounds."""
        t, lb = self.telemetry, {"model": self.model}
        stats = self.stats

        def _stat(key: str):
            return lambda: stats[key]

        for key, name, help_ in (
            ("admitted", "serving_requests_admitted_total",
             "Requests past admission"),
            ("rejected", "serving_requests_rejected_total",
             "Requests bounced at admission"),
            ("completed", "serving_requests_completed_total",
             "Requests that produced max_new_tokens"),
            ("tokens_generated", "serving_tokens_generated_total",
             "Tokens delivered across all requests"),
            ("prefill_batches", "serving_prefill_batches_total",
             "Prefill micro-batches executed"),
            ("prefill_chunks", "serving_prefill_chunks_total",
             "Chunked-prefill actions executed"),
            ("decode_steps", "serving_decode_steps_total",
             "Decode micro-batch steps executed"),
            ("preempted", "serving_preemptions_total",
             "Requests preempted on pool exhaustion"),
            ("quota_rejections", "serving_quota_rejections_total",
             "Tenant quota/rate/entitlement rejections"),
            ("prefix_tokens_reused", "serving_prefix_tokens_reused_total",
             "Prompt tokens served from the prefix cache"),
            ("cow_copies", "serving_cow_copies_total",
             "Copy-on-write block copies before shared-block writes"),
            ("sync_retries", "serving_sync_retries_total",
             "Wire-call retries across sync and tier fetches"),
            ("sync_timeouts", "serving_sync_timeouts_total",
             "Wire-call retries caused by timeouts/disconnects"),
            ("sync_quarantines", "serving_sync_quarantines_total",
             "Versions quarantined after repeated failed syncs"),
        ):
            t.counter(name, labels=lb, help=help_, fn=_stat(key))
        _LEASE_LEVEL = {"healthy": 0, "degraded": 1, "offline": 2}
        t.gauge("serving_license_lease_state", labels=lb,
                help="License lease state (0 healthy, 1 degraded, 2 offline)",
                fn=lambda: _LEASE_LEVEL[self._lease_state])
        t.counter("serving_degraded_seconds_total", labels=lb,
                  help="Cumulative seconds spent outside the healthy "
                       "lease state",
                  fn=self.degraded_seconds_total)
        t.gauge("serving_queue_depth", labels=lb,
                help="Requests waiting for admission",
                fn=lambda: len(self.scheduler.waiting))
        t.gauge("serving_running_requests", labels=lb,
                help="Requests holding a lane (prefilling or decoding)",
                fn=lambda: len(self.scheduler.running))
        t.gauge("serving_oldest_queue_wait_seconds", labels=lb,
                help="Age of the oldest queued request",
                fn=self.scheduler.oldest_wait_s)
        t.gauge("serving_weight_version", labels=lb,
                help="Weight version new admissions pin",
                fn=lambda: self.version)
        t.gauge("serving_view_cache_entries", labels=lb,
                help="Materialized (tier, version) weight views",
                fn=lambda: len(self.views))
        if self.paged:
            t.gauge("serving_cache_blocks_held", labels=lb,
                    help="Physical cache blocks allocated",
                    fn=lambda: self.pool.allocator.num_held)
            t.gauge("serving_cache_blocks_free", labels=lb,
                    help="Physical cache blocks on the free list",
                    fn=lambda: self.pool.allocator.num_free)
        if self.prefix is not None:
            t.gauge("serving_prefix_reclaimable_blocks", labels=lb,
                    help="Retained prefix blocks evictable on demand",
                    fn=self.prefix.reclaimable)
        h = t.histogram
        self.h_ttft = h("serving_ttft_seconds", labels=lb,
                        help="Submit to first token")
        self.h_gap = h("serving_inter_token_seconds", labels=lb,
                       help="Gap between consecutive tokens of one request")
        self.h_queue = h("serving_queue_wait_seconds", labels=lb,
                         help="Submit to lane assignment")
        self.h_prefill = h("serving_prefill_step_seconds", labels=lb,
                           help="Wall time of one prefill action")
        self.h_decode = h("serving_decode_step_seconds", labels=lb,
                          help="Wall time of one decode step")
        self.h_stager = h("serving_stager_step_seconds", labels=lb,
                          help="Wall time of one staged-update step "
                               "(the decode-stall bound)")
        t.declare(*GATEWAY_METRICS_KEYS)

    # ------------------------------------------- license lease & fault handling
    def degraded_seconds_total(self) -> float:
        """Cumulative wall time outside HEALTHY, including the open span."""
        total = self._degraded_seconds
        if self._lease_degraded_since is not None:
            total += self.clock() - self._lease_degraded_since
        return total

    def _lease_renew(self) -> None:
        """Record a successful server exchange.

        Timestamp-only store: safe to call from the background fetch
        worker.  State *transitions* (and their audit/trace events)
        happen lazily in :meth:`_lease_tick` on the serving thread."""
        self._lease_renewed_t = self.clock()

    def _lease_target(self, now: float) -> str:
        age = now - self._lease_renewed_t
        if age <= self.lease_ttl_s:
            return "healthy"
        if age <= self.lease_ttl_s + self.lease_grace_s:
            return "degraded"
        return "offline"

    def _lease_tick(self) -> None:
        """Advance the lease state machine (serving thread only).

        Purely time-driven: the target state is a function of the age of
        the last successful exchange vs ttl/grace, so a renewal from the
        fetch worker heals the lease on the next tick without any
        cross-thread state writes.  While unhealthy, a rate-limited probe
        (``production_version``) gives an idle gateway — no sync in
        flight, no tier fetches — a path back to HEALTHY."""
        if self._server is None:
            return
        now = self.clock()
        target = self._lease_target(now)
        if target != "healthy":
            # self-heal probe, at most ~4 per ttl so an unreachable
            # server costs bounded wire attempts per serving step
            interval = max(0.05, min(1.0, self.lease_ttl_s / 4))
            if (self._lease_recheck_t is None
                    or now - self._lease_recheck_t >= interval):
                self._lease_recheck_t = now
                try:
                    self._transport.production_version(self.model)
                    self._lease_renew()
                    target = "healthy"
                except (TransportError, KeyError):
                    pass
        if target == self._lease_state:
            return
        prev, self._lease_state = self._lease_state, target
        if prev == "healthy":
            self._lease_degraded_since = now
        elif target == "healthy":
            if self._lease_degraded_since is not None:
                self._degraded_seconds += now - self._lease_degraded_since
            self._lease_degraded_since = None
        event = ("lease_restored" if target == "healthy"
                 else "lease_" + target)
        if self.obs:
            self.audit.record(event, model=self.model, prev=prev,
                              state=target,
                              renew_age_s=round(now - self._lease_renewed_t, 3))
            self.tracer.instant("lease:" + target,
                                attrs={"model": self.model, "prev": prev})
        if target == "healthy" and self._tiers_stale:
            # a tier refresh was deferred by a wire fault mid-sync;
            # rerun it now that the server is reachable again
            owner = self.gateway if self.gateway is not None else self
            refresh = getattr(owner, "_refresh_server_tiers", None)
            if refresh is not None:
                refresh()

    def _lease_admission(self, license: str) -> Tuple[str, Optional[str]]:
        """Admission-time lease gate: ``(serve_as_tier, error)``.

        HEALTHY/DEGRADED serve every already-granted tier unchanged
        (DEGRADED only refuses *new* server grants — that lives in
        :meth:`_resolve_tier`).  OFFLINE applies the configured policy:
        ``floor`` substitutes the floor tier when it is locally known,
        ``reject`` (or a missing floor) bounces the request."""
        self._lease_tick()
        if self._lease_state != "offline":
            return license, None
        if (self.lease_policy == "floor"
                and self.lease_floor_tier is not None
                and self.lease_floor_tier in self.tiers):
            return self.lease_floor_tier, None
        return license, (f"license lease offline (policy="
                         f"{self.lease_policy}): cannot validate tier "
                         f"{license!r} against an unreachable server")

    def _count_wire_retry(self, attempt: int, exc: BaseException,
                          delay: float, to_version: Optional[int] = None,
                          ) -> None:
        """RetryPolicy ``on_retry`` hook: counters + audit per backoff."""
        self.stats["sync_retries"] += 1
        if isinstance(exc, (TransportTimeout, TransportDisconnect)):
            self.stats["sync_timeouts"] += 1
        if self.obs:
            self.audit.record("sync_retry", model=self.model,
                              attempt=attempt, error=type(exc).__name__,
                              backoff_s=round(delay, 4),
                              to_version=to_version)

    def _note_sync_failure(self, version: int) -> None:
        """Count a consecutive failed sync toward quarantining ``version``."""
        n = self._sync_failures.get(version, 0) + 1
        self._sync_failures[version] = n
        if (n >= self.quarantine_after
                and version not in self.quarantined_versions):
            self.quarantined_versions.add(version)
            self.stats["sync_quarantines"] += 1
            if self.obs:
                self.audit.record("sync_quarantine", model=self.model,
                                  version=version, failures=n)
                self.tracer.instant("sync:quarantine",
                                    attrs={"model": self.model,
                                           "version": version})

    def _note_sync_success(self, version: int) -> None:
        self._sync_failures.pop(version, None)
        self._lease_renew()

    def clear_quarantine(self, version: Optional[int] = None) -> None:
        """Operator override: drop the quarantine (one version or all)."""
        if version is None:
            self.quarantined_versions.clear()
            self._sync_failures.clear()
        else:
            self.quarantined_versions.discard(version)
            self._sync_failures.pop(version, None)

    # ------------------------------------------------------------ weight views
    def _resolve_tier(self, name: str) -> LicenseTier:
        tier = self.tiers.get(name)
        if tier is None and self._server is not None:
            # an unhealthy lease refuses NEW grants: every tier served
            # during an outage must have been validated while the server
            # was reachable (the pinned-view guarantee)
            if self._lease_state != "healthy":
                raise KeyError(
                    f"unknown license tier {name!r} (lease "
                    f"{self._lease_state}: refusing new tier grant)")
            try:
                tier = self.retry_policy.run(
                    lambda: self._transport.tier(self.model, name),
                    on_retry=self._count_wire_retry)
                self._lease_renew()
                self.tiers[name] = tier
                self._server_tiers.add(name)
                if self.obs:
                    self.audit.record("tier_grant", model=self.model,
                                      tier=name, version=self.version,
                                      source="server")
            except KeyError:
                tier = None
            except TransportError as exc:
                raise KeyError(
                    f"unknown license tier {name!r} (license server "
                    f"unreachable: {exc})") from exc
        if tier is None:
            raise KeyError(f"unknown license tier {name!r}")
        return tier

    def _materialize(self, tier_name: str, version: Optional[int]):
        """Build the (params, intervals) view served to one (tier, version)."""
        tier = self._resolve_tier(tier_name)
        if self.obs:
            self.audit.record("view_materialize", model=self.model,
                              tier=tier_name, version=version,
                              fingerprint=tier.fingerprint())
        base = self._weights[version]
        if not self.quantized:
            return apply_license(base, tier), None
        if self.materialize_int8_views:
            from repro.serving.quantized import materialize_licensed_view

            return materialize_licensed_view(base, tier, self.cfg.dtype), None
        from repro.serving.quantized import tier_intervals

        return base, tier_intervals(tier)

    # ------------------------------------------------------ scheduler callbacks
    def _suffix_bucket(self, req: GatewayRequest, fresh: bool = False) -> int:
        """Prefix-aware admission probe: the uncached suffix width this
        request would prefill at — ``max_prompt`` when cold, down to 1
        for a full match (the last position always recomputes).  Uses
        the side-effect-free :meth:`PrefixCache.peek` so scheduling
        probes never touch LRU order or reference counts, and caches the
        answer on the request keyed by the cache's mutation epoch — a
        deep backlog re-probes only after an insert/evict/drop actually
        changed what a prompt could match.

        The cached probe is a scheduling *hint*, not a fact: an eviction
        between the probe and batch formation (or anything else that
        desynchronizes the stored epoch from the tree) would let a stale
        bucket mis-group the batch.  ``fresh=True`` bypasses the cache —
        the scheduler re-validates every selected member through
        :meth:`_suffix_bucket_fresh` at formation time."""
        cached = None if fresh else getattr(req, "_suffix_probe", None)
        if cached is not None and cached[0] == self.prefix.epoch:
            return cached[1]
        toks = right_align([req.prompt], self.max_prompt, 1)[0]
        matched = self.prefix.peek((req.license, req.version), toks)
        bucket = self.max_prompt - min(matched, self.max_prompt - 1)
        req._suffix_probe = (self.prefix.epoch, bucket)
        return bucket

    def _suffix_bucket_fresh(self, req: GatewayRequest) -> int:
        """Cache-bypassing probe for batch-formation re-validation."""
        return self._suffix_bucket(req, fresh=True)

    def _blocks_needed(self, req: GatewayRequest) -> int:
        """Chunked-admission block budget: blocks covering the TRUE
        prompt length — conservative, since adopted prefix blocks only
        reduce the fresh allocation."""
        return max(1, cdiv(len(req.prompt), self.pool.block_size))


# --------------------------------------------------------------------- tenants
def _pattern_match(pattern: str, value: str) -> bool:
    return pattern == "*" or pattern == value


class _Tenant:
    """One tenant's entitlements, limits, bucket state, and counters."""

    __slots__ = ("name", "entitlements", "max_concurrent", "rate", "burst",
                 "bucket", "last_refill", "inflight", "submitted", "admitted",
                 "completed", "tokens_generated", "quota_rejections")

    def __init__(self, name: str,
                 entitlements: Iterable,
                 max_concurrent: Optional[int],
                 rate: Optional[float], burst: Optional[float]):
        self.name = name
        self.entitlements: set = set()
        for ent in entitlements:
            self.entitlements.add(_parse_entitlement(ent))
        self.max_concurrent = (None if max_concurrent is None
                               else int(max_concurrent))
        self.rate = None if rate is None else float(rate)
        self.burst = (float(burst) if burst is not None
                      else (self.rate if self.rate is not None else 0.0))
        if self.rate is not None and self.burst < 1.0:
            raise ValueError(
                f"burst={self.burst} < 1: tenant {name!r} could never "
                f"pass the rate limit")
        self.bucket = self.burst          # start full: a burst is allowed
        self.last_refill: Optional[float] = None
        self.inflight = 0
        self.submitted = 0
        self.admitted = 0
        self.completed = 0
        self.tokens_generated = 0
        self.quota_rejections = 0


def _parse_entitlement(ent) -> Tuple[str, str]:
    """Accept ``(model, tier)`` tuples or ``"model:tier"`` strings;
    ``"*"`` wildcards either side."""
    if isinstance(ent, str):
        model, _, tier = ent.partition(":")
        return (model or "*", tier or "*")
    model, tier = ent
    return (str(model), str(tier))


class TenantRegistry:
    """Per-tenant licensing enforcement: entitlements, quotas, rates.

    * **Entitlements** are (model, tier) patterns (``"*"`` wildcards
      either side): which licensed variants a tenant may request at all.
    * **Concurrency** (``max_concurrent``): live requests (queued or
      running, fleet-wide) per tenant.  ``0`` is a valid zero-quota
      tenant — entitled on paper, admitted never.  ``None`` = unlimited.
    * **Rate** (``rate`` requests/s refilled into a bucket of capacity
      ``burst``): a standard token bucket, charged one token per
      accepted submit.  ``clock`` is injectable so tests drive time
      deterministically.

    :meth:`acquire` runs all three checks and charges on success;
    :meth:`cancel` refunds a charge whose request the gateway then
    bounced for non-tenant reasons (bad prompt, unknown tier);
    :meth:`drop_queued` settles a request rejected at batch formation
    (entitlement revoked while queued — the rate token is *not*
    refunded, the submit was served); :meth:`finish` settles a
    completed request.
    """

    def __init__(self, *, clock: Callable[[], float] = time.monotonic):
        self._clock = clock
        self._tenants: Dict[str, _Tenant] = {}
        # licensing ledger (tracing.AuditLog), wired by FleetGateway so
        # tenant definition changes land in the fleet's audit stream
        self.audit: Any = None

    # ------------------------------------------------------------- definition
    def register(self, name: str, *,
                 entitlements: Iterable = ("*:*",),
                 max_concurrent: Optional[int] = None,
                 rate: Optional[float] = None,
                 burst: Optional[float] = None) -> None:
        """Define (or redefine) a tenant.  Redefinition keeps live
        inflight/usage counters so re-provisioning a tenant mid-flight
        cannot leak or double-count its running requests."""
        fresh = _Tenant(name, entitlements, max_concurrent, rate, burst)
        old = self._tenants.get(name)
        if old is not None:
            for k in ("inflight", "submitted", "admitted", "completed",
                      "tokens_generated", "quota_rejections"):
                setattr(fresh, k, getattr(old, k))
        self._tenants[name] = fresh
        if self.audit is not None:
            self.audit.record(
                "tenant_register", tenant=name,
                entitlements=sorted(f"{m}:{t}" for m, t in fresh.entitlements),
                max_concurrent=fresh.max_concurrent, rate=fresh.rate)

    def grant(self, name: str, model: str = "*", tier: str = "*") -> None:
        self._tenants[name].entitlements.add((model, tier))
        if self.audit is not None:
            self.audit.record("entitlement_grant", tenant=name, model=model,
                              tier=tier)

    def revoke(self, name: str, model: str = "*", tier: str = "*") -> None:
        """Remove every entitlement pattern that would entitle
        (model, tier) — including broader wildcard patterns, so after
        ``revoke(t, m, x)`` the tenant is guaranteed not entitled to
        (m, x); ``"*"`` arguments match any pattern component.  Queued
        requests of the tenant are rejected at the next batch
        formation; already decoding ones complete (never cancelled
        mid-generation)."""
        t = self._tenants[name]
        t.entitlements = {
            (pm, pt) for (pm, pt) in t.entitlements
            if not ((model == "*" or _pattern_match(pm, model))
                    and (tier == "*" or _pattern_match(pt, tier)))}

    def known(self, name: str) -> bool:
        return name in self._tenants

    def entitled(self, name: str, model: str, tier: str) -> bool:
        t = self._tenants.get(name)
        if t is None:
            return False
        return any(_pattern_match(pm, model) and _pattern_match(pt, tier)
                   for (pm, pt) in t.entitlements)

    # ------------------------------------------------------------ enforcement
    def _refill(self, t: _Tenant) -> None:
        if t.rate is None:
            return
        now = self._clock()
        if t.last_refill is not None:
            t.bucket = min(t.burst, t.bucket + (now - t.last_refill) * t.rate)
        t.last_refill = now

    def acquire(self, name: str, model: str, tier: str) -> Optional[str]:
        """All submit-time checks; charges (inflight + one bucket token)
        and returns None on success, else the rejection reason."""
        t = self._tenants.get(name)
        if t is None:
            return f"unknown tenant {name!r}"
        t.submitted += 1
        if not self.entitled(name, model, tier):
            t.quota_rejections += 1
            return (f"tenant {name!r} is not entitled to "
                    f"({model!r}, {tier!r})")
        if t.max_concurrent is not None and t.inflight >= t.max_concurrent:
            t.quota_rejections += 1
            return (f"tenant {name!r} at its concurrent-request quota "
                    f"({t.max_concurrent})")
        if t.rate is not None:
            self._refill(t)
            if t.bucket < 1.0:
                t.quota_rejections += 1
                return (f"tenant {name!r} rate-limited "
                        f"({t.rate:g} req/s, burst {t.burst:g})")
            t.bucket -= 1.0
        t.inflight += 1
        t.admitted += 1
        return None

    def cancel(self, name: str) -> None:
        """Refund an :meth:`acquire` whose request the gateway bounced
        for non-tenant reasons — no service was rendered, so the rate
        token comes back too."""
        t = self._tenants[name]
        t.inflight -= 1
        t.admitted -= 1
        if t.rate is not None:
            t.bucket = min(t.burst, t.bucket + 1.0)

    def drop_queued(self, name: str) -> None:
        """Settle a request rejected at batch formation (entitlement
        revoked while it queued).  Counts as a quota rejection; the rate
        token stays spent."""
        t = self._tenants[name]
        t.inflight -= 1
        t.quota_rejections += 1

    def finish(self, name: str, tokens: int) -> None:
        t = self._tenants.get(name)
        if t is None:                      # tenant deleted mid-flight
            return
        t.inflight -= 1
        t.completed += 1
        t.tokens_generated += int(tokens)

    # ----------------------------------------------------------------- stats
    def stats(self) -> Dict[str, Dict[str, Any]]:
        out: Dict[str, Dict[str, Any]] = {}
        for name, t in self._tenants.items():
            self._refill(t)
            out[name] = {
                "inflight": t.inflight, "submitted": t.submitted,
                "admitted": t.admitted, "completed": t.completed,
                "tokens_generated": t.tokens_generated,
                "quota_rejections": t.quota_rejections,
                "max_concurrent": t.max_concurrent,
                "rate": t.rate,
                "rate_tokens_available": (None if t.rate is None
                                          else t.bucket),
                "entitlements": sorted(
                    f"{m}:{ti}" for (m, ti) in t.entitlements),
            }
        return out


# ----------------------------------------------------------------------- fleet
class FleetGateway:
    """N :class:`ModelSlot`\\ s behind one submit/step/run loop.

    ``add_model`` registers a model (constructing its wrapping
    ``LicensedGateway``); ``attach`` adopts an existing gateway (e.g.
    one booted via ``LicensedGateway.from_server``).  ``submit`` routes
    by model name and enforces the :class:`TenantRegistry`; ``step``
    executes ONE micro-batch — round-robin over slots with work — plus
    at most ONE slot's active update-stager step; ``run`` drains every
    slot's queue.

    ``cache_budget_bytes`` caps the *sum* of allocated cache-block bytes
    across every paged slot (see the module docstring for the
    byte-denominated budget semantics).  ``None`` = no global cap (each
    slot is bounded by its own pool alone).
    """

    def __init__(self, *, cache_budget_bytes: Optional[int] = None,
                 tenants: Optional[TenantRegistry] = None,
                 telemetry: Any = True,
                 clock: Optional[Callable[[], float]] = None,
                 sanitize: Optional[bool] = None):
        self.cache_budget_bytes = (None if cache_budget_bytes is None
                                   else int(cache_budget_bytes))
        self.sanitize = sanitize           # default for add_model slots
        # one shared registry for the whole fleet: ``add_model`` passes
        # it to every slot (distinct {"model": name} labels keep their
        # instruments apart), ``attach`` adopts a standalone gateway's
        self.clock = clock if clock is not None else time.perf_counter
        if isinstance(telemetry, Telemetry):
            self.telemetry = telemetry
        else:
            self.telemetry = Telemetry(clock=self.clock,
                                       enabled=bool(telemetry))
        self.obs = self.telemetry.enabled
        self.audit = AuditLog(clock=self.clock, enabled=self.obs)
        self.tenants = (tenants if tenants is not None
                        else TenantRegistry(clock=self.clock))
        self.tenants.audit = self.audit
        self.gateways: Dict[str, Any] = {}
        self._rr = 0                       # slot round-robin cursor
        self._stager_rr = 0                # stager round-robin cursor
        self._steps = 0
        self._t0: Optional[float] = None   # first-step timestamp (tokens/s)
        self._register_telemetry()

    # ---------------------------------------------------------- observability
    def _register_telemetry(self) -> None:
        """Fleet-level instruments: budget occupancy gauges plus a
        dynamic per-tenant collector (tenants register at any time, so
        their instruments are enumerated at scrape time rather than
        pre-registered)."""
        t = self.telemetry
        t.gauge("fleet_models", help="Registered model slots",
                fn=lambda: len(self.gateways))
        t.counter("fleet_steps_total", help="Fleet scheduler iterations",
                  fn=lambda: self._steps)
        t.gauge("fleet_cache_budget_bytes",
                help="Global cache byte budget (0 = uncapped)",
                fn=lambda: self.cache_budget_bytes or 0)
        t.gauge("fleet_cache_used_bytes",
                help="Cache block bytes allocated fleet-wide",
                fn=self.used_cache_bytes)
        t.gauge("fleet_cache_reclaimable_bytes",
                help="Bytes held only by retained prefix chains",
                fn=self.reclaimable_cache_bytes)
        t.register_collector(self._tenant_collector)
        t.declare(*FLEET_METRICS_KEYS)

    def _tenant_collector(self):
        for name, s in self.tenants.stats().items():
            lb = {"tenant": name}
            yield ("tenant_inflight", "gauge",
                   "Live (queued or running) requests", lb, s["inflight"])
            yield ("tenant_submitted_total", "counter",
                   "Requests submitted", lb, s["submitted"])
            yield ("tenant_completed_total", "counter",
                   "Requests completed", lb, s["completed"])
            yield ("tenant_tokens_generated_total", "counter",
                   "Tokens delivered", lb, s["tokens_generated"])
            yield ("tenant_quota_rejections_total", "counter",
                   "Entitlement/concurrency/rate rejections", lb,
                   s["quota_rejections"])

    def render_prometheus(self) -> str:
        """One scrape page covering every slot plus the fleet gauges."""
        return self.telemetry.render_prometheus()

    def chrome_trace(self) -> str:
        """Whole-fleet Chrome trace: one pid per model, one timebase."""
        return merge_chrome_traces(
            (name, gw.tracer) for name, gw in self.gateways.items())

    def audit_events(self, event: Optional[str] = None) -> List[Dict[str, Any]]:
        """Fleet-wide licensing ledger: the fleet's own records (tenant
        definitions, quota rejections) merged with every slot's, ordered
        by (ts, seq)."""
        logs = [self.audit] + [gw.audit for gw in self.gateways.values()]
        merged = AuditLog.merge(logs)
        if event is not None:
            merged = [e for e in merged if e["event"] == event]
        return merged

    # ------------------------------------------------------------ registration
    def add_model(self, name: str, cfg: ModelConfig, params: Any,
                  **kw) -> Any:
        """Construct and register one model's gateway.  ``kw`` are
        ``LicensedGateway`` knobs (tiers, pool geometry, …)."""
        from repro.serving.gateway import LicensedGateway

        kw.pop("model", None)
        kw.setdefault("telemetry", self.telemetry)
        kw.setdefault("clock", self.clock)
        kw.setdefault("sanitize", self.sanitize)
        gw = LicensedGateway(cfg, params, model=name, **kw)
        return self.attach(gw)

    def attach(self, gw: Any) -> Any:
        """Adopt an existing ``LicensedGateway`` as one slot (keyed by
        its ``model`` name) and wire the fleet hooks into its slot and
        scheduler."""
        name = gw.model
        if name in self.gateways:
            raise ValueError(f"model {name!r} already registered")
        if gw.slot.fleet is not None:
            raise ValueError(f"gateway {name!r} already belongs to a fleet")
        if self.cache_budget_bytes is not None and gw.paged:
            # every paged slot must be able to run one full-capacity
            # request to completion even when every OTHER slot holds one
            # too — otherwise a budget-bound fleet can admit requests
            # that no amount of reclaim or (within-slot) preemption can
            # ever finish
            need = sum(cdiv(g.capacity, g.pool.block_size)
                       * g.pool.block_bytes
                       for g in list(self.gateways.values()) + [gw]
                       if g.paged)
            if need > self.cache_budget_bytes:
                raise ValueError(
                    f"cache_budget_bytes={self.cache_budget_bytes} cannot "
                    f"hold one full request per paged slot ({need} bytes "
                    f"across {len(self.gateways) + 1} models)")
        gw.slot.fleet = self
        gw.slot.on_finish = self._on_finish
        if gw.paged:
            gw.scheduler.global_budget = \
                lambda g=gw: self._slot_headroom(g)
        gw.scheduler.admission_filter = \
            lambda r, g=gw: self._admission_ok(g, r)
        # a standalone gateway brings its own registry: fold its
        # instruments into the fleet's scrape page (adopt() is a no-op
        # for add_model slots, which already share self.telemetry)
        self.telemetry.adopt(gw.telemetry)
        self.gateways[name] = gw
        return gw

    def _paged(self) -> List[Any]:
        return [g for g in self.gateways.values() if g.paged]

    # ---------------------------------------------------------- global budget
    def used_cache_bytes(self) -> int:
        """Bytes of cache blocks currently allocated fleet-wide (running
        requests' chains AND retained prefix chains)."""
        return sum(g.pool.block_bytes * g.pool.allocator.num_held
                   for g in self._paged())

    def reclaimable_cache_bytes(self) -> int:
        """Bytes held only by prefix-cache retained chains — freeable on
        demand, so they count as admission headroom."""
        return sum(g.pool.block_bytes * g.prefix.reclaimable()
                   for g in self._paged() if g.prefix is not None)

    def _slot_headroom(self, gw: Any) -> int:
        """How many MORE of ``gw``'s blocks the fleet budget can cover,
        counting every slot's reclaimable chains as free — the
        ``Scheduler.global_budget`` hook."""
        if self.cache_budget_bytes is None:
            return gw.pool.num_blocks
        free = (self.cache_budget_bytes - self.used_cache_bytes()
                + self.reclaimable_cache_bytes())
        return max(0, int(free) // gw.pool.block_bytes)

    def _ensure_headroom(self, gw: Any, n: int) -> bool:
        """Make strict room for ``n`` of ``gw``'s blocks under the
        budget, evicting retained prefix chains — ``gw``'s own first
        (freeing them also helps its local allocation), then other
        slots', LRU within each.  Returns False when the budget still
        cannot cover it (every remaining byte is pinned by running
        requests) — the caller falls back to within-slot preemption."""
        if self.cache_budget_bytes is None:
            return True
        need = n * gw.pool.block_bytes

        def free() -> int:
            return self.cache_budget_bytes - self.used_cache_bytes()

        if free() >= need:
            return True
        for g in [gw] + [g for g in self._paged() if g is not gw]:
            if g.prefix is None:
                continue
            while free() < need and g.prefix.reclaimable() > 0:
                want = cdiv(need - free(), g.pool.block_bytes)
                if g.prefix.evict(want) == 0:
                    break
        return free() >= need

    # -------------------------------------------------------------- admission
    def _admission_ok(self, gw: Any, req: GatewayRequest) -> bool:
        """Batch-formation entitlement re-check (``admission_filter``):
        a tenant revoked since submit must not reach a lane.  In-flight
        requests are never revisited — a revocation drains, it never
        cancels."""
        if req.tenant is None:
            return True
        if self.tenants.entitled(req.tenant, gw.model, req.license):
            return True
        req.state = RequestState.REJECTED
        req.error = (f"tenant {req.tenant!r} entitlement to "
                     f"({gw.model!r}, {req.license!r}) revoked while queued")
        self.tenants.drop_queued(req.tenant)
        gw.stats["quota_rejections"] += 1
        gw.stats["rejected"] += 1
        if self.obs:
            self.audit.record("tenant_reject", tenant=req.tenant,
                              model=gw.model, tier=req.license,
                              reason="entitlement revoked while queued")
        return False

    def submit(self, model: str, prompt, *, tenant: Optional[str] = None,
               license: str = "full", **kw) -> GatewayRequest:
        """Route one request to its model slot, enforcing the tenant's
        entitlements, concurrency quota, and rate limit first.  A
        rejection (tenant or gateway) returns a REJECTED request with
        ``error`` set, exactly like single-gateway admission."""
        gw = self.gateways.get(model)
        if gw is None:
            req = GatewayRequest(
                prompt=np.asarray(prompt, np.int32).reshape(-1),
                license=license, model=model, tenant=tenant)
            req.state = RequestState.REJECTED
            req.error = f"unknown model {model!r}"
            return req
        if tenant is not None:
            reason = self.tenants.acquire(tenant, model, license)
            if reason is not None:
                req = GatewayRequest(
                    prompt=np.asarray(prompt, np.int32).reshape(-1),
                    license=license, model=model, tenant=tenant)
                req.state = RequestState.REJECTED
                req.error = reason
                gw.stats["quota_rejections"] += 1
                gw.stats["rejected"] += 1
                if self.obs:
                    self.audit.record("quota_reject", tenant=tenant,
                                      model=model, tier=license,
                                      reason=reason)
                return req
        req = gw.submit(prompt, license=license, tenant=tenant, **kw)
        if tenant is not None and req.state is RequestState.REJECTED:
            # bounced after the quota charge for a non-tenant reason
            # (bad prompt length, unknown tier, bad seed): refund
            self.tenants.cancel(tenant)
        return req

    # -------------------------------------------------------------- execution
    def step(self) -> Optional[Any]:
        """ONE fleet iteration: the next slot (round-robin) with work
        runs one micro-batch, and at most ONE slot's active update
        stager advances one bounded step.  Returns the executed
        ``ScheduledAction`` (its ``model`` field names the slot), or
        None when no slot has work."""
        if self._t0 is None:
            self._t0 = self.clock()
        self._steps += 1
        order = list(self.gateways.values())
        act = None
        n = len(order)
        for i in range(n):
            gw = order[(self._rr + i) % n]
            act = gw.step(drive_stager=False)
            if act is not None:
                self._rr = (self._rr + i + 1) % n
                break
        else:
            self._rr = (self._rr + 1) % n if n else 0
        syncing = [g for g in order if g.sync_active]
        if syncing:
            try:
                syncing[self._stager_rr % len(syncing)].sync_step()
            except TransportError:
                # retries exhausted: the stager already aborted (weights
                # dropped, failure counted toward quarantine) — the slot
                # keeps serving its current version
                pass
            self._stager_rr += 1
        return act

    def run(self, max_steps: int = 1_000_000) -> List[GatewayRequest]:
        """Drain every slot's queue; returns requests completed during
        this call (all models interleaved, in completion order).  Active
        staged syncs keep stepping after the queues empty, so returning
        implies any begun version flip landed."""
        drained: List[GatewayRequest] = []
        for gw in self.gateways.values():
            gw._drain_sink = drained
        try:
            for _ in range(max_steps):
                if self.step() is None and not any(
                        g.sync_active for g in self.gateways.values()):
                    break
        finally:
            for gw in self.gateways.values():
                gw._drain_sink = None
        return drained

    def _on_finish(self, req: GatewayRequest) -> None:
        if req.tenant is not None:
            self.tenants.finish(req.tenant, len(req.out_tokens))

    # ----------------------------------------------------------------- metrics
    def metrics(self) -> Dict[str, Any]:
        """Three sections: ``fleet`` (budget + totals), ``models`` (one
        per slot: the EXACT single-gateway ``LicensedGateway.metrics()``
        schema, plus a fleet-computed ``tokens_per_s``), and ``tenants``
        (registry counters + live blocks held + oldest queue wait, per
        tenant).  The per-model schema embedding is load-bearing: one
        dashboard/parser serves both deployments, and
        ``telemetry.validate_fleet_metrics`` asserts it."""
        now = self.clock()
        elapsed = (now - self._t0) if self._t0 is not None else 0.0
        models: Dict[str, Any] = {}
        for name, gw in self.gateways.items():
            toks = gw.stats["tokens_generated"]
            models[name] = {
                **gw.metrics(),
                "tokens_per_s": (toks / elapsed if elapsed > 0 else 0.0),
            }
        tenants = self.tenants.stats()
        for t in tenants.values():
            t["blocks_held"] = 0
            t["oldest_wait_s"] = 0.0
            t["tokens_per_s"] = (t["tokens_generated"] / elapsed
                                 if elapsed > 0 else 0.0)
        for gw in self.gateways.values():
            slot_now = gw.clock()          # slot timestamps, slot clock
            for r in gw.scheduler.running:
                if r.tenant in tenants:
                    tenants[r.tenant]["blocks_held"] += len(r.blocks)
            for r in gw.scheduler.waiting:
                if r.tenant in tenants:
                    t = tenants[r.tenant]
                    t["oldest_wait_s"] = max(t["oldest_wait_s"],
                                             slot_now - r.submit_t)
        fleet = {
            "models": len(self.gateways),
            "steps": self._steps,
            "cache_budget_bytes": self.cache_budget_bytes,
            "cache_used_bytes": self.used_cache_bytes(),
            "cache_reclaimable_bytes": self.reclaimable_cache_bytes(),
            "tokens_generated": sum(m["tokens_generated"]
                                    for m in models.values()),
            "completed": sum(m["completed"] for m in models.values()),
            "quota_rejections": sum(m["quota_rejections"]
                                    for m in models.values()),
            "oldest_wait_s": max(
                [m["oldest_wait_s"] for m in models.values()] or [0.0]),
        }
        return {"fleet": fleet, "models": models, "tenants": tenants}
