"""Shared-prefix radix cache: tier-scoped prompt-prefix reuse over paged blocks.

Tier-homogeneous traffic through the licensed gateway repeatedly
prefill-computes the same system/prompt prefixes — identical tokens at
identical positions under the same ``(tier, version)`` weight view
produce identical KV blocks, so recomputing them is pure wasted FLOPs
and pool space.  This module retains those blocks after their request
finishes and hands them to later requests (SGLang-style radix caching
on top of the vLLM-style block pool in ``serving/paging.py``):

* :class:`PrefixCache` keeps one radix tree **per (tier, version)
  scope**.  Scoping is the licensing boundary: a cached block encodes
  activations of a *masked weight view*, so a ``free``-tier prefix must
  never seed a ``pro``-tier request even when the tokens match —
  cross-tier reuse would leak the better view's representations.  Each
  tree node covers one physical block (up to ``block_size`` tokens;
  the last node of a chain may be *partial* — prompt buckets are fixed
  per scope, so partial fills only ever terminate a chain and never
  need splitting).
* Retention holds one allocator **reference** per tree-referenced
  block.  A block whose refcount is exactly 1 is held by the tree alone
  ("refcount-0" from the requests' point of view) and is *reclaimable*:
  :meth:`evict` walks leaves in LRU order and drops tree references
  until enough blocks actually return to the free list, skipping
  blocks still pinned by running requests.  A request's table holds the
  whole chain of any block it holds, so a refcount-1 node can never
  have a request-pinned descendant — its entire subtree is evictable.
* :meth:`match` returns the longest cached chain for a prompt and takes
  a reference on every returned block for the caller; :meth:`insert`
  donates a freshly prefilled chain (the tree takes its own references)
  so the *first* request with a prompt populates the cache for the rest.

Writes never target a shared block: the gateway routes prefill
write-back of adopted blocks to the null block, and decode
copy-on-writes a shared tail block before its first write into it
(``PagedCachePool.copy_block``).
"""
from __future__ import annotations

import heapq
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

from repro.serving.paging import BlockAllocator


class _Node:
    """One cached block: ``tokens`` (its chunk, ``fill`` of them) under a
    parent chunk chain.  ``children`` is keyed by the child's full token
    tuple, so full-block lookup is one dict probe."""

    __slots__ = ("tokens", "block", "parent", "children", "last_used")

    def __init__(self, tokens: Tuple[int, ...], block: int,
                 parent: "_Node"):
        self.tokens = tokens
        self.block = block
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_used = 0

    @property
    def fill(self) -> int:
        return len(self.tokens)


class _Root(_Node):
    def __init__(self):
        super().__init__((), -1, None)  # type: ignore[arg-type]


class PrefixCache:
    """Radix trees of retained prompt-block chains, one per scope.

    The allocator is shared with the gateway's :class:`PagedCachePool`;
    the cache only ever moves *references*, never block contents.
    """

    def __init__(self, allocator: BlockAllocator, block_size: int):
        self.allocator = allocator
        self.block_size = int(block_size)
        self._scopes: Dict[Hashable, _Root] = {}
        self._by_block: Dict[int, _Node] = {}   # block id -> retaining node
        # count of tree blocks whose ONLY reference is the tree's — the
        # evictable set.  Kept O(1)-exact across every transition: the
        # tree sees its own incref/decref sites, and the gateway reports
        # request releases via note_release().  Admission reads this
        # every scheduling step, so it must not walk the tree.
        self._retained = 0
        self._clock = 0                  # LRU tick, bumped on every touch
        self.hits = 0                    # match() calls that reused >=1 block
        self.misses = 0
        self.hit_tokens = 0              # cumulative tokens served from cache
        self.inserted_blocks = 0         # chains donated by finished prefills
        self.evicted_blocks = 0          # tree references dropped under pressure
        self.dropped_blocks = 0          # scope invalidations (version GC,
                                         # tier redefinition) — not pressure

    # ----------------------------------------------------------- structure
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _nodes(self, root: _Node) -> List[_Node]:
        out, stack = [], list(root.children.values())
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children.values())
        return out

    def num_blocks(self) -> int:
        """Total blocks referenced by all trees (any refcount)."""
        return len(self._by_block)

    def reclaimable(self) -> int:
        """Blocks held by the tree alone (allocator refcount == 1) —
        exactly the blocks :meth:`evict` can return to the free list.
        A request holds the full chain of every block it shares, so a
        refcount-1 node cannot have a request-pinned descendant; the
        count is exact (an O(1) maintained counter, asserted against a
        full recount in the tests)."""
        return self._retained

    def note_release(self, block: int) -> None:
        """Gateway hook: a request dropped its reference on ``block`` and
        exactly one reference remains.  If that survivor is the tree's,
        the block just became reclaimable."""
        if block in self._by_block:
            self._retained += 1

    # --------------------------------------------------------------- match
    def match(self, scope: Hashable, tokens: Sequence[int]) \
            -> Tuple[List[int], int]:
        """Longest cached chain for ``tokens`` under ``scope``.

        Returns ``(blocks, matched_tokens)`` in logical order; every
        returned block has been ``incref``-ed for the caller (so a
        concurrent eviction can never free it under the caller), and the
        matched path is LRU-touched.  ``matched_tokens`` counts the real
        tokens the chain covers — a partial tail node matches only when
        it covers the remaining tokens exactly.
        """
        tokens = [int(t) for t in tokens]
        root = self._scopes.get(scope)
        blocks: List[int] = []
        matched = 0
        if root is not None:
            node = root
            i = 0
            while i < len(tokens):
                child = None
                if i + self.block_size <= len(tokens):
                    child = node.children.get(
                        tuple(tokens[i: i + self.block_size]))
                if child is None:
                    tail = node.children.get(tuple(tokens[i:]))
                    if tail is not None and tail.fill < self.block_size:
                        child = tail
                if child is None:
                    break
                child.last_used = self._tick()
                blocks.append(child.block)
                matched += child.fill
                node = child
                i = matched
        for b in blocks:
            if self.allocator.incref(b) == 2:
                self._retained -= 1          # was tree-only, now adopted
        if matched:
            self.hits += 1
            self.hit_tokens += matched
        else:
            self.misses += 1
        return blocks, matched

    # -------------------------------------------------------------- insert
    def insert(self, scope: Hashable, tokens: Sequence[int],
               blocks: Sequence[int]) -> int:
        """Donate a freshly prefilled chain: ``blocks[j]`` holds tokens
        ``[j*bs, min((j+1)*bs, len(tokens)))``.

        Chunks already present keep the tree's existing block (two
        same-prompt requests prefilled in one micro-batch both compute
        the chain; the second's copy stays private to it and dies with
        it).  New chunks take one tree reference on the request's block.
        Returns the number of newly retained blocks.
        """
        tokens = [int(t) for t in tokens]
        root = self._scopes.setdefault(scope, _Root())
        node: _Node = root
        donated = 0
        for j, block in enumerate(blocks):
            chunk = tuple(tokens[j * self.block_size:
                                 (j + 1) * self.block_size])
            if not chunk:
                break
            child = node.children.get(chunk)
            if child is None:
                child = _Node(chunk, int(block), node)
                node.children[chunk] = child
                self.allocator.incref(int(block))
                self._by_block[int(block)] = child
                donated += 1
            child.last_used = self._tick()
            node = child
        self.inserted_blocks += donated
        return donated

    # ------------------------------------------------------------ eviction
    def evict(self, n_blocks: int) -> int:
        """Drop LRU refcount-0 chains until ``n_blocks`` blocks actually
        returned to the free list (or nothing more is evictable).

        Only leaves are evictable (an interior block is the prefix of its
        children), and leaves still pinned by a request are skipped —
        dropping the tree's reference on those would reclaim nothing and
        forfeit the future hit.  Returns the number of blocks freed.
        """
        freed = 0
        if n_blocks <= 0 or self._retained <= 0:
            return freed                   # nothing evictable: skip the walk
        heap: List[Tuple[int, int, Hashable, _Node]] = []
        seq = 0
        for scope, root in self._scopes.items():
            for node in self._nodes(root):
                if not node.children:
                    heapq.heappush(heap, (node.last_used, seq, scope, node))
                    seq += 1
        while heap and freed < n_blocks:
            _, _, scope, node = heapq.heappop(heap)
            if node.children:          # re-pushed parent grew? (defensive)
                continue
            if self.allocator.refcount(node.block) != 1:
                continue               # request-pinned: not reclaimable
            self.allocator.decref(node.block)
            self.evicted_blocks += 1
            self._retained -= 1
            freed += 1
            parent = node.parent
            del parent.children[node.tokens]
            self._by_block.pop(node.block, None)
            if parent is not None and not isinstance(parent, _Root) \
                    and not parent.children:
                heapq.heappush(heap, (parent.last_used, seq, scope, parent))
                seq += 1
        return freed

    # ------------------------------------------------------------- scoping
    def drop_scope(self, *, tier: Optional[str] = None,
                   version: Optional[int] = None) -> int:
        """Release every tree reference of the matching scopes (None = any
        on that axis) — weight-version GC and tier redefinition/revocation
        must not keep serving stale activations.  Blocks still pinned by
        in-flight requests stay alive until those requests release them.
        """
        dropped = 0
        for scope in [s for s in self._scopes
                      if (tier is None or s[0] == tier)
                      and (version is None or s[1] == version)]:
            for node in self._nodes(self._scopes.pop(scope)):
                if self.allocator.refcount(node.block) == 1:
                    self._retained -= 1    # was tree-only before the drop
                self.allocator.decref(node.block)
                self._by_block.pop(node.block, None)
                dropped += 1
        self.dropped_blocks += dropped
        return dropped

    def forget_block(self, block: int) -> bool:
        """Drop the tree's reference on one retained *leaf* block so its
        remaining holder can write it in place.

        This is the pressure valve behind copy-on-write: when a request
        must write into its shared prompt tail but the pool has no spare
        block for a copy, forfeiting the tail's future hits beats
        preempting a running request.  Interior nodes are refused —
        their content is the prefix of live children.  Returns True if a
        reference was dropped.
        """
        node = self._by_block.get(block)
        if node is None or node.children:
            return False
        del node.parent.children[node.tokens]
        del self._by_block[block]
        if self.allocator.refcount(block) == 1:
            self._retained -= 1            # was tree-only before the drop
        self.allocator.decref(block)
        self.evicted_blocks += 1
        return True

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "hits": self.hits, "misses": self.misses,
            "hit_rate": round(self.hits / total, 4) if total else 0.0,
            # raw matched tokens; the gateway's ``prefix_tokens_reused``
            # stat is the capped number actually skipped at prefill
            "matched_tokens": self.hit_tokens,
            "cached_blocks": len(self._by_block),
            "retained_blocks": self._retained,
            "inserted_blocks": self.inserted_blocks,
            "evicted_blocks": self.evicted_blocks,
            "dropped_blocks": self.dropped_blocks,
            "scopes": len(self._scopes),
        }
