"""Shared-prefix radix cache: tier-scoped prompt-prefix reuse over paged blocks.

Tier-homogeneous traffic through the licensed gateway repeatedly
prefill-computes the same system/prompt prefixes — identical tokens at
identical positions under the same ``(tier, version)`` weight view
produce identical KV blocks, so recomputing them is pure wasted FLOPs
and pool space.  This module retains those blocks after their request
finishes and hands them to later requests (SGLang-style radix caching
on top of the vLLM-style block pool in ``serving/paging.py``):

* :class:`PrefixCache` keeps one radix tree **per (tier, version)
  scope**.  Scoping is the licensing boundary: a cached block encodes
  activations of a *masked weight view*, so a ``free``-tier prefix must
  never seed a ``pro``-tier request even when the tokens match —
  cross-tier reuse would leak the better view's representations.  Each
  tree node covers one physical block (up to ``block_size`` tokens; the
  last node of a chain may be *partial*).  Keys are whatever token rows
  the gateway donates: under chunked prefill these are TRUE unpadded
  prompts, so chains match across prompt-*length* boundaries — any
  prompt sharing a full-block prefix adopts it, whatever its own
  length.  A partial tail node matches only when it covers the
  remaining tokens *exactly* (:meth:`_walk`), which is what lets
  partial fills terminate a chain without ever needing node splitting:
  a shorter or diverging prompt simply stops at the last full block.
* Retention holds one allocator **reference** per tree-referenced
  block.  A block whose refcount is exactly 1 is held by the tree alone
  ("refcount-0" from the requests' point of view) and is *reclaimable*.
  The evictable set — reclaimable blocks whose node is a **leaf** — is
  maintained *incrementally* as an ordered dict updated at every
  transition (``note_release`` appends, ``match`` adoption removes,
  ``insert`` refreshes/de-leafs, eviction promotes drained parents), so
  :meth:`evict` pops from the front in O(1) per block instead of
  rebuilding a leaf heap per call.  Order is LRU in the access sense:
  a block enters when its last request releases it and moves to the
  back when the tree re-touches it.  A request's table holds the whole
  chain of any block it holds, so a refcount-1 node can never have a
  request-pinned descendant — its entire subtree drains leaf-first.
  Set ``debug = True`` to re-derive the set from a full walk at every
  eviction and assert the incremental bookkeeping never drifted.
* :meth:`match` returns the longest cached chain for a prompt and takes
  a reference on every returned block for the caller; :meth:`insert`
  donates a freshly prefilled chain (the tree takes its own references)
  so the *first* request with a prompt populates the cache for the rest.

Writes never target a shared block: the gateway routes prefill
write-back of adopted blocks to the null block, and decode
copy-on-writes a shared tail block before its first write into it
(``PagedCachePool.copy_block``).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Hashable, List, Optional, Sequence, Tuple

from repro.serving.paging import BlockAllocator


class _Node:
    """One cached block: ``tokens`` (its chunk, ``fill`` of them) under a
    parent chunk chain.  ``children`` is keyed by the child's full token
    tuple, so full-block lookup is one dict probe."""

    __slots__ = ("tokens", "block", "parent", "children", "last_used")

    def __init__(self, tokens: Tuple[int, ...], block: int,
                 parent: "_Node"):
        self.tokens = tokens
        self.block = block
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.last_used = 0

    @property
    def fill(self) -> int:
        return len(self.tokens)


class _Root(_Node):
    def __init__(self):
        super().__init__((), -1, None)  # type: ignore[arg-type]


class PrefixCache:
    """Radix trees of retained prompt-block chains, one per scope.

    The allocator is shared with the gateway's :class:`PagedCachePool`;
    the cache only ever moves *references*, never block contents.
    """

    def __init__(self, allocator: BlockAllocator, block_size: int):
        self.allocator = allocator
        self.block_size = int(block_size)
        self._scopes: Dict[Hashable, _Root] = {}
        self._by_block: Dict[int, _Node] = {}   # block id -> retaining node
        # count of tree blocks whose ONLY reference is the tree's — the
        # reclaimable set.  Kept O(1)-exact across every transition: the
        # tree sees its own incref/decref sites, and the gateway reports
        # request releases via note_release().  Admission reads this
        # every scheduling step, so it must not walk the tree.
        self._retained = 0
        # the persistent eviction structure: reclaimable LEAF blocks in
        # LRU order (front = evict next).  note_release appends (the
        # releasing request was the last user), match-adoption removes,
        # insert refreshes a re-donated leaf / removes a de-leafed
        # parent, and evict promotes a drained chain's parent to the
        # front so chains keep draining oldest-first.  evict(1) is O(1).
        self._evictable: "OrderedDict[int, _Node]" = OrderedDict()
        self.debug = False               # recount-assert at every evict()
        # bumped whenever tree CONTENT changes (insert/evict/drop/forget)
        # — i.e. whenever a previous peek()/match() result may be stale.
        # The gateway keys its per-request suffix-bucket cache on this so
        # admission probing is O(1) per request per epoch, not a fresh
        # radix walk every scheduling pass.
        self.epoch = 0
        self._clock = 0                  # LRU tick, bumped on every touch
        self.hits = 0                    # match() calls that reused >=1 block
        self.misses = 0
        self.hit_tokens = 0              # cumulative tokens served from cache
        self.inserted_blocks = 0         # chains donated by finished prefills
        self.evicted_blocks = 0          # tree references dropped under pressure
        self.dropped_blocks = 0          # scope invalidations (version GC,
                                         # tier redefinition) — not pressure

    # ----------------------------------------------------------- structure
    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _nodes(self, root: _Node) -> List[_Node]:
        out, stack = [], list(root.children.values())
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children.values())
        return out

    def num_blocks(self) -> int:
        """Total blocks referenced by all trees (any refcount)."""
        return len(self._by_block)

    def reclaimable(self) -> int:
        """Blocks held by the tree alone (allocator refcount == 1) —
        exactly the blocks :meth:`evict` can return to the free list.
        A request holds the full chain of every block it shares, so a
        refcount-1 node cannot have a request-pinned descendant; the
        count is exact (an O(1) maintained counter, asserted against a
        full recount in the tests)."""
        return self._retained

    def note_release(self, block: int) -> None:
        """Gateway hook: a request dropped its reference on ``block`` and
        exactly one reference remains.  If that survivor is the tree's,
        the block just became reclaimable — and, when its node is a
        leaf, joins the back of the eviction order (the releasing
        request was its most recent user)."""
        node = self._by_block.get(block)
        if node is not None:
            self._retained += 1
            if not node.children:
                self._evictable[block] = node

    def _walk(self, scope: Hashable, tokens: List[int]) -> List["_Node"]:
        """Longest cached chain for ``tokens``: the nodes in logical
        order.  The ONE matching rule shared by :meth:`match` and
        :meth:`peek` — full-block chunks by dict probe, then a partial
        tail node only when it covers the remaining tokens exactly."""
        root = self._scopes.get(scope)
        path: List[_Node] = []
        if root is None:
            return path
        node = root
        i = 0
        while i < len(tokens):
            child = None
            if i + self.block_size <= len(tokens):
                child = node.children.get(
                    tuple(tokens[i: i + self.block_size]))
            if child is None:
                tail = node.children.get(tuple(tokens[i:]))
                if tail is not None and tail.fill < self.block_size:
                    child = tail
            if child is None:
                break
            path.append(child)
            i += child.fill
            node = child
        return path

    # --------------------------------------------------------------- match
    def match(self, scope: Hashable, tokens: Sequence[int]) \
            -> Tuple[List[int], int]:
        """Longest cached chain for ``tokens`` under ``scope``.

        Returns ``(blocks, matched_tokens)`` in logical order; every
        returned block has been ``incref``-ed for the caller (so a
        concurrent eviction can never free it under the caller), and the
        matched path is LRU-touched.  ``matched_tokens`` counts the real
        tokens the chain covers — a partial tail node matches only when
        it covers the remaining tokens exactly.
        """
        path = self._walk(scope, [int(t) for t in tokens])
        blocks = [n.block for n in path]
        matched = sum(n.fill for n in path)
        for n in path:
            n.last_used = self._tick()
        for b in blocks:
            if self.allocator.incref(b) == 2:
                self._retained -= 1          # was tree-only, now adopted
                self._evictable.pop(b, None)
        if matched:
            self.hits += 1
            self.hit_tokens += matched
        else:
            self.misses += 1
        return blocks, matched

    def peek(self, scope: Hashable, tokens: Sequence[int]) -> int:
        """Length of the longest cached chain for ``tokens`` — the same
        :meth:`_walk` as :meth:`match` with NO side effects: no
        references taken, no LRU touch, no hit/miss accounting.  The
        scheduler's prefix-aware admission grouping probes waiting
        requests with this each step, so it must not distort the
        eviction order or pin anything."""
        return sum(n.fill for n in self._walk(scope,
                                              [int(t) for t in tokens]))

    # -------------------------------------------------------------- insert
    def insert(self, scope: Hashable, tokens: Sequence[int],
               blocks: Sequence[int]) -> int:
        """Donate a freshly prefilled chain: ``blocks[j]`` holds tokens
        ``[j*bs, min((j+1)*bs, len(tokens)))``.

        Chunks already present keep the tree's existing block (two
        same-prompt requests prefilled in one micro-batch both compute
        the chain; the second's copy stays private to it and dies with
        it).  New chunks take one tree reference on the request's block.
        Returns the number of newly retained blocks.
        """
        tokens = [int(t) for t in tokens]
        root = self._scopes.setdefault(scope, _Root())
        node: _Node = root
        donated = 0
        for j, block in enumerate(blocks):
            chunk = tuple(tokens[j * self.block_size:
                                 (j + 1) * self.block_size])
            if not chunk:
                break
            child = node.children.get(chunk)
            if child is None:
                # the parent stops being a leaf: out of the evictable set
                # (it may re-enter via promotion once its subtree drains)
                if not isinstance(node, _Root):
                    self._evictable.pop(node.block, None)
                child = _Node(chunk, int(block), node)
                node.children[chunk] = child
                self.allocator.incref(int(block))
                self._by_block[int(block)] = child
                donated += 1
            elif child.block in self._evictable:
                # re-donated chunk: the tree keeps its block, but this is
                # a fresh use — refresh its LRU position
                self._evictable.move_to_end(child.block)
            child.last_used = self._tick()
            node = child
        self.inserted_blocks += donated
        if donated:
            self.epoch += 1
        return donated

    # ------------------------------------------------------------ eviction
    def _recount_evictable(self) -> Tuple[int, Dict[int, "_Node"]]:
        """Ground truth by full walk: (reclaimable count, evictable leaf
        blocks).  Debug-mode oracle for the incremental structures."""
        retained = 0
        evictable: Dict[int, _Node] = {}
        for root in self._scopes.values():
            for node in self._nodes(root):
                if self.allocator.refcount(node.block) == 1:
                    retained += 1
                    if not node.children:
                        evictable[node.block] = node
        return retained, evictable

    def _check(self) -> None:
        retained, evictable = self._recount_evictable()
        assert retained == self._retained, (retained, self._retained)
        assert set(evictable) == set(self._evictable), \
            (sorted(evictable), sorted(self._evictable))

    def evict(self, n_blocks: int) -> int:
        """Drop LRU refcount-0 chains until ``n_blocks`` blocks actually
        returned to the free list (or nothing more is evictable).

        Pops the persistent evictable dict front-first — no tree walk,
        no heap rebuild: ``evict(1)`` is O(1) however many nodes the
        trees hold.  Only leaves are evictable (an interior block is the
        prefix of its children); when a leaf's eviction drains its
        parent into a reclaimable leaf, :meth:`_promote` places the
        parent at the front when it is no younger than the current LRU
        head (chains drain oldest-first) and at the back when a
        diverging match kept the prefix hot.  Returns the number of
        blocks freed.
        """
        if self.debug:
            self._check()
        freed = 0
        if n_blocks <= 0:
            return freed
        while self._evictable and freed < n_blocks:
            block, node = self._evictable.popitem(last=False)
            assert self.allocator.refcount(block) == 1, \
                (block, self.allocator.refcount(block))
            self.allocator.decref(block)
            self.evicted_blocks += 1
            self._retained -= 1
            freed += 1
            parent = node.parent
            del parent.children[node.tokens]
            self._by_block.pop(block, None)
            self._promote(parent)
        if freed:
            self.epoch += 1
        return freed

    def _promote(self, parent: "_Node") -> None:
        """A leaf eviction may leave its parent a reclaimable leaf.  In
        the common chain-drain case the parent's last touch is the same
        walk that touched the evicted child, so it belongs at the FRONT
        (drain the chain oldest-first).  But a parent can be *younger*
        than its drained child — a diverging match re-touches the shared
        prefix without touching the stale branch — and front-promoting a
        recently-hot prefix would evict it before genuinely colder
        leaves; those keep their recency at the back instead."""
        if isinstance(parent, _Root) or parent.children \
                or self.allocator.refcount(parent.block) != 1 \
                or parent.block in self._evictable:
            return
        self._evictable[parent.block] = parent
        head = next(iter(self._evictable))
        if head != parent.block and \
                parent.last_used <= self._evictable[head].last_used:
            self._evictable.move_to_end(parent.block, last=False)

    # ------------------------------------------------------------- scoping
    def drop_scope(self, *, tier: Optional[str] = None,
                   version: Optional[int] = None) -> int:
        """Release every tree reference of the matching scopes (None = any
        on that axis) — weight-version GC and tier redefinition/revocation
        must not keep serving stale activations.  Blocks still pinned by
        in-flight requests stay alive until those requests release them.
        """
        dropped = 0
        for scope in [s for s in self._scopes
                      if (tier is None or s[0] == tier)
                      and (version is None or s[1] == version)]:
            for node in self._nodes(self._scopes.pop(scope)):
                if self.allocator.refcount(node.block) == 1:
                    self._retained -= 1    # was tree-only before the drop
                self.allocator.decref(node.block)
                self._by_block.pop(node.block, None)
                self._evictable.pop(node.block, None)
                dropped += 1
        self.dropped_blocks += dropped
        if dropped:
            self.epoch += 1
        return dropped

    def forget_block(self, block: int) -> bool:
        """Drop the tree's reference on one retained *leaf* block so its
        remaining holder can write it in place.

        This is the pressure valve behind copy-on-write: when a request
        must write into its shared prompt tail but the pool has no spare
        block for a copy, forfeiting the tail's future hits beats
        preempting a running request.  Interior nodes are refused —
        their content is the prefix of live children.  Returns True if a
        reference was dropped.
        """
        node = self._by_block.get(block)
        if node is None or node.children:
            return False
        parent = node.parent
        del parent.children[node.tokens]
        del self._by_block[block]
        self._evictable.pop(block, None)
        if self.allocator.refcount(block) == 1:
            self._retained -= 1            # was tree-only before the drop
        self.allocator.decref(block)
        self.evicted_blocks += 1
        self.epoch += 1
        # the forgotten block's holder pins its whole chain, so the
        # newly-leafed parent is never reclaimable here — but direct API
        # callers may violate that, so keep the structure exact anyway
        self._promote(parent)
        return True

    # --------------------------------------------------------------- stats
    def stats(self) -> Dict[str, float]:
        total = self.hits + self.misses
        return {
            "hits": self.hits, "misses": self.misses,
            "hit_rate": round(self.hits / total, 4) if total else 0.0,
            # raw matched tokens; the gateway's ``prefix_tokens_reused``
            # stat is the capped number actually skipped at prefill
            "matched_tokens": self.hit_tokens,
            "cached_blocks": len(self._by_block),
            "retained_blocks": self._retained,
            "evictable_leaves": len(self._evictable),
            "inserted_blocks": self.inserted_blocks,
            "evicted_blocks": self.evicted_blocks,
            "dropped_blocks": self.dropped_blocks,
            "scopes": len(self._scopes),
        }
