"""Request lifecycle tracing, Chrome trace_event export, licensing audit.

Three pieces, all zero-dependency and always-on cheap:

* :class:`TraceRecorder` — per-gateway event tape.  Every record is one
  O(1) append of a plain tuple ``(ts, kind, rid, name, attrs)`` onto a
  bounded deque; no dict churn, no string formatting on the hot path.
  The span taxonomy (``docs/OBSERVABILITY.md``) covers the full request
  lifecycle: ``submit → admit → prefix_hit → prefill_chunk×N →
  decode_step×M → preempt/restart → finish``, plus scheduler actions
  and stager phases as instant/complete events and pool occupancy as
  counter samples.
* Chrome ``trace_event`` export — :meth:`TraceRecorder.chrome_trace`
  renders the tape into the JSON Array Format that Perfetto /
  ``chrome://tracing`` load directly: request spans as matched ``B``/``E``
  pairs (one tid per request), scheduler actions and stager phases as
  ``X`` complete events on pseudo-threads, occupancy as ``C`` counter
  tracks, and ``M`` metadata naming every track.  A fleet merges slot
  tapes with one *pid per model*.
* :class:`AuditLog` — the licensing ledger: append-only
  ``(ts, seq, event, attrs)`` records for tier grants/revocations,
  view-cache materializations, version installs/flips, and per-tenant
  quota/rate rejections — "who could run which tier at which version
  when", answerable after the fact.

:func:`validate_chrome_trace` is the acceptance check the test-suite and
benchmark share: parseable JSON, non-decreasing timestamps, and
balanced ``B``/``E`` pairs per (pid, tid).
"""
from __future__ import annotations

import itertools
import json
import time
from collections import deque
from typing import (Any, Callable, Dict, Iterable, List, Optional, Tuple)

__all__ = ["TraceRecorder", "AuditLog", "validate_chrome_trace",
           "merge_chrome_traces", "SCHED_TID", "STAGER_TID"]

# Span kinds (ph in the Chrome mapping):
#   "B"/"E"  span begin/end          (per-request lifecycle phases)
#   "i"      instant                 (submit, admit, prefix_hit, preempt, ...)
#   "X"      complete w/ duration    (scheduler action, stager phase)
#   "C"      counter sample          (pool occupancy, queue depth)

SCHED_TID = 0           # pseudo-thread for scheduler actions
STAGER_TID = 1          # pseudo-thread for stager phases
_COUNTER_TID = 2        # counters hang off the process track
_RID_TID_BASE = 10      # request rid r -> tid 10 + r


class TraceRecorder:
    """Bounded per-gateway event tape with Chrome trace_event export.

    ``record*`` methods are the only hot-path surface: one tuple append
    each, guarded by ``enabled``.  Everything else (per-request slicing,
    Chrome JSON rendering) walks the tape at export time.
    """

    __slots__ = ("clock", "enabled", "events", "_t0")

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter,
                 enabled: bool = True, maxlen: int = 200_000):
        self.clock = clock
        self.enabled = bool(enabled)
        # (ts, ph, rid, name, attrs_or_None, dur_or_value)
        self.events: "deque[Tuple]" = deque(maxlen=maxlen)
        self._t0 = clock()

    # ------------------------------------------------------------- recording
    def instant(self, name: str, rid: int = -1,
                attrs: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled:
            return
        self.events.append((self.clock(), "i", rid, name, attrs, None))

    def begin(self, name: str, rid: int,
              attrs: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled:
            return
        self.events.append((self.clock(), "B", rid, name, attrs, None))

    def end(self, name: str, rid: int,
            attrs: Optional[Dict[str, Any]] = None) -> None:
        if not self.enabled:
            return
        self.events.append((self.clock(), "E", rid, name, attrs, None))

    def complete(self, name: str, start: float, end: float, *,
                 tid: int = SCHED_TID,
                 attrs: Optional[Dict[str, Any]] = None) -> None:
        """X event with explicit duration, on a pseudo-thread track."""
        if not self.enabled:
            return
        self.events.append((start, "X", -1 - tid, name, attrs, end - start))

    def counter(self, name: str, value: float) -> None:
        if not self.enabled:
            return
        self.events.append((self.clock(), "C", -1, name, None, value))

    # --------------------------------------------------------------- queries
    def request_events(self, rid: int) -> List[Dict[str, Any]]:
        """Chronological event dicts for one request (its lifecycle story)."""
        out = []
        for ts, ph, erid, name, attrs, _ in self.events:
            if erid == rid:
                out.append({"ts": ts, "ph": ph, "name": name,
                            "attrs": dict(attrs) if attrs else {}})
        return out

    def span_names(self, rid: int) -> List[str]:
        return [e["name"] for e in self.request_events(rid)]

    # ---------------------------------------------------------- chrome export
    def chrome_events(self, *, pid: int = 1,
                      process_name: str = "gateway",
                      t0: Optional[float] = None) -> List[Dict[str, Any]]:
        """Raw trace_event dicts (ts in µs, relative to recorder start;
        pass ``t0`` to align several recorders on one timebase)."""
        t0 = self._t0 if t0 is None else t0
        ev: List[Dict[str, Any]] = [
            {"ph": "M", "pid": pid, "tid": 0, "name": "process_name",
             "args": {"name": process_name}},
            {"ph": "M", "pid": pid, "tid": SCHED_TID,
             "name": "thread_name", "args": {"name": "scheduler"}},
            {"ph": "M", "pid": pid, "tid": STAGER_TID,
             "name": "thread_name", "args": {"name": "stager"}},
        ]
        named_rids = set()
        open_spans: Dict[Tuple[int, str], int] = {}   # (rid, name) -> count
        for ts, ph, rid, name, attrs, extra in sorted(
                self.events, key=lambda e: e[0]):
            us = max(0.0, (ts - t0) * 1e6)
            args = dict(attrs) if attrs else {}
            if ph == "C":
                ev.append({"ph": "C", "pid": pid, "tid": _COUNTER_TID,
                           "name": name, "ts": us, "args": {"value": extra}})
                continue
            if ph == "X":
                tid = -1 - rid          # complete() encodes tid as -1-tid
                ev.append({"ph": "X", "pid": pid, "tid": tid, "name": name,
                           "ts": us, "dur": max(0.0, extra * 1e6),
                           "args": args})
                continue
            tid = _RID_TID_BASE + rid if rid >= 0 else SCHED_TID
            if rid >= 0 and rid not in named_rids:
                named_rids.add(rid)
                ev.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": f"request {rid}"}})
            if ph == "B":
                open_spans[(rid, name)] = open_spans.get((rid, name), 0) + 1
            elif ph == "E":
                if open_spans.get((rid, name), 0) <= 0:
                    continue            # unmatched E: drop, keep trace valid
                open_spans[(rid, name)] -= 1
            ev.append({"ph": ph, "pid": pid, "tid": tid, "name": name,
                       "ts": us, "args": args})
            if ph == "i":
                ev[-1]["s"] = "t"       # instant scope: thread
        # Close any still-open span (request mid-flight at export) at the
        # tape's last timestamp so every B has a matching E.
        last_us = max((e["ts"] for e in ev if "ts" in e), default=0.0)
        for (rid, name), n in open_spans.items():
            for _ in range(n):
                ev.append({"ph": "E", "pid": pid,
                           "tid": _RID_TID_BASE + rid, "name": name,
                           "ts": last_us, "args": {}})
        return ev

    def chrome_trace(self, *, pid: int = 1,
                     process_name: str = "gateway") -> str:
        """Whole-tape timeline as Chrome trace_event JSON (array format)."""
        return json.dumps(self.chrome_events(pid=pid,
                                             process_name=process_name))


def merge_chrome_traces(
        tapes: Iterable[Tuple[str, "TraceRecorder"]]) -> str:
    """Merge named recorders into one trace — one pid per model/slot,
    all aligned on the earliest recorder's timebase."""
    tapes = list(tapes)
    t0 = min((rec._t0 for _, rec in tapes), default=0.0)
    ev: List[Dict[str, Any]] = []
    for pid, (name, rec) in enumerate(tapes, start=1):
        ev.extend(rec.chrome_events(pid=pid, process_name=name, t0=t0))
    return json.dumps(ev)


class AuditLog:
    """Append-only licensing ledger.

    Events: ``tier_grant``, ``tier_revoke``, ``tier_redefine``,
    ``view_materialize``, ``version_install``, ``version_flip``,
    ``sync_begin``, ``sync_abort``, ``sync_retry``, ``sync_quarantine``,
    ``lease_degraded``, ``lease_offline``, ``lease_restored``,
    ``quota_reject``, ``rate_reject``, ``tenant_reject``.  Each record
    is ``(ts, seq, event, attrs)`` — one tuple append, no formatting
    until export.  ``record`` is safe from the background fetch worker:
    the deque append and the itertools counter are both atomic.
    """

    __slots__ = ("clock", "enabled", "records", "_seq")

    def __init__(self, *, clock: Callable[[], float] = time.perf_counter,
                 enabled: bool = True, maxlen: int = 100_000):
        self.clock = clock
        self.enabled = bool(enabled)
        self.records: "deque[Tuple[float, int, str, Dict]]" = \
            deque(maxlen=maxlen)
        self._seq = itertools.count()

    def record(self, event: str, **attrs: Any) -> None:
        if not self.enabled:
            return
        self.records.append((self.clock(), next(self._seq), event, attrs))

    def events(self, event: Optional[str] = None) -> List[Dict[str, Any]]:
        out = []
        for ts, seq, ev, attrs in self.records:
            if event is not None and ev != event:
                continue
            out.append({"ts": ts, "seq": seq, "event": ev, **attrs})
        return out

    def render_jsonl(self) -> str:
        return "\n".join(json.dumps(e, default=str)
                         for e in self.events()) + "\n"

    @staticmethod
    def merge(logs: Iterable["AuditLog"]) -> List[Dict[str, Any]]:
        """Fleet-wide view: merged records ordered by (ts, seq)."""
        out: List[Dict[str, Any]] = []
        for log in logs:
            out.extend(log.events())
        out.sort(key=lambda e: (e["ts"], e["seq"]))
        return out


def validate_chrome_trace(text: str) -> List[Dict[str, Any]]:
    """Assert ``text`` is valid Chrome trace_event JSON; return events.

    Checks the acceptance-criteria triple: parseable, per-track
    non-decreasing timestamps, and matched B/E nesting per (pid, tid).
    Raises ``ValueError`` on any violation.
    """
    events = json.loads(text)
    if not isinstance(events, list):
        raise ValueError("trace must be a JSON array of events")
    last_ts: Dict[Tuple[int, int], float] = {}
    stacks: Dict[Tuple[int, int], List[str]] = {}
    for e in events:
        if not isinstance(e, dict) or "ph" not in e:
            raise ValueError(f"malformed event: {e!r}")
        ph = e["ph"]
        if ph == "M":
            continue
        key = (e.get("pid", 0), e.get("tid", 0))
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event missing/invalid ts: {e!r}")
        if ts < last_ts.get(key, 0.0):
            raise ValueError(
                f"timestamps not monotonic on track {key}: "
                f"{ts} < {last_ts[key]} at {e!r}")
        last_ts[key] = ts
        if ph == "B":
            stacks.setdefault(key, []).append(e["name"])
        elif ph == "E":
            stack = stacks.get(key, [])
            if not stack:
                raise ValueError(f"unmatched E event on track {key}: {e!r}")
            stack.pop()
    open_tracks = {k: v for k, v in stacks.items() if v}
    if open_tracks:
        raise ValueError(f"unclosed B spans at end of trace: {open_tracks}")
    return events
