"""Block-paged KV cache pool: vLLM-style paging under the licensed gateway.

The seed :class:`~repro.serving.scheduler.CachePool` reserves one
``capacity``-token KV slab per lane, so a 4-token request strands as much
cache memory as a 64-token one and the lane count — not the memory — caps
concurrency.  This module replaces the slab with **fixed-size blocks**:

* :class:`BlockAllocator` — a host-side free list of physical block ids
  with per-block **reference counts**.  Requests allocate blocks on
  demand (``ceil(max_prompt/block_size)`` at prefill, one more whenever
  decode crosses a block boundary) and release their references on
  finish or preemption, so short and long requests share the pool
  without over-reserving; the prefix cache (``serving/prefix.py``)
  retains prompt chains by holding extra references, and a shared block
  is only written after :meth:`PagedCachePool.copy_block` gives the
  writer a private copy (copy-on-write).
* :class:`PagedCachePool` — the device-side store.  Per-token cache
  leaves (attention K/V, MLA compressed KV, int8 KV scales) live as
  ``(num_blocks + 1, ..., block_size, ...)`` physical blocks addressed
  through per-request **block tables**; constant-size per-lane state
  (SSM conv/state, RG-LRU state, ``len`` counters, sliding-window ring
  caches whose window is below the pool capacity) stays lane-stacked
  exactly like ``CachePool``.

``gather(lanes, tables)`` materializes each lane's logical cache as a
contiguous batch-1 view (block-table order == logical order — blocks are
appended as the sequence grows), so the gateway's lane-vmapped
prefill runs unmodified; ``scatter`` writes the views back through
the same tables.  Index ``num_blocks`` is a *null block* and index
``num_lanes`` a *scratch lane*: both absorb the writes of padding rows so
duplicate pad indices can never corrupt a live request.

Decode does NOT round-trip through gather/scatter: ``decode_cache``
hands the gateway's batched decode step the pool's physical block
arrays *by reference* (plus the lane-stacked constant-size state,
gathered by lane id), and ``absorb_decode`` adopts the step's returned
arrays wholesale — the step wrote exactly one token per lane through
the block table (``models/layers.py`` paged-decode attention /
``kernels/paged_attention.py``), so no contiguous view of any sequence
ever exists during decode.  Paged leaves are stored with the physical
block axis *in place of* the capacity axis — ``(..., num_blocks + 1,
block_size, ...)`` — so the unit axis stays leading and the model's
``lax.scan`` over units can slice the pool per unit without a
transpose.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_lane_ids(lanes: Sequence[int], width: int,
                 scratch: int) -> List[int]:
    """Pad a lane-id list to ``width`` with the scratch lane (shared by
    the contiguous and paged pools so the padding contract can't drift)."""
    lanes = list(lanes)
    assert len(lanes) <= width, (len(lanes), width)
    return lanes + [scratch] * (width - len(lanes))


class NoPagedLeavesError(ValueError):
    """The model's cache holds no per-token leaves to page (pure-recurrent,
    or every attention window is below the pool capacity).  The gateway
    catches exactly this to fall back to the contiguous pool; genuine
    geometry errors stay plain ``ValueError`` and propagate."""


class BlockAllocator:
    """Free list of physical cache blocks with double-alloc/free guards
    and per-block reference counts.

    Allocation is all-or-nothing (``alloc`` returns ``None`` rather than a
    partial grant) so a caller never holds a half-provisioned request.

    Reference counts are the sharing substrate of the prefix cache
    (``serving/prefix.py``): a freshly allocated block holds one
    reference; every additional holder (a request adopting a cached
    prefix chain, or the radix tree retaining one) takes its own via
    :meth:`incref` and releases it via :meth:`decref` — the block
    returns to the free list only when the last reference drops.  The
    original double-alloc/free guards extend to the refcount paths:
    ``incref`` on a block that is not live raises, and the hard
    :meth:`free` refuses blocks with live references besides the
    caller's, so a sharing bug surfaces as an exception rather than as
    two requests silently scribbling over one block.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        self.num_blocks = int(num_blocks)
        self._free: List[int] = list(range(self.num_blocks))
        self._ref: Dict[int, int] = {}   # live block id -> reference count
        self.alloc_count = 0             # cumulative blocks ever allocated

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_held(self) -> int:
        return len(self._ref)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Atomically allocate ``n`` blocks; None if the pool can't cover it.

        Each granted block starts with reference count 1 (the caller's)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        for b in got:
            self._ref[b] = 1
        self.alloc_count += n
        return got

    def refcount(self, block: int) -> int:
        """Live reference count of ``block`` (0 when free/foreign)."""
        return self._ref.get(block, 0)

    def incref(self, block: int) -> int:
        """Take an additional reference on a live block; freed or foreign
        block ids raise (the double-alloc guard on the sharing path)."""
        if block not in self._ref:
            raise ValueError(f"incref of unallocated block {block}")
        self._ref[block] += 1
        return self._ref[block]

    def decref(self, block: int) -> int:
        """Drop one reference; the block returns to the free list when the
        count reaches zero.  Returns the remaining count.  Over-release
        (a freed or foreign id) raises — the double-free guard."""
        if block not in self._ref:
            raise ValueError(f"decref of unallocated block {block}")
        self._ref[block] -= 1
        left = self._ref[block]
        if left == 0:
            del self._ref[block]
            self._free.append(block)
        return left

    def free(self, blocks: Sequence[int]) -> None:
        """Return exclusively-held blocks to the pool; double-frees,
        foreign ids, and blocks with live shared references raise."""
        for b in blocks:
            if b not in self._ref:
                raise ValueError(f"free of unallocated block {b}")
            if self._ref[b] != 1:
                raise ValueError(
                    f"free of block {b} with {self._ref[b]} live refs; "
                    f"shared blocks must be released via decref")
            del self._ref[b]
            self._free.append(b)

    def stats(self) -> Dict[str, int]:
        return {"num_blocks": self.num_blocks, "free": self.num_free,
                "held": self.num_held, "alloc_count": self.alloc_count,
                "shared": sum(1 for c in self._ref.values() if c > 1)}


class PagedCachePool:
    """Block-paged KV/SSM cache store behind per-request block tables.

    Parameters
    ----------
    cfg:
        Model config; the cache pytree layout comes from
        ``model.init_cache``.
    num_lanes:
        Per-lane state slots.  Decoupled from the gateway's ``max_batch``
        vmap width: with paging, concurrency is bounded by *blocks*, so a
        gateway can run more lanes than it decodes per step.
    capacity:
        Logical per-request token capacity (prompt bucket + decode cap).
    block_size:
        Tokens per physical block.
    num_blocks:
        Physical blocks shared by every lane and license tier.  Must be
        at least ``blocks_per_lane`` so one full-capacity request always
        fits (the preemption policy's termination guarantee).
    """

    def __init__(self, cfg: ModelConfig, num_lanes: int, capacity: int,
                 block_size: int, num_blocks: int):
        self.cfg = cfg
        self.num_lanes = int(num_lanes)
        self.capacity = int(capacity)
        self.block_size = int(block_size)
        self.num_blocks = int(num_blocks)
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.blocks_per_lane = cdiv(self.capacity, self.block_size)
        # the vmapped model sees this (static) capacity; positions beyond
        # the logical capacity are dead weight masked by the cache ``len``
        self.padded_capacity = self.blocks_per_lane * self.block_size
        if self.num_blocks < self.blocks_per_lane:
            raise ValueError(
                f"num_blocks={num_blocks} cannot hold one full request "
                f"({self.blocks_per_lane} blocks of {self.block_size})")
        self.allocator = BlockAllocator(self.num_blocks)

        # Classify cache leaves by probing init_cache at two capacities:
        # a leaf whose shape grows by exactly block_size along one axis is
        # per-token (paged); anything else — SSM/LRU state, len counters,
        # window ring caches already capped below the pool capacity — is
        # constant-size per-lane state.  A third probe at batch 2 finds
        # each leaf's batch axis, which the kernel-resident decode path
        # needs to splice the lane axis into the model's cache layout.
        template = model_lib.init_cache(cfg, 1, self.padded_capacity)
        probe = model_lib.init_cache(
            cfg, 1, self.padded_capacity + self.block_size)
        bprobe = model_lib.init_cache(cfg, 2, self.padded_capacity)
        t_leaves, self._treedef = jax.tree_util.tree_flatten(template)
        p_leaves, _ = jax.tree_util.tree_flatten(probe)
        b_leaves, _ = jax.tree_util.tree_flatten(bprobe)
        # (paged, capacity axis, batch axis); paged leaves are stored as
        # t.shape[:axis] + (num_blocks + 1, block_size) + t.shape[axis+1:]
        # — the block axis sits where the capacity axis was, so leading
        # axes (the unit-scan axis) are untouched.
        self._meta: List[Tuple[bool, int, int]] = []
        self._storage: List[jnp.ndarray] = []
        self._lane_init: List[Optional[jnp.ndarray]] = []  # pristine per-lane
        for t, p, bp in zip(t_leaves, p_leaves, b_leaves):
            bdiff = [i for i, (a, b) in enumerate(zip(t.shape, bp.shape))
                     if a != b]
            assert len(bdiff) == 1, \
                f"cache leaf without a unique batch axis: {t.shape}"
            baxis = bdiff[0]
            diff = [i for i, (a, b) in enumerate(zip(t.shape, p.shape))
                    if a != b]
            if len(diff) == 1 and p.shape[diff[0]] - t.shape[diff[0]] == \
                    self.block_size:
                axis = diff[0]
                self._meta.append((True, axis, baxis))
                self._storage.append(jnp.zeros(
                    (*t.shape[:axis], self.num_blocks + 1, self.block_size,
                     *t.shape[axis + 1:]), t.dtype))
                self._lane_init.append(None)
            else:
                self._meta.append((False, -1, baxis))
                self._storage.append(jnp.broadcast_to(
                    t[None], (self.num_lanes + 1, *t.shape)))
                self._lane_init.append(t)
        if not any(paged for paged, _, _ in self._meta):
            raise NoPagedLeavesError(
                "no per-token cache leaves to page (pure-recurrent model); "
                "use the contiguous CachePool instead")
        # Prefix caching stores *blocks* only, so a cached chain can seed a
        # new request iff every non-paged leaf is a position counter the
        # gateway can reconstruct (integer ``len``).  Float per-lane state
        # (SSM/conv/RG-LRU, sliding-window ring caches) would need a state
        # snapshot at the prefix boundary — not block-shaped — so models
        # carrying any disable prefix reuse rather than serve wrong state.
        self.prefix_cacheable = all(
            jnp.issubdtype(t.dtype, jnp.integer)
            for t, (paged, _, _) in zip(t_leaves, self._meta) if not paged)

    # ------------------------------------------------------------- indices
    @property
    def scratch(self) -> int:
        """Scratch lane id absorbing padded per-lane-state writes."""
        return self.num_lanes

    @property
    def null_block(self) -> int:
        """Null block id absorbing padded block-table writes."""
        return self.num_blocks

    @property
    def cache_tokens(self) -> int:
        """Token capacity of the shared pool (excludes the null block)."""
        return self.num_blocks * self.block_size

    @property
    def nbytes(self) -> int:
        return sum(int(x.nbytes) for x in self._storage)

    @property
    def block_bytes(self) -> int:
        """Bytes ONE physical block occupies across every paged leaf —
        the exchange rate a fleet-wide cache budget (serving/fleet.py)
        converts between heterogeneous models' blocks with.  Exact: each
        paged leaf's storage is ``num_blocks + 1`` equal block slabs."""
        return sum(int(arr.nbytes) // (self.num_blocks + 1)
                   for arr, (paged, _, _) in zip(self._storage, self._meta)
                   if paged)

    def pad_lanes(self, lanes: Sequence[int], width: int) -> List[int]:
        return pad_lane_ids(lanes, width, self.scratch)

    def pad_tables(self, tables: Sequence[Sequence[int]], width: int,
                   n_cols: Optional[int] = None) -> np.ndarray:
        """(width, n_cols) int32 table matrix, null-padded.  ``n_cols``
        defaults to ``blocks_per_lane`` (a full logical table); the
        kernel-resident decode trims it to the micro-batch's used blocks
        so attention reads O(context), not O(capacity)."""
        n_cols = self.blocks_per_lane if n_cols is None else int(n_cols)
        assert len(tables) <= width, (len(tables), width)
        out = np.full((width, n_cols), self.null_block, np.int32)
        for i, t in enumerate(tables):
            assert len(t) <= n_cols, (len(t), n_cols)
            out[i, : len(t)] = t
        return out

    # ------------------------------------------------------- gather/scatter
    def gather(self, lanes: Sequence[int], tables, *,
               fresh_lane_state: bool = False) -> Any:
        """Materialize per-lane contiguous cache views for a micro-batch.

        ``tables`` is (B, blocks_per_lane) int32; entry order is logical
        order, so concatenating a lane's blocks reconstructs positions
        ``[0, padded_capacity)``.  Unallocated (null) entries contribute
        garbage beyond the lane's valid length, which the attention mask
        (``kv_len``) never reads.

        ``fresh_lane_state=True`` substitutes the pristine ``init_cache``
        value for every non-paged (per-lane) leaf instead of reading the
        lane rows — the prefix-cached prefill seeds a *new* request from
        retained blocks, and its freshly assigned lane may still carry a
        previous occupant's counters.
        """
        lane_idx = jnp.asarray(lanes, jnp.int32)
        tab = jnp.asarray(tables, jnp.int32)
        width = len(lanes)
        leaves = []
        for arr, (paged, axis, _), init in zip(self._storage, self._meta,
                                               self._lane_init):
            if paged:
                # (..., P+1, bs, ...) taken at the block axis with (B, T)
                # indices -> (..., B, T, bs, ...); lane axis to the front,
                # then (T, bs) merges back into the capacity axis
                g = jnp.moveaxis(jnp.take(arr, tab, axis=axis), axis, 0)
                s = g.shape
                g = g.reshape(*s[: 1 + axis], s[1 + axis] * s[2 + axis],
                              *s[3 + axis:])
                leaves.append(g)
            elif fresh_lane_state:
                leaves.append(jnp.broadcast_to(init[None],
                                               (width, *init.shape)))
            else:
                leaves.append(arr[lane_idx])
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def scatter(self, lanes: Sequence[int], tables, caches) -> None:
        """Write batch views back: paged leaves through their block tables,
        per-lane state by lane id.  Padding rows target the null block /
        scratch lane, so duplicate pad indices never race a live lane."""
        lane_idx = jnp.asarray(lanes, jnp.int32)
        tab = jnp.asarray(tables, jnp.int32)
        new_leaves, treedef = jax.tree_util.tree_flatten(caches)
        assert treedef == self._treedef
        out = []
        for arr, new, (paged, axis, _) in zip(self._storage, new_leaves,
                                              self._meta):
            if paged:
                s = new.shape
                v = new.reshape(*s[: 1 + axis], s[1 + axis] // self.block_size,
                                self.block_size, *s[2 + axis:])
                # (B, *pre, T, bs, *post) -> (*pre, B, T, bs, *post); the
                # advanced index (B, T) at the block axis consumes (B, T)
                v = jnp.moveaxis(v, 0, axis)
                idx = (slice(None),) * axis + (tab,)
                out.append(arr.at[idx].set(v.astype(arr.dtype)))
            else:
                out.append(arr.at[lane_idx].set(new.astype(arr.dtype)))
        self._storage = out

    # ----------------------------------------------- kernel-resident decode
    def decode_cache(self, lanes: Sequence[int]) -> Any:
        """Hybrid cache pytree for the batched kernel-resident decode step.

        Paged leaves enter *by reference* — the pool's physical block
        arrays, ``(..., num_blocks + 1, block_size, ...)`` with the unit
        axis still leading, so the model's unit scan slices them without
        a gather and the paged-decode attention reads blocks through the
        micro-batch's (trimmed) tables.  Non-paged leaves (SSM/LRU state,
        ``len`` counters) are gathered by lane id, with the lane axis
        spliced where ``init_cache(cfg, B)`` would put the batch axis —
        the only O(1)-per-lane state that still round-trips per step.
        """
        lane_idx = jnp.asarray(lanes, jnp.int32)
        leaves = []
        for arr, (paged, _, baxis) in zip(self._storage, self._meta):
            if paged:
                leaves.append(arr)
            else:
                # (B, *t.shape) -> lane axis replaces the size-1 batch axis
                g = jnp.moveaxis(arr[lane_idx], 0, baxis)
                leaves.append(jnp.squeeze(g, axis=baxis + 1))
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def absorb_decode(self, lanes: Sequence[int], caches: Any) -> None:
        """Adopt a kernel-resident decode step's outputs: paged leaves
        replace the pool arrays wholesale (the step wrote exactly one
        token per lane through the block table — shared prefix blocks
        were never write targets, ``_grow_block_tables`` CoW'd the tail
        first), non-paged lane state scatters back by lane id."""
        lane_idx = jnp.asarray(lanes, jnp.int32)
        new_leaves, treedef = jax.tree_util.tree_flatten(caches)
        assert treedef == self._treedef
        out = []
        for arr, new, (paged, _, baxis) in zip(self._storage, new_leaves,
                                               self._meta):
            if paged:
                assert new.shape == arr.shape, (new.shape, arr.shape)
                out.append(new.astype(arr.dtype))
            else:
                v = jnp.moveaxis(jnp.expand_dims(new, baxis + 1), baxis, 0)
                out.append(arr.at[lane_idx].set(v.astype(arr.dtype)))
        self._storage = out

    # --------------------------------------------------- prefix-cache hooks
    def copy_block(self, src: int, dst: int) -> None:
        """Copy one physical block's content across every paged leaf —
        the device half of copy-on-write: a request about to write into a
        shared block gets a private ``dst`` holding identical bytes."""
        out = []
        for arr, (paged, axis, _) in zip(self._storage, self._meta):
            if paged:
                idx = (slice(None),) * axis
                out.append(arr.at[idx + (dst,)].set(arr[idx + (src,)]))
            else:
                out.append(arr)
        self._storage = out

    def override_counters(self, caches: Any, value) -> Any:
        """Set every non-paged integer leaf (position counters) to ``value``.

        The suffix/chunked prefill runs only ``W`` uncached tokens per
        lane, so the model's ``len`` accounting comes out as ``W`` (or
        junk for padded lanes) instead of the true logical fill; the
        gateway pins it to the real fill before scattering.  ``value``
        may be a scalar (every lane gets it — the bucketed suffix path)
        or a (B,) array of per-lane fills (the left-aligned chunked path,
        where every lane's cursor differs).  Valid exactly because
        ``prefix_cacheable`` guarantees non-paged leaves are counters."""
        leaves, treedef = jax.tree_util.tree_flatten(caches)
        assert treedef == self._treedef
        val = jnp.asarray(value, jnp.int32)
        out = []
        for leaf, (paged, _, _) in zip(leaves, self._meta):
            if not paged and jnp.issubdtype(leaf.dtype, jnp.integer):
                if val.ndim == 0:
                    out.append(jnp.full_like(leaf, value))
                else:
                    # gathered non-paged leaves carry the lane axis first:
                    # (B, *batch1_leaf_shape)
                    v = val.reshape((val.shape[0],) + (1,) * (leaf.ndim - 1))
                    out.append(jnp.broadcast_to(v, leaf.shape).astype(leaf.dtype))
            else:
                out.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, out)

    def stats(self) -> Dict[str, int]:
        st = self.allocator.stats()
        st.update(block_size=self.block_size, cache_tokens=self.cache_tokens,
                  blocks_per_lane=self.blocks_per_lane,
                  num_lanes=self.num_lanes,
                  # per-block byte cost: the fleet budget's exchange rate
                  block_bytes=self.block_bytes)
        return st
