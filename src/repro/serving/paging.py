"""Block-paged KV cache pool: vLLM-style paging under the licensed gateway.

The seed :class:`~repro.serving.scheduler.CachePool` reserves one
``capacity``-token KV slab per lane, so a 4-token request strands as much
cache memory as a 64-token one and the lane count — not the memory — caps
concurrency.  This module replaces the slab with **fixed-size blocks**:

* :class:`BlockAllocator` — a host-side free list of physical block ids.
  Requests allocate blocks on demand (``ceil(max_prompt/block_size)`` at
  prefill, one more whenever decode crosses a block boundary) and return
  them all on finish or preemption, so short and long requests share the
  pool without over-reserving.
* :class:`PagedCachePool` — the device-side store.  Per-token cache
  leaves (attention K/V, MLA compressed KV, int8 KV scales) live as
  ``(num_blocks + 1, ..., block_size, ...)`` physical blocks addressed
  through per-request **block tables**; constant-size per-lane state
  (SSM conv/state, RG-LRU state, ``len`` counters, sliding-window ring
  caches whose window is below the pool capacity) stays lane-stacked
  exactly like ``CachePool``.

``gather(lanes, tables)`` materializes each lane's logical cache as a
contiguous batch-1 view (block-table order == logical order — blocks are
appended as the sequence grows), so the gateway's lane-vmapped
prefill/decode runs unmodified; ``scatter`` writes the views back through
the same tables.  Index ``num_blocks`` is a *null block* and index
``num_lanes`` a *scratch lane*: both absorb the writes of padding rows so
duplicate pad indices can never corrupt a live request.  The
TPU-compiled decode path that skips the materialized view and gathers
K/V inside the kernel is ``kernels/paged_attention.py``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pad_lane_ids(lanes: Sequence[int], width: int,
                 scratch: int) -> List[int]:
    """Pad a lane-id list to ``width`` with the scratch lane (shared by
    the contiguous and paged pools so the padding contract can't drift)."""
    lanes = list(lanes)
    assert len(lanes) <= width, (len(lanes), width)
    return lanes + [scratch] * (width - len(lanes))


class NoPagedLeavesError(ValueError):
    """The model's cache holds no per-token leaves to page (pure-recurrent,
    or every attention window is below the pool capacity).  The gateway
    catches exactly this to fall back to the contiguous pool; genuine
    geometry errors stay plain ``ValueError`` and propagate."""


class BlockAllocator:
    """Free list of physical cache blocks with double-alloc/free guards.

    Allocation is all-or-nothing (``alloc`` returns ``None`` rather than a
    partial grant) so a caller never holds a half-provisioned request.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 1:
            raise ValueError(f"num_blocks must be >= 1, got {num_blocks}")
        self.num_blocks = int(num_blocks)
        self._free: List[int] = list(range(self.num_blocks))
        self._held: set = set()

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_held(self) -> int:
        return len(self._held)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Atomically allocate ``n`` blocks; None if the pool can't cover it."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        self._held.update(got)
        return got

    def free(self, blocks: Sequence[int]) -> None:
        """Return blocks to the pool; double-frees and foreign ids raise."""
        for b in blocks:
            if b not in self._held:
                raise ValueError(f"free of unallocated block {b}")
            self._held.discard(b)
            self._free.append(b)

    def stats(self) -> Dict[str, int]:
        return {"num_blocks": self.num_blocks, "free": self.num_free,
                "held": self.num_held}


class PagedCachePool:
    """Block-paged KV/SSM cache store behind per-request block tables.

    Parameters
    ----------
    cfg:
        Model config; the cache pytree layout comes from
        ``model.init_cache``.
    num_lanes:
        Per-lane state slots.  Decoupled from the gateway's ``max_batch``
        vmap width: with paging, concurrency is bounded by *blocks*, so a
        gateway can run more lanes than it decodes per step.
    capacity:
        Logical per-request token capacity (prompt bucket + decode cap).
    block_size:
        Tokens per physical block.
    num_blocks:
        Physical blocks shared by every lane and license tier.  Must be
        at least ``blocks_per_lane`` so one full-capacity request always
        fits (the preemption policy's termination guarantee).
    """

    def __init__(self, cfg: ModelConfig, num_lanes: int, capacity: int,
                 block_size: int, num_blocks: int):
        self.cfg = cfg
        self.num_lanes = int(num_lanes)
        self.capacity = int(capacity)
        self.block_size = int(block_size)
        self.num_blocks = int(num_blocks)
        if self.block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        self.blocks_per_lane = cdiv(self.capacity, self.block_size)
        # the vmapped model sees this (static) capacity; positions beyond
        # the logical capacity are dead weight masked by the cache ``len``
        self.padded_capacity = self.blocks_per_lane * self.block_size
        if self.num_blocks < self.blocks_per_lane:
            raise ValueError(
                f"num_blocks={num_blocks} cannot hold one full request "
                f"({self.blocks_per_lane} blocks of {self.block_size})")
        self.allocator = BlockAllocator(self.num_blocks)

        # Classify cache leaves by probing init_cache at two capacities:
        # a leaf whose shape grows by exactly block_size along one axis is
        # per-token (paged); anything else — SSM/LRU state, len counters,
        # window ring caches already capped below the pool capacity — is
        # constant-size per-lane state.
        template = model_lib.init_cache(cfg, 1, self.padded_capacity)
        probe = model_lib.init_cache(
            cfg, 1, self.padded_capacity + self.block_size)
        t_leaves, self._treedef = jax.tree_util.tree_flatten(template)
        p_leaves, _ = jax.tree_util.tree_flatten(probe)
        self._meta: List[Tuple[bool, int]] = []   # (paged, capacity axis)
        self._storage: List[jnp.ndarray] = []
        for t, p in zip(t_leaves, p_leaves):
            diff = [i for i, (a, b) in enumerate(zip(t.shape, p.shape))
                    if a != b]
            if len(diff) == 1 and p.shape[diff[0]] - t.shape[diff[0]] == \
                    self.block_size:
                axis = diff[0]
                shape = list(t.shape)
                shape[axis] = self.block_size
                self._meta.append((True, axis))
                self._storage.append(
                    jnp.zeros((self.num_blocks + 1, *shape), t.dtype))
            else:
                self._meta.append((False, -1))
                self._storage.append(jnp.broadcast_to(
                    t[None], (self.num_lanes + 1, *t.shape)))
        if not any(paged for paged, _ in self._meta):
            raise NoPagedLeavesError(
                "no per-token cache leaves to page (pure-recurrent model); "
                "use the contiguous CachePool instead")

    # ------------------------------------------------------------- indices
    @property
    def scratch(self) -> int:
        """Scratch lane id absorbing padded per-lane-state writes."""
        return self.num_lanes

    @property
    def null_block(self) -> int:
        """Null block id absorbing padded block-table writes."""
        return self.num_blocks

    @property
    def cache_tokens(self) -> int:
        """Token capacity of the shared pool (excludes the null block)."""
        return self.num_blocks * self.block_size

    @property
    def nbytes(self) -> int:
        return sum(int(x.nbytes) for x in self._storage)

    def pad_lanes(self, lanes: Sequence[int], width: int) -> List[int]:
        return pad_lane_ids(lanes, width, self.scratch)

    def pad_tables(self, tables: Sequence[Sequence[int]],
                   width: int) -> np.ndarray:
        """(width, blocks_per_lane) int32 table matrix, null-padded."""
        assert len(tables) <= width, (len(tables), width)
        out = np.full((width, self.blocks_per_lane), self.null_block,
                      np.int32)
        for i, t in enumerate(tables):
            assert len(t) <= self.blocks_per_lane, (len(t),
                                                    self.blocks_per_lane)
            out[i, : len(t)] = t
        return out

    # ------------------------------------------------------- gather/scatter
    def gather(self, lanes: Sequence[int], tables) -> Any:
        """Materialize per-lane contiguous cache views for a micro-batch.

        ``tables`` is (B, blocks_per_lane) int32; entry order is logical
        order, so concatenating a lane's blocks reconstructs positions
        ``[0, padded_capacity)``.  Unallocated (null) entries contribute
        garbage beyond the lane's valid length, which the attention mask
        (``kv_len``) never reads.
        """
        lane_idx = jnp.asarray(lanes, jnp.int32)
        tab = jnp.asarray(tables, jnp.int32)
        leaves = []
        for arr, (paged, axis) in zip(self._storage, self._meta):
            if paged:
                g = jnp.moveaxis(arr[tab], 1, 1 + axis)
                s = g.shape
                g = g.reshape(*s[: 1 + axis], s[1 + axis] * s[2 + axis],
                              *s[3 + axis:])
                leaves.append(g)
            else:
                leaves.append(arr[lane_idx])
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    def scatter(self, lanes: Sequence[int], tables, caches) -> None:
        """Write batch views back: paged leaves through their block tables,
        per-lane state by lane id.  Padding rows target the null block /
        scratch lane, so duplicate pad indices never race a live lane."""
        lane_idx = jnp.asarray(lanes, jnp.int32)
        tab = jnp.asarray(tables, jnp.int32)
        new_leaves, treedef = jax.tree_util.tree_flatten(caches)
        assert treedef == self._treedef
        out = []
        for arr, new, (paged, axis) in zip(self._storage, new_leaves,
                                           self._meta):
            if paged:
                s = new.shape
                v = new.reshape(*s[: 1 + axis], s[1 + axis] // self.block_size,
                                self.block_size, *s[2 + axis:])
                v = jnp.moveaxis(v, 1 + axis, 1)
                out.append(arr.at[tab].set(v.astype(arr.dtype)))
            else:
                out.append(arr.at[lane_idx].set(new.astype(arr.dtype)))
        self._storage = out

    def stats(self) -> Dict[str, int]:
        st = self.allocator.stats()
        st.update(block_size=self.block_size, cache_tokens=self.cache_tokens,
                  blocks_per_lane=self.blocks_per_lane,
                  num_lanes=self.num_lanes)
        return st
