"""Continuous-batching scheduler for the licensed serving gateway.

The seed ``ServingEngine`` serves one request stream at a time: a static
batch is prefilled together and decoded in lock-step until the *longest*
request finishes.  The gateway instead schedules at *iteration* level
(Orca-style continuous batching): every scheduler step emits one
micro-batch — either a PREFILL of newly admitted requests or a DECODE
step over running ones — so a finished request's lane is refilled
immediately while the rest of the batch keeps decoding.

Licensing adds one constraint on top of stock continuous batching: all
requests in a micro-batch must share a **(license tier, weight version)**
key, because the batch is served through a single masked weight view
(§3.5 — one stored weight set, per-tier interval-masked views).  The
pieces here are pure host-side bookkeeping; the jitted compute lives in
``serving/gateway.py``:

* ``GatewayRequest``   — one in-flight generation with its pinned
  ``(tier, version)`` key, lane assignment, and latency timestamps;
* ``TierViewCache``    — LRU cache of licensed weight views keyed by
  (tier, version), so ``apply_license``/interval packing is paid once per
  key instead of once per request (shared with ``ServingEngine``);
* ``CachePool``        — lane-stacked KV/SSM cache pool shared by every
  tier, with gather/scatter by lane id and a scratch lane that absorbs
  padded writes (the contiguous fallback; the default block-paged pool
  is ``serving/paging.py``);
* ``Scheduler``        — admission queue + the prefill-priority,
  queue-age-fair, block-budgeted policy that picks the next micro-batch
  (and the preemption hook the paged pool's exhaustion path uses).
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Deque, Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.serving.paging import pad_lane_ids


class RequestState(str, Enum):
    QUEUED = "queued"        # admitted, waiting for a free lane
    PREFILLING = "prefilling"  # holds a lane, prompt chunking through
    RUNNING = "running"      # prefilled, holds a lane, decoding
    DONE = "done"            # produced max_new_tokens
    REJECTED = "rejected"    # failed admission (unknown tier / bad prompt)


@dataclass(eq=False)   # identity equality: requests live in queues
class GatewayRequest:
    """One generation request flowing through the gateway.

    ``license``/``version`` form the micro-batch key: the scheduler only
    groups requests whose (tier, version) match, so one masked weight
    view serves the whole batch.  ``version`` is pinned at admission —
    a weight update mid-flight never changes the view a request sees.
    """

    prompt: np.ndarray                       # (S,) int32
    max_new_tokens: int = 16
    license: str = "full"
    temperature: float = 0.0
    top_k: int = 0                           # 0 = no top-k truncation
    seed: int = 0

    # fleet serving (serving/fleet.py): the model slot the request was
    # submitted to and the tenant it is billed against — together with
    # (license, version) they complete the fleet's micro-batch key
    model: Optional[str] = None
    tenant: Optional[str] = None

    # assigned by the gateway
    rid: int = -1
    version: Optional[int] = None            # weight version pinned at admission
    state: RequestState = RequestState.QUEUED
    out_tokens: List[int] = field(default_factory=list)
    lane: Optional[int] = None               # cache-pool lane while RUNNING
    blocks: List[int] = field(default_factory=list)  # paged-pool block table
    prefix_tokens: int = 0                   # prompt tokens served from the
                                             # prefix cache at prefill
    cursor: int = 0                          # prompt tokens already prefilled
                                             # (chunked prefill progress)
    pos: int = 0                             # next decode position
    start_seq: int = -1                      # admission order (preemption age)
    preemptions: int = 0
    logits_rows: Optional[List[np.ndarray]] = None   # record_logits only
    error: Optional[str] = None
    submit_t: float = 0.0
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None

    # telemetry bookkeeping (serving/telemetry.py).  ``_ttft_done``
    # survives preemption — a restarted request re-emits its first token
    # but its TTFT was already counted once; ``_last_tok_t`` does not
    # (the inter-token gap across a preemption gap is not a decode gap).
    _ttft_done: bool = False
    _last_tok_t: Optional[float] = None
    _open_span: Optional[str] = None         # current lifecycle B span

    @property
    def group_key(self) -> Tuple[str, Optional[int]]:
        return (self.license, self.version)

    @property
    def latency(self) -> Optional[float]:
        """Submit -> last token wall time (None until DONE)."""
        if self.finish_t is None:
            return None
        return self.finish_t - self.submit_t

    @property
    def ttft(self) -> Optional[float]:
        """Submit -> first token wall time."""
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t


@dataclass
class ScheduledAction:
    """One micro-batch decision: prefill or decode a tier-homogeneous group.

    ``suffix_bucket`` records the prefix-aware admission decision for
    prefills: the uncached-suffix width every member of the batch shares
    (None when grouping is off or for decode actions).  ``model`` is the
    serving slot's model name — under a ``FleetGateway`` every action is
    keyed (model, tier, version); a standalone gateway stamps its own
    (single) model name."""

    kind: str                                # "prefill" | "decode"
    tier: str
    version: Optional[int]
    requests: List[GatewayRequest]
    suffix_bucket: Optional[int] = None
    model: Optional[str] = None


class TierViewCache:
    """LRU cache of licensed weight views keyed by (tier, version).

    ``build(tier_name, version)`` materializes a view on miss — for the
    float path that is ``apply_license`` over the full tree, for the int8
    path just the packed license intervals.  Either way the cost is paid
    once per (tier, version), not once per request: the amortization the
    gateway's throughput claim rests on.  Hit/miss/invalidation counters
    are exported via :meth:`stats` and asserted by the benchmarks.
    """

    def __init__(self, build: Callable[[str, Optional[int]], Any],
                 capacity: int = 8):
        self._build = build
        self.capacity = int(capacity)
        self._entries: "OrderedDict[Tuple[str, Optional[int]], Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def get(self, tier: str, version: Optional[int] = None) -> Any:
        key = (tier, version)
        if key in self._entries:
            self.hits += 1
            self._entries.move_to_end(key)
            return self._entries[key]
        self.misses += 1
        view = self._build(tier, version)
        self._entries[key] = view
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return view

    def __contains__(self, key: Tuple[str, Optional[int]]) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def invalidate(self, *, tier: Optional[str] = None,
                   version: Optional[int] = None) -> int:
        """Drop entries matching the given tier and/or version (None = any)."""
        doomed = [k for k in self._entries
                  if (tier is None or k[0] == tier)
                  and (version is None or k[1] == version)]
        for k in doomed:
            del self._entries[k]
        self.invalidations += len(doomed)
        return len(doomed)

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "invalidations": self.invalidations,
                "entries": len(self._entries)}


class CachePool:
    """Shared KV/SSM cache pool: ``num_lanes`` per-request cache slots.

    Leaves are lane-stacked: leading axis indexes the lane, each lane
    holding a batch-1 cache from ``init_cache(cfg, 1, capacity)``.  The
    gateway's decode is ``vmap``-ed over this axis, which is what lets
    every lane carry its own absolute position (requests at different
    depths share one micro-batch).  One extra *scratch* lane (index
    ``num_lanes``) absorbs the writes of padding lanes, so scatters with
    duplicate pad indices can never corrupt a live request.
    """

    def __init__(self, cfg: ModelConfig, num_lanes: int, capacity: int):
        self.num_lanes = int(num_lanes)
        self.capacity = int(capacity)
        lane = model_lib.init_cache(cfg, 1, capacity)
        self.cache = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.num_lanes + 1, *x.shape)),
            lane,
        )

    @property
    def scratch(self) -> int:
        return self.num_lanes

    @property
    def cache_tokens(self) -> int:
        """Token capacity reserved across lanes (excludes the scratch lane);
        the equal-memory axis the paged-pool benchmark compares on."""
        return self.num_lanes * self.capacity

    @property
    def nbytes(self) -> int:
        return sum(int(x.nbytes) for x in jax.tree_util.tree_leaves(self.cache))

    def stats(self) -> Dict[str, int]:
        """Occupancy facts.  Shares only the ``cache_tokens``/``num_lanes``
        core with ``PagedCachePool.stats`` — block-geometry keys
        (``num_blocks``, ``free``, ...) exist only on the paged pool, so
        pool-agnostic callers must key off ``metrics()['cache_pool']['paged']``
        before reading them."""
        return {"cache_tokens": self.cache_tokens,
                "num_lanes": self.num_lanes, "capacity": self.capacity}

    def pad_lanes(self, lanes: Sequence[int], width: int) -> List[int]:
        """Pad a lane-id list to ``width`` with the scratch lane."""
        return pad_lane_ids(lanes, width, self.scratch)

    def gather(self, lanes: Sequence[int]):
        idx = jnp.asarray(lanes, jnp.int32)
        return jax.tree_util.tree_map(lambda x: x[idx], self.cache)

    def scatter(self, lanes: Sequence[int], lane_caches) -> None:
        idx = jnp.asarray(lanes, jnp.int32)
        self.cache = jax.tree_util.tree_map(
            lambda pool, new: pool.at[idx].set(new.astype(pool.dtype)),
            self.cache, lane_caches,
        )


class Scheduler:
    """Prefill-priority continuous-batching policy with block-aware admission.

    * admission serves the waiting (tier, version) group whose **oldest
      member has waited longest** (queue-wait aging, not deque position —
      the two differ once a preempted request is requeued at the front),
      then every same-key request in queue order, up to the free lane
      count, ``max_batch``, and — when a block allocator is attached —
      the free-block budget above the watermark.  Aging means a hot
      tier's prefill stream cannot starve another tier's queued requests:
      whichever group is oldest is served next, regardless of how many
      hot-tier requests sit in front of it.
    * with nothing to prefill, decode round-robins over the running
      (tier, version) groups so no tier starves, rotating *within* a
      group when it exceeds ``max_batch``;
    * :meth:`preempt` returns a running request to the *front* of the
      queue (it keeps its original ``submit_t``, so aging re-admits it
      first) — the gateway invokes it on the youngest running request
      when the block pool is exhausted mid-decode.
    * ``chunked=True`` switches to the left-aligned chunked-prefill
      policy: admitted requests enter PREFILLING and advance one chunk
      per prefill action, strictly alternating with decode steps over
      the RUNNING set — the bounded-stall guarantee that no decode step
      waits longer than one chunk.  Admission budgets blocks per request
      via ``blocks_needed`` (true prompt length) instead of the flat
      worst-case ``prefill_blocks``.
    """

    def __init__(self, num_lanes: int, max_batch: int, *,
                 allocator: Any = None, prefill_blocks: int = 0,
                 watermark_blocks: int = 0,
                 reclaimable: Optional[Callable[[], int]] = None,
                 suffix_bucket: Optional[
                     Callable[[GatewayRequest], int]] = None,
                 suffix_revalidate: Optional[
                     Callable[[GatewayRequest], int]] = None,
                 chunked: bool = False,
                 blocks_needed: Optional[
                     Callable[[GatewayRequest], int]] = None,
                 clock: Callable[[], float] = time.perf_counter):
        self.num_lanes = int(num_lanes)
        self.max_batch = int(max_batch)
        # injectable clock: every wait/latency timestamp in the gateway
        # stack flows through this, so tests can drive virtual time
        self.clock = clock
        self.allocator = allocator
        self.prefill_blocks = int(prefill_blocks)
        self.watermark_blocks = int(watermark_blocks)
        # blocks the gateway can reclaim on demand (prefix-cache retained
        # chains with no live request references) — they count toward the
        # admission budget because eviction frees them before allocation
        self.reclaimable = reclaimable
        # prefix-aware admission grouping: probe of a request's uncached
        # suffix width (the gateway wires PrefixCache.peek through this).
        # Prefill lanes share one static suffix width, so batching a
        # full-match lane (1-token suffix) with a cold lane pads the hit
        # up to the cold lane's full width — grouping by bucket keeps
        # each micro-batch at its own (narrow) width instead.
        self.suffix_bucket = suffix_bucket
        # fresh (cache-bypassing) probe used to re-validate members at
        # batch formation: a cached probe taken before an eviction can
        # report a bucket the radix tree no longer backs, and admitting
        # on it would mis-group the batch
        self.suffix_revalidate = suffix_revalidate
        # chunked mode: admitted requests enter PREFILLING and their
        # prompts advance chunk-by-chunk, strictly alternating with
        # decode steps (no decode waits longer than one chunk)
        self.chunked = bool(chunked)
        # per-request block need (chunked admission budgets per prompt
        # length instead of the flat worst-case ``prefill_blocks``)
        self.blocks_needed = blocks_needed
        # fleet hooks, wired post-construction by FleetGateway
        # (serving/fleet.py).  ``global_budget`` returns how many MORE of
        # this slot's blocks the fleet-wide cache budget can cover
        # (counting every slot's reclaimable chains); admission takes the
        # min of the local and global budgets, so one hot model cannot
        # admit past the fleet's shared memory even with a free local
        # pool.  ``admission_filter`` re-validates a QUEUED request at
        # batch formation (tenant entitlement revoked since submit);
        # returning False drops it from the queue — the callback itself
        # marks the request rejected.
        self.global_budget: Optional[Callable[[], int]] = None
        self.admission_filter: Optional[
            Callable[[GatewayRequest], bool]] = None
        self.waiting: Deque[GatewayRequest] = deque()
        self.running: List[GatewayRequest] = []
        self._free_lanes: List[int] = list(range(num_lanes))
        self._rr = 0
        self._chunk_rr = 0
        self._group_cursor: Dict[Hashable, int] = {}
        self._start_seq = 0
        self._last_prefill = False

    # ----------------------------------------------------------- bookkeeping
    def submit(self, req: GatewayRequest) -> None:
        req.state = RequestState.QUEUED
        self.waiting.append(req)

    def free_lanes(self) -> int:
        return len(self._free_lanes)

    def start(self, req: GatewayRequest, *, prefilling: bool = False) -> int:
        """Move a request to RUNNING (or PREFILLING, when its prompt will
        chunk through over several steps), assigning it a lane."""
        lane = self._free_lanes.pop()
        req.lane = lane
        req.state = (RequestState.PREFILLING if prefilling
                     else RequestState.RUNNING)
        req.start_seq = self._start_seq
        self._start_seq += 1
        self.running.append(req)
        return lane

    def finish(self, req: GatewayRequest) -> None:
        """Release the lane of a completed request."""
        self.running.remove(req)
        if req.lane is not None:
            self._free_lanes.append(req.lane)
        req.lane = None
        req.state = RequestState.DONE
        req.finish_t = self.clock()

    def preempt(self, req: GatewayRequest) -> None:
        """Evict a running request back to the head of the queue.

        The request restarts from scratch on re-admission (recompute
        preemption): generation is deterministic given (seed, prompt,
        view), so a restarted request reproduces its evicted tokens.
        Caller is responsible for releasing any cache blocks it held.
        """
        self.running.remove(req)
        if req.lane is not None:
            self._free_lanes.append(req.lane)
        req.lane = None
        req.pos = 0
        req.cursor = 0
        req.prefix_tokens = 0
        req.out_tokens.clear()
        if req.logits_rows is not None:
            req.logits_rows.clear()
        req.first_token_t = None
        req._last_tok_t = None
        req.preemptions += 1
        req.state = RequestState.QUEUED
        self.waiting.appendleft(req)

    def youngest_running(self) -> Optional[GatewayRequest]:
        """Most recently started request — the preemption victim."""
        if not self.running:
            return None
        return max(self.running, key=lambda r: r.start_seq)

    def pinned_versions(self) -> set:
        """Weight versions still referenced by queued or running requests."""
        return {r.version for r in self.waiting} | {r.version for r in self.running}

    def pinned_tier_versions(self) -> set:
        """(tier, version) pairs referenced by queued or running requests —
        the views DEGRADED lease serving is contractually pinned to."""
        return {(r.license, r.version)
                for r in list(self.waiting) + list(self.running)}

    def hot_tiers(self) -> List[str]:
        """License tiers with queued or running requests, busiest first.

        This is the occupancy signal the staged-update prewarm uses: tiers
        serving traffic *now* are the ones whose first admission at a new
        weight version would otherwise pay a cold view materialization."""
        counts: Dict[str, int] = {}
        for r in list(self.running) + list(self.waiting):
            counts[r.license] = counts.get(r.license, 0) + 1
        return sorted(counts, key=lambda t: (-counts[t], t))

    # --------------------------------------------------------- wait metrics
    def oldest_wait_s(self, now: Optional[float] = None) -> float:
        """Age of the oldest queued request (0.0 with an empty queue)."""
        if not self.waiting:
            return 0.0
        now = self.clock() if now is None else now
        return now - min(r.submit_t for r in self.waiting)

    def queue_wait_by_tier(self, now: Optional[float] = None) -> Dict[str, float]:
        """Per-tier age of the oldest queued request."""
        now = self.clock() if now is None else now
        out: Dict[str, float] = {}
        for r in self.waiting:
            age = now - r.submit_t
            out[r.license] = max(out.get(r.license, 0.0), age)
        return out

    # ---------------------------------------------------------------- policy
    def _prefill_room(self) -> int:
        room = min(len(self._free_lanes), self.max_batch)
        if self.allocator is not None and self.prefill_blocks > 0:
            budget = self.allocator.num_free - self.watermark_blocks
            if self.reclaimable is not None:
                budget += self.reclaimable()
            if self.global_budget is not None:
                budget = min(budget, self.global_budget())
            room = min(room, max(0, budget // self.prefill_blocks))
        return room

    def next_action(self) -> Optional[ScheduledAction]:
        if self.chunked:
            return self._next_action_chunked()
        act = self._admission_batch()
        if act is not None:
            return act
        return self._decode_action()

    def _next_action_chunked(self) -> Optional[ScheduledAction]:
        """Chunked-prefill policy: strict alternation between prefill
        chunks (continuing PREFILLING requests first, admitting new ones
        otherwise) and decode steps, so no decode step ever waits longer
        than one chunk and no prefill starves behind a decode stream."""
        chunking = [r for r in self.running
                    if r.state is RequestState.PREFILLING]
        decoding = [r for r in self.running
                    if r.state is RequestState.RUNNING]
        if self._last_prefill and decoding:
            self._last_prefill = False
            return self._decode_action()
        act = (self._chunk_action(chunking) if chunking
               else self._admission_batch())
        if act is not None:
            self._last_prefill = True
            return act
        if decoding:
            self._last_prefill = False
            return self._decode_action()
        return None

    def _chunk_action(self, chunking: List[GatewayRequest]) -> ScheduledAction:
        """Continue mid-prefill requests: round-robin over their (tier,
        version) groups, rotating within a group past ``max_batch``."""
        groups: Dict[Hashable, List[GatewayRequest]] = {}
        for r in chunking:
            groups.setdefault(r.group_key, []).append(r)
        keys = sorted(groups, key=str)
        key = keys[self._chunk_rr % len(keys)]
        self._chunk_rr += 1
        members = groups[key]
        if len(members) > self.max_batch:
            cur = self._group_cursor.get(("chunk", key), 0) % len(members)
            members = (members + members)[cur:cur + self.max_batch]
            self._group_cursor[("chunk", key)] = cur + self.max_batch
        return ScheduledAction("prefill", key[0], key[1], list(members))

    def _admission_batch(self) -> Optional[ScheduledAction]:
        if self.admission_filter is not None and self.waiting:
            # entitlement re-check at batch formation: a tenant revoked
            # since submit must not reach a lane.  The filter marks the
            # request rejected; only survivors stay queued.  In-flight
            # (PREFILLING/RUNNING) requests are never revisited — like
            # tier redefinitions, a revocation drains, it never cancels.
            self.waiting = deque(
                r for r in self.waiting if self.admission_filter(r))
        room = self._prefill_room()
        if not (room and self.waiting):
            return None
        # aging: serve the group whose oldest member arrived first;
        # deque position breaks ties (plain FIFO when ages are equal)
        oldest: Dict[Tuple, Tuple[float, int]] = {}
        for i, r in enumerate(self.waiting):
            cand = (r.submit_t, i)
            if r.group_key not in oldest or cand < oldest[r.group_key]:
                oldest[r.group_key] = cand
        key = min(oldest, key=lambda k: oldest[k])
        bucket: Optional[int] = None
        anchor: Optional[GatewayRequest] = None
        probed: Dict[int, int] = {}          # id(req) -> bucket, one
                                             # probe per request per pass
        if self.suffix_bucket is not None:

            def _bucket(r: GatewayRequest) -> int:
                got = probed.get(id(r))
                if got is None:
                    got = probed[id(r)] = self.suffix_bucket(r)
                return got

            # the oldest member defines the batch's suffix width;
            # same-key requests with a different cached-suffix bucket
            # wait for their own batch rather than padding this one.
            # The anchor's probe is taken fresh when a revalidator is
            # wired: a stale cached bucket must not define the batch.
            anchor = self.waiting[oldest[key][1]]
            if self.suffix_revalidate is not None:
                bucket = probed[id(anchor)] = self.suffix_revalidate(anchor)
            else:
                bucket = _bucket(anchor)
        budget: Optional[int] = None
        if self.allocator is not None and self.blocks_needed is not None:
            budget = self.allocator.num_free - self.watermark_blocks
            if self.reclaimable is not None:
                budget += self.reclaimable()
            if self.global_budget is not None:
                budget = min(budget, self.global_budget())
        batch: List[GatewayRequest] = []
        remaining: Deque[GatewayRequest] = deque()
        for r in self.waiting:               # one pass: select + requeue
            take = (len(batch) < room and r.group_key == key and
                    (bucket is None or _bucket(r) == bucket))
            if (take and bucket is not None and r is not anchor
                    and self.suffix_revalidate is not None):
                # re-validate at formation: the cached probe may predate
                # an eviction that shrank this request's cached prefix
                fresh = probed[id(r)] = self.suffix_revalidate(r)
                take = fresh == bucket
            if take and budget is not None:
                need = self.blocks_needed(r)
                take = need <= budget
                if take:
                    budget -= need
            if take:
                batch.append(r)
            else:
                remaining.append(r)
        if not batch:
            self.waiting = remaining
            return None
        self.waiting = remaining
        return ScheduledAction("prefill", key[0], key[1], batch,
                               suffix_bucket=bucket)

    def _decode_action(self) -> Optional[ScheduledAction]:
        pool = [r for r in self.running if r.state is RequestState.RUNNING]
        if not pool:
            return None
        groups: Dict[Hashable, List[GatewayRequest]] = {}
        for r in pool:
            groups.setdefault(r.group_key, []).append(r)
        keys = sorted(groups, key=str)
        key = keys[self._rr % len(keys)]
        self._rr += 1
        members = groups[key]
        if len(members) > self.max_batch:
            cur = self._group_cursor.get(key, 0) % len(members)
            members = (members + members)[cur:cur + self.max_batch]
            self._group_cursor[key] = cur + self.max_batch
        return ScheduledAction("decode", key[0], key[1], list(members))
