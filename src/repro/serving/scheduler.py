"""Continuous-batching scheduler for the licensed serving gateway.

The seed ``ServingEngine`` serves one request stream at a time: a static
batch is prefilled together and decoded in lock-step until the *longest*
request finishes.  The gateway instead schedules at *iteration* level
(Orca-style continuous batching): every scheduler step emits one
micro-batch — either a PREFILL of newly admitted requests or a DECODE
step over running ones — so a finished request's lane is refilled
immediately while the rest of the batch keeps decoding.

Licensing adds one constraint on top of stock continuous batching: all
requests in a micro-batch must share a **(license tier, weight version)**
key, because the batch is served through a single masked weight view
(§3.5 — one stored weight set, per-tier interval-masked views).  The
pieces here are pure host-side bookkeeping; the jitted compute lives in
``serving/gateway.py``:

* ``GatewayRequest``   — one in-flight generation with its pinned
  ``(tier, version)`` key, lane assignment, and latency timestamps;
* ``TierViewCache``    — LRU cache of licensed weight views keyed by
  (tier, version), so ``apply_license``/interval packing is paid once per
  key instead of once per request (shared with ``ServingEngine``);
* ``CachePool``        — lane-stacked KV/SSM cache pool shared by every
  tier, with gather/scatter by lane id and a scratch lane that absorbs
  padded writes;
* ``Scheduler``        — admission queue + the prefill-priority,
  tier-round-robin policy that picks the next micro-batch.
"""
from __future__ import annotations

import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from enum import Enum
from typing import Any, Callable, Deque, Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib


class RequestState(str, Enum):
    QUEUED = "queued"        # admitted, waiting for a free lane
    RUNNING = "running"      # prefilled, holds a lane, decoding
    DONE = "done"            # produced max_new_tokens
    REJECTED = "rejected"    # failed admission (unknown tier / bad prompt)


@dataclass(eq=False)   # identity equality: requests live in queues
class GatewayRequest:
    """One generation request flowing through the gateway.

    ``license``/``version`` form the micro-batch key: the scheduler only
    groups requests whose (tier, version) match, so one masked weight
    view serves the whole batch.  ``version`` is pinned at admission —
    a weight update mid-flight never changes the view a request sees.
    """

    prompt: np.ndarray                       # (S,) int32
    max_new_tokens: int = 16
    license: str = "full"
    temperature: float = 0.0
    seed: int = 0

    # assigned by the gateway
    rid: int = -1
    version: Optional[int] = None            # weight version pinned at admission
    state: RequestState = RequestState.QUEUED
    out_tokens: List[int] = field(default_factory=list)
    lane: Optional[int] = None               # cache-pool lane while RUNNING
    pos: int = 0                             # next decode position
    error: Optional[str] = None
    submit_t: float = 0.0
    first_token_t: Optional[float] = None
    finish_t: Optional[float] = None

    @property
    def group_key(self) -> Tuple[str, Optional[int]]:
        return (self.license, self.version)

    @property
    def latency(self) -> Optional[float]:
        """Submit -> last token wall time (None until DONE)."""
        if self.finish_t is None:
            return None
        return self.finish_t - self.submit_t

    @property
    def ttft(self) -> Optional[float]:
        """Submit -> first token wall time."""
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t


@dataclass
class ScheduledAction:
    """One micro-batch decision: prefill or decode a tier-homogeneous group."""

    kind: str                                # "prefill" | "decode"
    tier: str
    version: Optional[int]
    requests: List[GatewayRequest]


class TierViewCache:
    """LRU cache of licensed weight views keyed by (tier, version).

    ``build(tier_name, version)`` materializes a view on miss — for the
    float path that is ``apply_license`` over the full tree, for the int8
    path just the packed license intervals.  Either way the cost is paid
    once per (tier, version), not once per request: the amortization the
    gateway's throughput claim rests on.  Hit/miss/invalidation counters
    are exported via :meth:`stats` and asserted by the benchmarks.
    """

    def __init__(self, build: Callable[[str, Optional[int]], Any],
                 capacity: int = 8):
        self._build = build
        self.capacity = int(capacity)
        self._entries: "OrderedDict[Tuple[str, Optional[int]], Any]" = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def get(self, tier: str, version: Optional[int] = None) -> Any:
        key = (tier, version)
        if key in self._entries:
            self.hits += 1
            self._entries.move_to_end(key)
            return self._entries[key]
        self.misses += 1
        view = self._build(tier, version)
        self._entries[key] = view
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1
        return view

    def __contains__(self, key: Tuple[str, Optional[int]]) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def invalidate(self, *, tier: Optional[str] = None,
                   version: Optional[int] = None) -> int:
        """Drop entries matching the given tier and/or version (None = any)."""
        doomed = [k for k in self._entries
                  if (tier is None or k[0] == tier)
                  and (version is None or k[1] == version)]
        for k in doomed:
            del self._entries[k]
        self.invalidations += len(doomed)
        return len(doomed)

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "invalidations": self.invalidations,
                "entries": len(self._entries)}


class CachePool:
    """Shared KV/SSM cache pool: ``num_lanes`` per-request cache slots.

    Leaves are lane-stacked: leading axis indexes the lane, each lane
    holding a batch-1 cache from ``init_cache(cfg, 1, capacity)``.  The
    gateway's decode is ``vmap``-ed over this axis, which is what lets
    every lane carry its own absolute position (requests at different
    depths share one micro-batch).  One extra *scratch* lane (index
    ``num_lanes``) absorbs the writes of padding lanes, so scatters with
    duplicate pad indices can never corrupt a live request.
    """

    def __init__(self, cfg: ModelConfig, num_lanes: int, capacity: int):
        self.num_lanes = int(num_lanes)
        self.capacity = int(capacity)
        lane = model_lib.init_cache(cfg, 1, capacity)
        self.cache = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x[None], (self.num_lanes + 1, *x.shape)),
            lane,
        )

    @property
    def scratch(self) -> int:
        return self.num_lanes

    def pad_lanes(self, lanes: Sequence[int], width: int) -> List[int]:
        """Pad a lane-id list to ``width`` with the scratch lane."""
        lanes = list(lanes)
        assert len(lanes) <= width, (len(lanes), width)
        return lanes + [self.scratch] * (width - len(lanes))

    def gather(self, lanes: Sequence[int]):
        idx = jnp.asarray(lanes, jnp.int32)
        return jax.tree_util.tree_map(lambda x: x[idx], self.cache)

    def scatter(self, lanes: Sequence[int], lane_caches) -> None:
        idx = jnp.asarray(lanes, jnp.int32)
        self.cache = jax.tree_util.tree_map(
            lambda pool, new: pool.at[idx].set(new.astype(pool.dtype)),
            self.cache, lane_caches,
        )


class Scheduler:
    """Prefill-priority continuous-batching policy.

    * admission is FIFO; a prefill batch takes the oldest waiting request
      and every same-(tier, version) request behind it, up to the free
      lane count and ``max_batch`` — tier homogeneity by construction;
    * with nothing to prefill, decode round-robins over the running
      (tier, version) groups so no tier starves, rotating *within* a
      group when it exceeds ``max_batch``.
    """

    def __init__(self, num_lanes: int, max_batch: int):
        self.num_lanes = int(num_lanes)
        self.max_batch = int(max_batch)
        self.waiting: Deque[GatewayRequest] = deque()
        self.running: List[GatewayRequest] = []
        self._free_lanes: List[int] = list(range(num_lanes))
        self._rr = 0
        self._group_cursor: Dict[Hashable, int] = {}

    # ----------------------------------------------------------- bookkeeping
    def submit(self, req: GatewayRequest) -> None:
        req.state = RequestState.QUEUED
        self.waiting.append(req)

    def free_lanes(self) -> int:
        return len(self._free_lanes)

    def start(self, req: GatewayRequest) -> int:
        """Move a request to RUNNING, assigning it a lane."""
        lane = self._free_lanes.pop()
        req.lane = lane
        req.state = RequestState.RUNNING
        self.running.append(req)
        return lane

    def finish(self, req: GatewayRequest) -> None:
        """Release the lane of a completed request."""
        self.running.remove(req)
        if req.lane is not None:
            self._free_lanes.append(req.lane)
        req.lane = None
        req.state = RequestState.DONE
        req.finish_t = time.perf_counter()

    def pinned_versions(self) -> set:
        """Weight versions still referenced by queued or running requests."""
        return {r.version for r in self.waiting} | {r.version for r in self.running}

    # ---------------------------------------------------------------- policy
    def next_action(self) -> Optional[ScheduledAction]:
        free = len(self._free_lanes)
        if free and self.waiting:
            key = self.waiting[0].group_key
            room = min(free, self.max_batch)
            batch: List[GatewayRequest] = []
            remaining: Deque[GatewayRequest] = deque()
            for r in self.waiting:               # one pass: select + requeue
                if len(batch) < room and r.group_key == key:
                    batch.append(r)
                else:
                    remaining.append(r)
            self.waiting = remaining
            return ScheduledAction("prefill", key[0], key[1], batch)

        if self.running:
            groups: Dict[Hashable, List[GatewayRequest]] = {}
            for r in self.running:
                groups.setdefault(r.group_key, []).append(r)
            keys = sorted(groups, key=str)
            key = keys[self._rr % len(keys)]
            self._rr += 1
            members = groups[key]
            if len(members) > self.max_batch:
                cur = self._group_cursor.get(key, 0) % len(members)
                members = (members + members)[cur:cur + self.max_batch]
                self._group_cursor[key] = cur + self.max_batch
            return ScheduledAction("decode", key[0], key[1], list(members))

        return None
