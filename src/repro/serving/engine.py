"""Serving engine: batched prefill + decode with KV/SSM caches, and the
paper's *licensed serving* as a first-class feature — a request's license
tier selects the interval-masked weight view served to it (one stored
weight set, many accuracy tiers, §3.5).

``serve_step`` / ``prefill_step`` are the pure functions the multi-pod
dry-run lowers; ``ServingEngine`` is the host-side driver (edge-device or
serving-pod role from Fig. 2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.licensing import FULL_TIER, LicenseTier, apply_license
from repro.models import model as model_lib
from repro.serving.scheduler import TierViewCache


def prefill_step(params, cfg: ModelConfig, tokens, cache,
                 patch_embeds=None, license_intervals=None):
    """Fill the cache from a token batch; returns (last-token logits, cache)."""
    logits, _, cache = model_lib.forward(
        params, cfg, tokens, patch_embeds=patch_embeds, cache=cache, pos=0,
        license_intervals=license_intervals,
    )
    return logits[:, -1], cache


def prefill_suffix_step(params, cfg: ModelConfig, tokens, cache, pos,
                        license_intervals=None):
    """Suffix prefill: extend a cache already holding positions ``[0, pos)``
    with ``tokens`` (B, W) — the uncached tail of a prompt whose prefix the
    prefix cache (serving/prefix.py) restored from retained blocks.

    Unlike :func:`prefill_step`, attention reads the resident cache (the
    shared prefix plus this step's own writes), and the *full* per-step
    logits come back — the caller picks the row of the last real token,
    which for right-padded suffixes is not the last row.  ``pos`` may be a
    per-lane traced scalar under ``vmap`` (variable prefill offsets)."""
    logits, _, cache = model_lib.forward(
        params, cfg, tokens, cache=cache, pos=pos,
        license_intervals=license_intervals, attend_cache=True,
    )
    return logits, cache


def stack_lane_caches(cfg: ModelConfig, b: int, capacity: int):
    """``b`` independent batch-1 caches stacked on a new leading lane
    axis — the layout :func:`prefill_chunk_step` (and the gateway's
    vmapped per-lane steps) operates on.  Unlike ``init_cache(cfg, b,
    capacity)``, every leaf gets the lane axis *first* regardless of
    where its batch axis sits, so ``vmap`` over axis 0 hands each lane
    exactly a batch-1 cache."""
    lane = model_lib.init_cache(cfg, 1, capacity)
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (b, *x.shape)), lane)


def prefill_chunk_step(params, cfg: ModelConfig, tokens, caches, pos,
                       chunk_valid=None, license_intervals=None):
    """Left-aligned chunked prefill: advance each lane's cursor by up to
    ``chunk_size`` tokens against its own cache.

    ``tokens`` (B, W) holds each lane's next chunk starting at that
    lane's absolute cursor ``pos`` (B,); lanes whose remaining prompt is
    shorter than W right-pad the row and report the real row count in
    ``chunk_valid`` (B,) — pad rows are causally invisible and their
    cache writes are clamped/masked (see ``attention_block``).
    ``caches`` is the lane-stacked layout from :func:`stack_lane_caches`;
    per-lane offsets mean no single batch cache layout fits, so the step
    vmaps a batch-1 suffix prefill over the lane axis.  Returns the full
    per-chunk logits (B, W, V) — the caller reads row ``chunk_valid - 1``
    of the final chunk — and the updated lane caches."""
    b = tokens.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))

    def _one(t, c, po, cv):
        logits, _, nc = model_lib.forward(
            params, cfg, t[None], cache=c, pos=po,
            license_intervals=license_intervals, attend_cache=True,
            chunk_valid=cv)
        return logits[0], nc

    if chunk_valid is None:
        return jax.vmap(lambda t, c, po: _one(t, c, po, None),
                        in_axes=(0, 0, 0))(tokens, caches, pos)
    cv = jnp.broadcast_to(jnp.asarray(chunk_valid, jnp.int32), (b,))
    return jax.vmap(_one, in_axes=(0, 0, 0, 0))(tokens, caches, pos, cv)


def serve_step(params, cfg: ModelConfig, tokens, cache, pos,
               license_intervals=None):
    """ONE decode step: tokens (B,1) + cache at fill-level ``pos``.

    With int8 ``params`` (serving/quantized.py) and ``license_intervals``,
    this is the fused masked-dequant licensed decode."""
    logits, _, cache = model_lib.forward(params, cfg, tokens, cache=cache,
                                         pos=pos, license_intervals=license_intervals)
    return logits[:, -1], cache


def serve_step_paged(params, cfg: ModelConfig, tokens, cache, tables, pos,
                     license_intervals=None, *, kernel: str = "off"):
    """ONE kernel-resident decode step over the paged pool.

    ``tokens`` (B, 1), ``cache`` the hybrid pytree from
    ``PagedCachePool.decode_cache`` (attention leaves are physical block
    arrays, per-lane state lane-gathered), ``tables`` (B, T) block tables
    trimmed to the micro-batch's used width, ``pos`` (B,) per-lane
    absolute positions.  Attention reads each cache byte once through the
    table and writes the one new K/V token through its block index — no
    contiguous view of any sequence exists (see ``models/layers.py``
    ``attention_block_paged``).  ``kernel`` selects the Pallas
    paged-attention kernel ("pallas" / "interpret") or the pure-JAX
    gather fallback ("off")."""
    logits, _, cache = model_lib.forward(
        params, cfg, tokens, cache=cache, pos=pos,
        license_intervals=license_intervals, paged_tables=tables,
        paged_kernel=kernel)
    return logits[:, -1], cache


def right_align(prompts, width: int, rows: int) -> np.ndarray:
    """(rows, width) int32 token matrix; short prompts padded on the left
    with their own first token (position-consistent, never attends ahead).
    Shared by the engine's group batching and the gateway's prompt bucket."""
    toks = np.zeros((rows, width), np.int32)
    for i, p in enumerate(prompts):
        if len(p) == 0:
            raise ValueError(f"empty prompt at row {i}")
        toks[i, width - len(p):] = p
        toks[i, : width - len(p)] = p[0]
    return toks


def sample(logits: jnp.ndarray, key, *, temperature: float = 1.0,
           top_k: int = 0) -> jnp.ndarray:
    if temperature <= 0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    logits = logits / temperature
    if top_k:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def sample_lane(logits: jnp.ndarray, key, temperature, top_k, *,
                with_rng: bool = True, with_topk: bool = True) -> jnp.ndarray:
    """One lane's sampling step with *traced* temperature/top-k.

    The gateway fuses this into its vmapped decode so each step ships one
    token id per lane device->host instead of a full logits row.  Both
    knobs are per-lane arrays under ``vmap``: greedy (argmax) where
    ``temperature <= 0``, else temperature-scaled categorical; ``top_k``
    is a traced int (0 = off) whose kth-largest threshold comes from a
    descending sort, so lanes with different k share one compilation.
    Matches :func:`sample` for any static ``top_k``.

    ``with_rng``/``with_topk`` are *static* batch-level facts ("no lane
    in this micro-batch samples / uses top-k") that let an all-greedy or
    no-top-k batch skip the categorical draw and the O(V log V) sort
    entirely — the traced per-lane knobs would otherwise keep both live
    in the hot loop for every step.
    """
    greedy = jnp.argmax(logits, -1).astype(jnp.int32)
    if not with_rng:
        return greedy
    v = logits.shape[-1]
    scaled = logits / jnp.maximum(temperature, 1e-6)
    if with_topk:
        kth = jnp.sort(scaled)[::-1][jnp.clip(top_k - 1, 0, v - 1)]
        scaled = jnp.where((top_k > 0) & (scaled < kth), -jnp.inf, scaled)
    drawn = jax.random.categorical(key, scaled[None])[0].astype(jnp.int32)
    return jnp.where(temperature <= 0, greedy, drawn)


@dataclass
class Request:
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    license: str = "full"
    temperature: float = 0.0
    out_tokens: List[int] = field(default_factory=list)


class ServingEngine:
    """Batched serving with per-tier licensed weight views.

    Weight views are materialized once per tier (masking is elementwise and
    cheap relative to serving) and cached — the paper's "unlimited licenses,
    one stored model".
    """

    def __init__(self, cfg: ModelConfig, params,
                 tiers: Optional[Dict[str, LicenseTier]] = None,
                 quantized: bool = False):
        """``quantized=True``: ONE int8 weight store serves all tiers with
        license masks fused into the in-scan dequant (beyond-paper mode;
        see serving/quantized.py).  Default is the paper's mask-at-load."""
        self.cfg = cfg
        self.quantized = quantized
        if quantized:
            from repro.serving.quantized import quantize_serving_params

            self.base_params = quantize_serving_params(params)
        else:
            self.base_params = params
        self.tiers = dict(tiers or {})
        self.tiers.setdefault("full", FULL_TIER)
        # (tier, version=None)-keyed licensed views, shared machinery with
        # the gateway (serving/gateway.py); the engine is versionless.
        self._views = TierViewCache(self._build_view, capacity=64)
        self._prefill = jax.jit(
            lambda p, t, c, li: prefill_step(p, cfg, t, c, license_intervals=li)
        )
        self._decode = jax.jit(
            lambda p, t, c, pos, li: serve_step(p, cfg, t, c, pos,
                                                license_intervals=li)
        )

    def _build_view(self, license_name: str, _version):
        """(params, intervals) licensed view — built once per tier."""
        tier = self.tiers.get(license_name)
        if tier is None:
            raise KeyError(f"unknown license tier {license_name!r}")
        if self.quantized:
            from repro.serving.quantized import tier_intervals

            return self.base_params, tier_intervals(tier)  # one store, every tier
        return apply_license(self.base_params, tier), None

    def params_for(self, license_name: str):
        return self._views.get(license_name)[0]

    def intervals_for(self, license_name: str):
        if not self.quantized:
            return None
        return self._views.get(license_name)[1]

    def gateway(self, **kw):
        """A :class:`~repro.serving.gateway.LicensedGateway` over this
        engine's weights and tiers (continuous batching front end).

        Quantization follows the engine; construct ``LicensedGateway``
        directly to choose a different weight-store mode."""
        if "quantized" in kw or "already_quantized" in kw:
            raise ValueError("gateway() mirrors the engine's quantization; "
                             "construct LicensedGateway directly to override")
        from repro.serving.gateway import LicensedGateway

        return LicensedGateway(self.cfg, self.base_params, tiers=self.tiers,
                               quantized=self.quantized,
                               already_quantized=self.quantized, **kw)

    def generate(self, requests: List[Request], *, seed: int = 0) -> List[Request]:
        """Serve a batch of same-tier requests (mixed tiers are grouped)."""
        by_tier: Dict[str, List[Request]] = {}
        for r in requests:
            by_tier.setdefault(r.license, []).append(r)
        for tier_name, group in by_tier.items():
            self._generate_group(group, tier_name, seed)
        return requests

    def _generate_group(self, group: List[Request], tier_name: str, seed: int):
        params = self.params_for(tier_name)
        li = self.intervals_for(tier_name)
        cfg = self.cfg
        b = len(group)
        max_prompt = max(len(r.prompt) for r in group)
        max_new = max(r.max_new_tokens for r in group)
        capacity = max_prompt + max_new

        toks = right_align([r.prompt for r in group], max_prompt, b)

        cache = model_lib.init_cache(cfg, b, capacity)
        logits, cache = self._prefill(params, jnp.asarray(toks), cache, li)
        key = jax.random.PRNGKey(seed)
        cur = sample(logits, key, temperature=group[0].temperature)
        for i, r in enumerate(group):
            r.out_tokens.append(int(cur[i]))
        for step in range(max_new - 1):
            key, sub = jax.random.split(key)
            logits, cache = self._decode(
                params, cur[:, None], cache, max_prompt + step, li
            )
            cur = sample(logits, sub, temperature=group[0].temperature)
            for i, r in enumerate(group):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(cur[i]))
