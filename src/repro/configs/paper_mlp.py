"""The paper's own experimental model: a small 3-layer MLP classifier
(~100k params, §3.5 / Table 1).  Used by the faithful-reproduction
benchmarks (storage cost, licensing accuracy ladder)."""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class MLPConfig:
    name: str = "paper-mlp"
    in_dim: int = 64
    hidden: Tuple[int, ...] = (256, 256)
    num_classes: int = 10

    @property
    def num_params(self) -> int:
        dims = (self.in_dim, *self.hidden, self.num_classes)
        return sum(dims[i] * dims[i + 1] + dims[i + 1] for i in range(len(dims) - 1))


# Table 1 rows, exact parameter counts.
# 109386 = the classic MNIST MLP 784-128-64-10 (inc. biases) — a unique,
# natural factorization, so we adopt it.  101770 has no 784-input
# 3-layer factorization; 256-212-212-10 matches it exactly.
TABLE1_A = MLPConfig(name="table1-a", in_dim=784, hidden=(128, 64), num_classes=10)
TABLE1_B = MLPConfig(name="table1-b", in_dim=256, hidden=(212, 212), num_classes=10)
