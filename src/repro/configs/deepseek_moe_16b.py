"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066].

28L d_model=2048 16H (kv=16, MHA) expert d_ff=1408 vocab=102400.
Standard attention (no MLA).  Uniform MoE layers (HF uses a dense first
layer; see DESIGN.md).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    arch_type="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=0,
    vocab_size=102400,
    num_experts=64,
    num_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1408,
    source="arXiv:2401.06066 (DeepSeekMoE)",
))
