"""recurrentgemma-2b — RG-LRU + local attention, 2 recurrent : 1 attention
[arXiv:2402.19427 Griffin / RecurrentGemma].

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000,
lru_width=2560, local attention window 2048.
26 = 8 full (rec,rec,attn) units + 2 tail recurrent layers.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    mlp_type="swiglu",
    layer_pattern=("rec", "rec", "attn"),
    window=2048,
    lru_width=2560,
    source="arXiv:2402.19427 (RecurrentGemma / Griffin)",
))
