"""deepseek-v2-lite-16b — MLA + fine-grained MoE [arXiv:2405.04434].

27L d_model=2048 16H, MLA kv_lora=512 (qk_nope 128, rope 64, v 128),
MoE: 64 routed experts top-6 + 2 shared, expert d_ff=1408, vocab 102400.
(The assignment bracket mentions 160 routed — that is full V2; the Lite
spec line "MoE 64e top-6" is what we implement, per the primary spec.)
Uniform MoE across layers (the HF model uses a dense first layer; uniform
keeps the layer stack scannable — noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-lite-16b",
    arch_type="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=0,           # MLA defines its own per-head dims
    d_ff=0,
    vocab_size=102400,
    use_mla=True,
    kv_lora_rank=512,
    qk_nope_dim=128,
    rope_head_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1408,
    source="arXiv:2405.04434 (DeepSeek-V2 / V2-Lite)",
))
