"""minitron-8b — pruned Nemotron-4 [arXiv:2407.14679].

32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000, squared-ReLU
(inherits the Nemotron family MLP).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="minitron-8b",
    arch_type="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=256000,
    mlp_type="squared_relu",
    source="arXiv:2407.14679 (Minitron)",
))
