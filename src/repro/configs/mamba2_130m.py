"""mamba2-130m — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768, attention-free, ssm_state=128, vocab 50280.
d_inner = 2*768 = 1536, 24 SSD heads of dim 64.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m",
    arch_type="ssm",
    num_layers=24,
    d_model=768,
    vocab_size=50280,
    layer_pattern=("ssm",),
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_chunk=128,
    source="arXiv:2405.21060 (Mamba-2 / SSD)",
))
