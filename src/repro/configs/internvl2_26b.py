"""internvl2-26b — VLM: InternViT (stub) + InternLM2-20B backbone
[arXiv:2404.16821].

LM backbone: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.
The InternViT encoder + MLP projector are the assignment's frontend stub:
``input_specs`` supplies 256 precomputed patch embeddings per image, which
the model projects and prepends to the text tokens.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-26b",
    arch_type="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    mlp_type="swiglu",
    frontend="vision",
    num_patches=256,
    source="arXiv:2404.16821 (InternVL 1.5/2 family)",
))
