"""musicgen-large — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284].

48L d_model=2048 32H (kv=32, MHA) d_ff=8192 vocab=2048 (codec codebook).
LayerNorm (GPT-style), learned-positional in the original; we use RoPE as
the positional scheme for the backbone (noted in DESIGN.md) and omit the
text-conditioning cross-attention (frontend stub per assignment carve-out).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-large",
    arch_type="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_type="swiglu",
    norm_layernorm=True,
    frontend="audio",
    source="arXiv:2306.05284 (MusicGen)",
))
