"""granite-34b — llama-arch code model, deep + MQA [arXiv:2405.04324].

88L d_model=6144 48H (MQA kv=1) d_ff=24576 vocab=49152.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-34b",
    arch_type="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="swiglu",
    source="arXiv:2405.04324 (Granite Code Models)",
))
