"""Config system: one frozen dataclass describes every supported arch.

``layer_pattern`` drives the block mix: ("attn",) pure transformer,
("ssm",) pure Mamba-2, ("rec","rec","attn") RecurrentGemma's 2:1 hybrid.
Registry maps --arch ids to configs; every entry cites its source.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                       # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    vocab_size: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    mlp_type: str = "swiglu"             # swiglu | squared_relu
    attn_bias: bool = False
    norm_layernorm: bool = False         # True: LayerNorm (musicgen); else RMS
    rope_theta: float = 10000.0
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0                      # sliding/local attention window (0=full)
    # MoE
    num_experts: int = 0
    num_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_renormalize: bool = True
    moe_aux_weight: float = 0.01
    moe_capacity_factor: float = 1.25
    # MLA (DeepSeek-V2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    rope_head_dim: int = 64
    v_head_dim: int = 128
    # SSM / recurrent
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    lru_width: int = 0
    # modality frontend (stub — embeddings arrive precomputed)
    frontend: str = "none"               # none | audio | vision
    num_patches: int = 256               # vision prefix length
    # numerics / engineering
    dtype_name: str = "bfloat16"
    q_chunk: int = 512
    remat: bool = True
    # distribution (beyond-paper §Perf knobs)
    seq_sharded_acts: bool = False   # Megatron-SP: residual stream seq-shards
                                     # over "model" between blocks
    fsdp: bool = False               # params/grads also shard over "data"
    pin_acts: bool = False           # pin residual stream batch-DP at entry
                                     # and unit boundaries (trades HBM
                                     # footprint for fewer collectives)
    norm_bf16_apply: bool = False    # rms_norm: stats in f32, apply in bf16
                                     # (halves backward all-reduce bytes)
    kv_cache_int8: bool = False      # int8 KV cache with per-token-per-head
                                     # scales (halves decode cache traffic)
    # citation
    source: str = ""

    @property
    def dtype(self):
        return jnp.dtype(self.dtype_name)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so embed/lm_head/logits
        shard over any model axis (Megatron-style vocab padding).  Logits
        at padded ids are masked to -1e9 in ``forward``."""
        return -(-self.vocab_size // 256) * 256

    @property
    def pattern_units(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def tail_pattern(self) -> Tuple[str, ...]:
        return self.layer_pattern[: self.num_layers % len(self.layer_pattern)]

    def block_kind(self, layer_idx: int) -> str:
        return self.layer_pattern[layer_idx % len(self.layer_pattern)]

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---------------- parameter counting (roofline MODEL_FLOPS) ----------
    def param_counts(self) -> Dict[str, float]:
        d, v = self.d_model, self.vocab_size
        per_layer_attn = per_layer_mlp = per_layer_moe_active = per_layer_moe_total = 0.0
        per_layer_ssm = per_layer_rec = 0.0
        if "attn" in self.layer_pattern:
            if self.use_mla:
                h = self.num_heads
                per_layer_attn = (
                    d * h * (self.qk_nope_dim + self.rope_head_dim)
                    + d * (self.kv_lora_rank + self.rope_head_dim)
                    + self.kv_lora_rank * h * (self.qk_nope_dim + self.v_head_dim)
                    + h * self.v_head_dim * d
                )
            else:
                per_layer_attn = d * self.head_dim * (
                    self.num_heads * 2 + self.num_kv_heads * 2
                )
        if self.num_experts:
            per_expert = 3 * d * self.moe_d_ff
            per_layer_moe_total = self.num_experts * per_expert + d * self.num_experts
            per_layer_moe_active = self.experts_per_token * per_expert + d * self.num_experts
            shared = self.num_shared_experts * 3 * d * self.moe_d_ff
            per_layer_moe_total += shared
            per_layer_moe_active += shared
        elif self.d_ff:
            mult = 3 if self.mlp_type == "swiglu" else 2
            per_layer_mlp = mult * d * self.d_ff
        if "ssm" in self.layer_pattern:
            di = self.ssm_expand * d
            per_layer_ssm = d * (2 * di + 2 * self.ssm_state + di // self.ssm_head_dim) + di * d
        if "rec" in self.layer_pattern:
            w = self.lru_width
            per_layer_rec = d * w * 2 + 2 * w * w + w * d

        total = active = 2 * v * d  # embed + head
        for i in range(self.num_layers):
            kind = self.block_kind(i)
            if kind == "attn":
                body = per_layer_attn + (per_layer_moe_total or per_layer_mlp)
                act = per_layer_attn + (per_layer_moe_active or per_layer_mlp)
            elif kind == "ssm":
                body = act = per_layer_ssm
            else:  # rec
                body = per_layer_rec + per_layer_mlp
                act = body
            total += body
            active += act
        return {"total": float(total), "active": float(active)}


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # import the configs package so registration side effects run
    import repro.configs  # noqa: F401

    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> Tuple[str, ...]:
    import repro.configs  # noqa: F401

    return tuple(sorted(_REGISTRY))


def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers (pattern-preserving), small dims."""
    pattern = cfg.layer_pattern
    n_layers = max(2, len(pattern))
    d = min(cfg.d_model, 256)
    kw: Dict[str, Any] = dict(
        num_layers=n_layers,
        d_model=d,
        vocab_size=min(cfg.vocab_size, 512),
        dtype_name="float32",
        remat=False,
        q_chunk=64,
        ssm_chunk=16,
    )
    if cfg.num_heads:
        heads = min(cfg.num_heads, 4)
        kv = max(1, min(cfg.num_kv_heads, heads))
        kw.update(num_heads=heads, num_kv_heads=kv, head_dim=d // heads)
    if cfg.d_ff:
        kw.update(d_ff=min(cfg.d_ff, 4 * d))
    if cfg.num_experts:
        kw.update(num_experts=4, experts_per_token=2,
                  num_shared_experts=min(cfg.num_shared_experts, 1),
                  moe_d_ff=min(cfg.moe_d_ff, d),
                  moe_capacity_factor=4.0)  # drop-free at smoke scale
    if cfg.use_mla:
        kw.update(kv_lora_rank=64, qk_nope_dim=32, rope_head_dim=16, v_head_dim=32,
                  head_dim=0)
        kw["num_heads"] = 4
        kw["num_kv_heads"] = 4
    if cfg.ssm_state:
        kw.update(ssm_state=min(cfg.ssm_state, 32), ssm_head_dim=32)
    if cfg.lru_width:
        kw.update(lru_width=d)
    if cfg.window:
        kw.update(window=min(cfg.window, 32))
    if cfg.frontend == "vision":
        kw.update(num_patches=8)
    return cfg.replace(name=cfg.name + "-smoke", **kw)
