"""Architecture registry — importing this package registers every config.

Assigned pool (10 archs spanning 6 types), each citing its source, plus
the paper's own MLP (paper_mlp).  Select with ``--arch <name>``.
"""
from repro.configs.base import ModelConfig, get_config, list_configs, register, smoke_variant

# registration side effects
from repro.configs import (  # noqa: F401
    deepseek_moe_16b,
    deepseek_v2_lite_16b,
    granite_34b,
    internvl2_26b,
    mamba2_130m,
    minitron_8b,
    musicgen_large,
    nemotron_4_15b,
    qwen2_5_3b,
    recurrentgemma_2b,
)

ASSIGNED_ARCHS = (
    "mamba2-130m",
    "qwen2.5-3b",
    "musicgen-large",
    "recurrentgemma-2b",
    "deepseek-v2-lite-16b",
    "nemotron-4-15b",
    "internvl2-26b",
    "minitron-8b",
    "deepseek-moe-16b",
    "granite-34b",
)

__all__ = ["ModelConfig", "get_config", "list_configs", "register",
           "smoke_variant", "ASSIGNED_ARCHS"]
