"""Fault-tolerant cloud sync: a staged weight update over a hostile
wire, plus license-lease degraded serving (ISSUE 9 / ARCHITECTURE.md §6).

Walks the two failure domains end to end:

1. boot a licensed gateway against an in-memory LicenseServer and put
   requests in flight;
2. publish v2 and carry it in with a *staged* sync routed through a
   ``ChaosTransport`` — 30% of wire calls time out, disconnect
   mid-stream, or corrupt a page, and deliveries may duplicate.  The
   retry policy and chunk-granular cursor resume absorb every fault;
   decode never stops, the flip lands exactly once, and the in-flight
   requests finish pinned to v1 with the same tokens a clean wire
   would have produced;
3. freeze time and take the server away: watch the license lease walk
   HEALTHY → DEGRADED (granted tiers keep serving, new grants are
   refused) → OFFLINE (admissions rejected) → restored by the
   self-heal probe once the server returns.

Run:  PYTHONPATH=src python examples/chaos_sync.py
"""
import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.core.protocol import LicenseServer
from repro.core.transport import (ChaosTransport, DirectTransport,
                                  RetryPolicy, TransportTimeout)
from repro.core.weightstore import WeightStore
from repro.models import init_params
from repro.serving import LicensedGateway, RequestState


class FlakyTransport(DirectTransport):
    """Direct delivery with a kill switch — the 'server unreachable'
    condition for the lease demo."""

    def __init__(self, server):
        super().__init__(server)
        self.down = False

    def _call(self, op, thunk):
        if self.down:
            raise TransportTimeout(f"{op}: server unreachable")
        return super()._call(op, thunk)


def _server(params):
    store = WeightStore(":memory:", row_limit=2048)
    server = LicenseServer(store)
    server.publish("lm", params, tag="v1")
    server.publish_tier("lm", LicenseTier(name="free",
                                          masks={"*": ((0.0, 0.004),)}))
    return server


def _boot(cfg, server, params, **kw):
    template = jax.tree_util.tree_map(lambda x: np.zeros_like(x), params)
    return LicensedGateway.from_server(cfg, server, "lm", template,
                                       max_batch=2, max_prompt=8,
                                       max_new_cap=16, **kw)


def _prompt(seed):
    return np.random.default_rng(seed).integers(0, 500, 8, dtype=np.int32)


def main():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = jax.device_get(init_params(jax.random.PRNGKey(0), cfg))

    # ---- 1. staged sync through a 30%-fault wire --------------------------
    server = _server(params)
    gw = _boot(cfg, server, params)
    a = gw.submit(_prompt(1), license="free", max_new_tokens=12)
    b = gw.submit(_prompt(2), license="free", max_new_tokens=12)
    gw.step()                                 # a, b mid-decode
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")

    chaos = ChaosTransport(server, seed=7, fault_rate=0.3, dup_rate=0.15,
                           sleep=lambda _s: None)
    retry = RetryPolicy(max_attempts=10, base_delay_s=0.0, jitter=0.0,
                        sleep=lambda _s: None)
    assert gw.begin_sync(transport=chaos, retry=retry,
                         max_step_bytes=24 << 10)
    while gw.sync_active or gw.scheduler.waiting or gw.scheduler.running:
        gw.step()                             # decode interleaves the sync
    assert a.state == b.state == RequestState.DONE

    st = gw.metrics()["staged_update"]
    wire = st["wire"]
    print(f"sync landed at v{gw.version} through "
          f"{wire['faults']}/{wire['calls']} faulted wire calls "
          f"(timeouts={wire['timeouts']} disconnects={wire['disconnects']} "
          f"corruptions={wire['corruptions']} dups={wire['duplicates']})")
    print(f"  retries={st['retries']} cursor-resumes={st['resumes']} "
          f"flips={st['flips']} (audit: "
          f"{len(gw.audit.events('version_flip'))} version_flip, "
          f"{len(gw.audit.events('sync_retry'))} sync_retry)")
    print(f"  in-flight requests finished pinned to v{a.version} — "
          f"faults changed counters, never tokens")

    # ---- 2. license-lease degraded serving --------------------------------
    now = [0.0]
    server2 = _server(params)
    tr = FlakyTransport(server2)
    gw2 = _boot(cfg, server2, params, transport=tr, clock=lambda: now[0],
                lease_ttl_s=10.0, lease_grace_s=20.0,
                retry_policy=RetryPolicy(max_attempts=2, base_delay_s=0.0,
                                         sleep=lambda _s: None))
    warm = gw2.submit(_prompt(0), license="free", max_new_tokens=2)
    gw2.run()
    assert warm.state == RequestState.DONE

    tr.down = True                            # server goes dark
    now[0] = 11.0                             # past the TTL
    gw2.step()
    ok = gw2.submit(_prompt(3), license="free", max_new_tokens=2)
    gw2.run()
    server2.publish_tier("lm", LicenseTier(name="pro",
                                           masks={"*": ((0.0, 0.002),)}))
    rej = gw2.submit(_prompt(4), license="pro", max_new_tokens=2)
    print(f"\nlease @t=11s: {gw2.metrics()['lease']['state']} — "
          f"granted tier served ({ok.state.name}), "
          f"new tier grant refused ({rej.state.name})")

    now[0] = 35.0                             # past TTL + grace
    gw2.step()
    rej2 = gw2.submit(_prompt(5), license="free", max_new_tokens=2)
    print(f"lease @t=35s: {gw2.metrics()['lease']['state']} — "
          f"admission {rej2.state.name}: {rej2.error}")

    tr.down = False                           # server returns
    now[0] = 37.0
    gw2.step()                                # self-heal probe fires
    lease = gw2.metrics()["lease"]
    back = gw2.submit(_prompt(6), license="pro", max_new_tokens=2)
    gw2.run()
    print(f"lease @t=37s: {lease['state']} after "
          f"{lease['degraded_seconds_total']:.0f}s degraded — deferred "
          f"'pro' grant now serves ({back.state.name})")


if __name__ == "__main__":
    main()
