"""Quickstart: the paper's full lifecycle in one script.

1. train the paper's 3-layer MLP to ~98% accuracy;
2. compress it (prune 80% -> fine-tune -> quantize, Fig. 3);
3. publish to the versioned WeightStore (Fig. 4 schema);
4. calibrate a free tier with Algorithm 1 and register it;
5. two edge clients (full / free license) pull the model — the free one
   receives interval-masked weights and lower accuracy;
6. push a small server-side update; clients low-latency-delta-sync (§4.3).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs.paper_mlp import TABLE1_A
from repro.core import compress_pipeline
from repro.core.licensing import calibrate_license
from repro.core.protocol import EdgeClient, LicenseServer
from repro.core.weightstore import WeightStore
from repro.data import classification_data
from repro.training import finetune_pruned_mlp, mlp_accuracy, train_mlp


def main():
    # 1. train ----------------------------------------------------------------
    x, y = classification_data(8000, TABLE1_A.in_dim, TABLE1_A.num_classes, seed=0)
    xtr, ytr, xte, yte = x[:6000], y[:6000], x[6000:], y[6000:]
    params = train_mlp(TABLE1_A, xtr, ytr, steps=600)
    acc0 = mlp_accuracy(params, xte, yte)
    print(f"[1] trained paper MLP ({TABLE1_A.num_params} params): acc={acc0:.3f}")

    # 2. compress (Fig. 3) ----------------------------------------------------
    pruned, quant, stats = compress_pipeline(params, sparsity=0.8)
    pruned = finetune_pruned_mlp(TABLE1_A, pruned, xtr, ytr, steps=200)
    acc1 = mlp_accuracy(pruned, xte, yte)
    print(f"[2] pruned 80% + fine-tuned: acc={acc1:.3f}  "
          f"storage {stats.full_bytes / 1e6:.2f}MB -> {stats.quantized_bytes / 1e6:.2f}MB")

    # 3. publish --------------------------------------------------------------
    store = WeightStore(":memory:")
    store.register_model("prod-mlp", "paper-mlp")
    server = LicenseServer(store)
    v1 = server.publish("prod-mlp", jax.device_get(pruned), tag="v1.0")
    print(f"[3] published version {v1}; DB rows: "
          f"{store.storage_bytes('prod-mlp')['weight_rows']}")

    # 4. calibrate the free tier (Algorithm 1, dynamic licensing) -------------
    tier, trace = calibrate_license(
        pruned, lambda p: mlp_accuracy(p, xte, yte), target_accuracy=0.70,
        k_intervals=12, tier_name="free",
    )
    server.publish_tier("prod-mlp", tier)
    print(f"[4] calibrated tier 'free': accuracy {tier.accuracy:.3f} "
          f"after {len(trace)} Algorithm-1 evaluations")

    # 5. licensed clients pull ------------------------------------------------
    from repro.core import flatten_params

    zeros = {k: np.zeros_like(v) for k, v in
             flatten_params(jax.device_get(pruned)).items()}
    paid = EdgeClient("prod-mlp", dict(zeros), license_name="full")
    free = EdgeClient("prod-mlp", dict(zeros), license_name="free")
    paid.request_update(server)
    free.request_update(server)
    from repro.core import unflatten_like

    acc_paid = mlp_accuracy(unflatten_like(pruned, paid.params), xte, yte)
    acc_free = mlp_accuracy(unflatten_like(pruned, free.params), xte, yte)
    print(f"[5] paid client acc={acc_paid:.3f}, free client acc={acc_free:.3f} "
          f"(one stored model, two licenses)")

    # 6. low-latency update ---------------------------------------------------
    newp = {k: np.array(v, copy=True) for k, v in
            flatten_params(jax.device_get(pruned)).items()}
    flat = newp["layer3/kernel"].reshape(-1)
    flat[:25] += 0.01
    server.publish("prod-mlp", newp, tag="v1.1")
    packet = paid.request_update(server)
    print(f"[6] delta update: {packet.num_entries} weights, {packet.nbytes}B "
          f"(vs {paid.bytes_downloaded - packet.nbytes}B initial download)")
    store.close()


if __name__ == "__main__":
    main()
