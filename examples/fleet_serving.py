"""Multi-model, multi-tenant fleet serving behind one gateway loop.

The consolidation deployment the paper's licensing model points at:
several licensed model products served from ONE edge binary, each
tenant's contract encoded as (model, tier) entitlements plus quotas —
not one process per model.

1. build three heterogeneous smoke models (GQA transformer, pure-SSM,
   sliding-window hybrid) and register them as fleet slots under one
   global cache-byte budget;
2. register two tenants: "acme" (entitled to two models, rate-limited)
   and "hobby" (free tier of one model, concurrency-capped at 1);
3. stream mixed requests — the fleet round-robins (model, tier,
   version)-homogeneous micro-batches across slots, debiting one shared
   byte budget, while quota rejections come back instantly at submit;
4. revoke "acme"'s entitlement mid-flight: the decoding request drains
   to completion, the queued one is rejected at batch formation;
5. print the three-section metrics: fleet totals, per-model, per-tenant.

Run:  PYTHONPATH=src python examples/fleet_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_params
from repro.serving import FleetGateway, TenantRegistry

MODELS = ("qwen2.5-3b", "mamba2-130m", "recurrentgemma-2b")
TIERS = {"free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})}


def main():
    rng = np.random.default_rng(0)

    # 1. three heterogeneous slots under one budget --------------------------
    tenants = TenantRegistry()
    fleet = FleetGateway(cache_budget_bytes=1 << 20, tenants=tenants)
    for i, name in enumerate(MODELS):
        cfg = smoke_variant(get_config(name))
        params = init_params(jax.random.PRNGKey(i), cfg)
        # the qwen slot gets ONE lane so step [4] below can show a
        # request still queued when its entitlement is revoked
        fleet.add_model(name, cfg, params, tiers=dict(TIERS),
                        max_batch=1 if name == "qwen2.5-3b" else 2,
                        max_prompt=8, max_new_cap=8)
    paged = [n for n, g in fleet.gateways.items() if g.paged]
    print(f"[1] fleet online: {len(fleet.gateways)} models "
          f"({', '.join(MODELS)}); {len(paged)} paged slots share a "
          f"{fleet.cache_budget_bytes >> 10} KiB cache budget "
          f"(the pure-SSM slot's constant-size lane state sits outside it)")

    # 2. tenant contracts ----------------------------------------------------
    tenants.register("acme",
                     entitlements=("qwen2.5-3b:*", "recurrentgemma-2b:*"),
                     rate=50.0, burst=8)
    tenants.register("hobby", entitlements=("mamba2-130m:free",),
                     max_concurrent=1)
    print("[2] tenants: acme (2 models, 50 req/s, burst 8) | "
          "hobby (mamba2 free tier, 1 concurrent)")

    # 3. mixed stream: routing, quotas, shared budget ------------------------
    def prompt():
        return rng.integers(0, 500, 8, dtype=np.int32)

    reqs = [
        fleet.submit("qwen2.5-3b", prompt(), tenant="acme",
                     license="full", max_new_tokens=6),
        fleet.submit("recurrentgemma-2b", prompt(), tenant="acme",
                     license="free", max_new_tokens=4),
        fleet.submit("mamba2-130m", prompt(), tenant="hobby",
                     license="free", max_new_tokens=6),
        # hobby is at its concurrency cap -> instant rejection
        fleet.submit("mamba2-130m", prompt(), tenant="hobby",
                     license="free", max_new_tokens=4),
        # hobby holds no qwen entitlement -> instant rejection
        fleet.submit("qwen2.5-3b", prompt(), tenant="hobby",
                     license="free", max_new_tokens=4),
    ]
    t0 = time.perf_counter()
    done = fleet.run()
    dt = time.perf_counter() - t0
    print(f"[3] drained {len(done)} requests in {dt:.2f}s; rejected at "
          f"submit: {[r.error for r in reqs if r.error][:2]}")

    # 4. mid-flight revocation: drain, never cancel --------------------------
    r_live = fleet.submit("qwen2.5-3b", prompt(), tenant="acme",
                          license="full", max_new_tokens=8)
    r_queued = fleet.submit("qwen2.5-3b", prompt(), tenant="acme",
                            license="full", max_new_tokens=8)
    while r_live.state.value != "running":      # step until r_live decodes
        fleet.step()
    tenants.revoke("acme", "qwen2.5-3b", "full")
    fleet.run()
    print(f"[4] revoked acme's (qwen2.5-3b, full) mid-flight: decoding "
          f"request {r_live.state.value} with {len(r_live.out_tokens)} "
          f"tokens, queued request {r_queued.state.value} "
          f"({r_queued.error})")

    # 5. three-section metrics ----------------------------------------------
    m = fleet.metrics()
    f = m["fleet"]
    print(f"[5] fleet: {f['completed']} completed / "
          f"{f['quota_rejections']} quota-rejected across {f['models']} "
          f"models in {f['steps']} steps; cache "
          f"{f['cache_used_bytes']}/{f['cache_budget_bytes']} bytes used")
    for name, mm in m["models"].items():
        # 'held' is block-pool geometry: the contiguous-fallback (SSM)
        # slot's cache_pool has lane occupancy instead
        print(f"    {name:18s} {mm['tokens_generated']:3d} tokens, "
              f"{mm['completed']} done, blocks held: "
              f"{mm['cache_pool'].get('held', 0)}")
    for name, t in m["tenants"].items():
        print(f"    tenant {name:6s} {t['admitted']}/{t['submitted']} "
              f"admitted, {t['completed']} done, "
              f"{t['quota_rejections']} quota-rejected, "
              f"entitlements {t['entitlements']}")


if __name__ == "__main__":
    main()
