"""Serving-wide observability: traces, histograms, Prometheus, audit.

Everything the serving stack does — admission, prefix hits, prefill
chunks, decode steps, preemptions, staged version flips, tenant quota
verdicts — lands on one always-on observability layer
(``serving/telemetry.py`` + ``serving/tracing.py``).  This example
drives a two-model, two-tenant fleet through a mid-run licensed weight
update and then dumps all three export surfaces:

1. boot a fleet: one slot synced from a ``LicenseServer`` (so versions
   can bump mid-run), one plain slot; register tenants "acme" and
   "hobby" (hobby concurrency-capped so a quota rejection shows up);
2. stream requests through both slots, then publish v2 on the license
   server and let a *staged* sync flip it in while decodes continue;
3. print one request's lifecycle span story off the trace tape;
4. dump the Prometheus text exposition (per-model labels, histogram
   buckets), the licensing audit JSONL (grants, materializations,
   sync begin/flip, quota rejections), and a whole-fleet Chrome trace
   (load ``obs_trace.json`` in Perfetto / chrome://tracing).

Run:  PYTHONPATH=src python examples/observability.py
"""
import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.core.protocol import LicenseServer
from repro.core.weightstore import WeightStore
from repro.models import init_params
from repro.serving import (FleetGateway, LicensedGateway, TenantRegistry,
                           validate_chrome_trace)

SYNCED, PLAIN = "qwen2.5-3b", "mamba2-130m"


def main():
    rng = np.random.default_rng(0)

    def prompt():
        return rng.integers(0, 500, 8, dtype=np.int32)

    # 1. fleet: a license-server-synced slot + a plain slot, two tenants
    cfg = smoke_variant(get_config(SYNCED))
    params = jax.device_get(init_params(jax.random.PRNGKey(0), cfg))
    server = LicenseServer(WeightStore(":memory:", row_limit=2048))
    server.publish(SYNCED, params, tag="v1")
    server.publish_tier(SYNCED, LicenseTier(name="free",
                                            masks={"*": ((0.0, 0.004),)}))
    template = jax.tree_util.tree_map(lambda x: np.zeros_like(x), params)
    gw = LicensedGateway.from_server(cfg, server, SYNCED, template,
                                     max_batch=2, max_prompt=8,
                                     max_new_cap=8)

    tenants = TenantRegistry()
    fleet = FleetGateway(tenants=tenants)
    fleet.attach(gw)                  # adopts the slot's telemetry too
    cfg2 = smoke_variant(get_config(PLAIN))
    fleet.add_model(PLAIN, cfg2, init_params(jax.random.PRNGKey(1), cfg2),
                    tiers={"free": LicenseTier(name="free",
                                               masks={"*": ((0.0, 0.004),)})},
                    max_batch=2, max_prompt=8, max_new_cap=8)
    tenants.register("acme", entitlements=(f"{SYNCED}:*", f"{PLAIN}:*"))
    tenants.register("hobby", entitlements=(f"{PLAIN}:free",),
                     max_concurrent=1)
    print(f"[1] fleet online: {SYNCED} (license-server v1) + {PLAIN}; "
          f"tenants acme (both models) / hobby ({PLAIN} free, 1 at a time)")

    # 2. traffic + a mid-run version bump through the staged sync -----------
    reqs = [fleet.submit(SYNCED, prompt(), tenant="acme", license="free",
                         max_new_tokens=6),
            fleet.submit(PLAIN, prompt(), tenant="hobby", license="free",
                         max_new_tokens=4),
            fleet.submit(PLAIN, prompt(), tenant="hobby", license="free",
                         max_new_tokens=4),       # over hobby's cap
            fleet.submit(SYNCED, prompt(), tenant="acme", license="full",
                         max_new_tokens=6)]
    fleet.step()                                  # first prefill lands
    server.publish(SYNCED, jax.tree_util.tree_map(
        lambda x: np.asarray(x) * 1.01, params), tag="v2")
    gw.begin_sync(max_step_bytes=4 << 20)         # staged, non-blocking
    fleet.run()                                   # decodes + flip interleave
    done = sum(r.state.value == "done" for r in reqs)
    print(f"[2] drained {done} requests across a staged v1->v2 flip "
          f"(slot now at version {gw.version}); hobby's second request: "
          f"{reqs[2].error!r}")

    # 3. one request's lifecycle story off the trace tape -------------------
    story = fleet.gateways[SYNCED].tracer.span_names(reqs[0].rid)
    print(f"[3] request {reqs[0].rid} lifecycle: {' -> '.join(story)}")

    # 4. the three export surfaces ------------------------------------------
    m = fleet.metrics()
    lat = m["models"][SYNCED]["latency"]
    print(f"[4] {SYNCED} ttft p50/p99: {lat['ttft_s']['p50'] * 1e3:.1f}/"
          f"{lat['ttft_s']['p99'] * 1e3:.1f} ms over "
          f"{lat['ttft_s']['count']} requests")

    prom = fleet.render_prometheus()
    wanted = ("serving_ttft_seconds_bucket", "serving_weight_version",
              "tenant_quota_rejections_total")
    shown = [ln for ln in prom.splitlines()
             if ln.startswith(wanted)][:8]
    print("    Prometheus excerpt:")
    for ln in shown:
        print(f"      {ln}")

    print("    audit stream:")
    for ev in fleet.audit_events():
        keys = {k: v for k, v in ev.items() if k not in ("ts", "seq")}
        print(f"      {keys}")

    trace = fleet.chrome_trace()
    events = validate_chrome_trace(trace)         # parseable + matched B/E
    with open("obs_trace.json", "w") as f:
        f.write(trace)
    print(f"    Chrome trace: {len(events)} events -> obs_trace.json "
          f"(open in Perfetto / chrome://tracing)")


if __name__ == "__main__":
    main()
