"""Continuous-batching licensed gateway, end to end against a LicenseServer.

The Fig. 2 deployment with the gateway as the serving pod:

1. publish a smoke-scale LM to the versioned WeightStore and register
   two license tiers in the accuracy table;
2. boot a ``LicensedGateway`` from the server (full snapshot over the
   §3.1.2 delta protocol);
3. stream mixed-tier requests with heterogeneous decode lengths — the
   scheduler forms tier-homogeneous micro-batches over the shared
   **block-paged** cache pool (oversubscribed here: 8 lanes of up to 7
   blocks each on a 36-block pool, so admission is bounded by blocks
   and the youngest request is preempted/requeued if decode growth
   exhausts them), and masked weight views are built once per
   (tier, version);
4. serve a shared-system-prompt round through the **prefix cache**: the
   first wave donates its prompt-block chains to the (tier, version)
   radix tree, follow-up waves adopt the shared prefix by reference and
   prefill only their user-specific suffix — same tokens, a fraction of
   the prefill compute, with decode copy-on-writing the shared tail
   block before its first write;
5. publish a server-side weight update mid-service and ``sync()``: new
   admissions pin the new version, stale views (and cached prefix
   scopes) are invalidated once the old version drains.

Run:  PYTHONPATH=src python examples/gateway_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.core.protocol import LicenseServer
from repro.core.weightstore import WeightStore
from repro.models import init_params
from repro.serving import LicensedGateway


def main():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = jax.device_get(init_params(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(0)

    # 1. cloud side: versioned store + tier ladder ---------------------------
    store = WeightStore(":memory:", row_limit=2048)   # chunk mode for LM layers
    server = LicenseServer(store)
    server.publish("lm", params, tag="v1.0")
    for name, hi in (("pro", 0.002), ("free", 0.004)):
        server.publish_tier("lm", LicenseTier(name=name,
                                              masks={"*": ((0.0, hi),)}))
    print(f"[1] published 'lm' v{store.production_version('lm')} "
          f"with tiers {[t for t, _ in store.list_tiers('lm')]}")

    # 2. serving pod: gateway boots from the server --------------------------
    template = jax.tree_util.tree_map(np.zeros_like, params)
    # max_prompt=10 is deliberately not block-aligned: shared prompt
    # chains end in a partial tail block, so the prefix demo below also
    # exercises decode's copy-on-write
    gw = LicensedGateway.from_server(cfg, server, "lm", template,
                                     max_batch=4, max_prompt=10,
                                     max_new_cap=16, block_size=4,
                                     max_lanes=8, num_blocks=36,
                                     watermark_blocks=1)
    pool = gw.pool.stats()
    print(f"[2] gateway online at weight version {gw.version}; paged pool: "
          f"{pool['num_blocks']} blocks x {pool['block_size']} tokens for "
          f"{pool['num_lanes']} lanes (vmap width {gw.max_batch})")

    # 3. mixed-tier request stream ------------------------------------------
    reqs = [gw.submit(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
                      license=lic, max_new_tokens=n)
            for lic, n in (("full", 8), ("free", 4), ("pro", 12), ("free", 8),
                           ("full", 4), ("pro", 6), ("free", 12), ("full", 6))]
    t0 = time.perf_counter()
    gw.run()
    dt = time.perf_counter() - t0
    m = gw.metrics()
    print(f"[3] served {m['completed']} mixed-tier requests "
          f"({m['tokens_generated']} tokens) in {dt:.2f}s — "
          f"{m['decode_steps']} decode steps, {m['prefill_batches']} prefills; "
          f"view cache {m['view_cache']['hits']} hits / "
          f"{m['view_cache']['misses']} misses; "
          f"peak {m['max_running']} concurrent on "
          f"{m['max_blocks_in_use']} blocks, {m['preempted']} preempted")
    for r in reqs[:3]:
        print(f"    [{r.license:4s} v{r.version}] {r.out_tokens}")

    # 4. shared-system-prompt round: prefix-cache reuse ----------------------
    system = rng.integers(0, cfg.vocab_size, 6, dtype=np.int32)
    convo = None
    lane0 = gw.stats["prefill_lane_tokens"]
    n = 0
    for wave in range(3):          # wave 0 populates, waves 1-2 hit
        for _ in range(3):
            user = rng.integers(0, cfg.vocab_size, 4, dtype=np.int32)
            prompt = np.concatenate([system, user])
            convo = prompt if convo is None else convo
            gw.submit(prompt, license="free", max_new_tokens=4)
            n += 1
        if wave:                   # re-served conversation: full-prompt match
            gw.submit(convo.copy(), license="free", max_new_tokens=4)
            n += 1
        gw.run()
    pm = gw.metrics()["prefix_cache"]
    print(f"[4] shared-system-prompt round: {pm['hit_rate']:.0%} hit rate, "
          f"{pm['prefix_tokens_reused']} prompt tokens served from cache "
          f"({gw.stats['prefill_lane_tokens'] - lane0} prefilled vs "
          f"{n * gw.max_prompt} cold), {pm['retained_blocks']} blocks "
          f"retained for future hits, {pm['cow_copies']} copy-on-writes")

    # 5. staged weight update mid-service -----------------------------------
    # publish v1.1 while requests decode: begin_sync() stages the delta in
    # bounded steps riding along with the scheduler; the in-flight request
    # stays pinned to the old version across the atomic flip
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v1.1")
    old = gw.submit(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
                    license="free", max_new_tokens=8)
    gw.step()                                  # old is in flight
    gw.begin_sync(max_step_bytes=2 << 20)      # pace the flip to land
                                               # while old still decodes
    gw.run()                                   # decode + staging interleave
    r = gw.submit(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32),
                  license="free", max_new_tokens=4)
    gw.run()
    st = gw.metrics()["staged_update"]
    print(f"[5] staged sync to v{gw.version} in {st['steps']} bounded steps "
          f"({st['bytes_applied']} delta bytes, {st['views_prewarmed']} view "
          f"prewarmed); in-flight request stayed pinned to v{old.version}, "
          f"new request pinned to v{r.version}, "
          f"prefix scopes live: {gw.prefix.stats()['scopes']}")
    store.close()


if __name__ == "__main__":
    main()
