"""Edge-fleet synchronization: many devices, mixed licenses, shard-aware
delta distribution (beyond paper — DESIGN.md §2).

Simulates a fleet of edge clients on different versions pulling from one
LicenseServer, then a *sharded* consumer (a 4-host serving pod) where each
host fetches only its shard's slice of the delta.

Run:  PYTHONPATH=src python examples/edge_fleet_sync.py
"""
import jax
import numpy as np

from repro.configs.paper_mlp import TABLE1_A
from repro.core import flatten_params, shard_delta, unflatten_like
from repro.core.licensing import LicenseTier
from repro.core.protocol import EdgeClient, LicenseServer
from repro.core.weightstore import WeightStore
from repro.data import classification_data
from repro.training import mlp_accuracy, train_mlp


def main():
    x, y = classification_data(4000, TABLE1_A.in_dim, TABLE1_A.num_classes, seed=0)
    nested = jax.device_get(train_mlp(TABLE1_A, x, y, steps=300))
    params = flatten_params(nested)

    store = WeightStore(":memory:")
    store.register_model("fleet", "mlp")
    server = LicenseServer(store)
    server.publish("fleet", params, tag="v1")
    server.publish_tier("fleet", LicenseTier(
        name="free", masks={"layer1": ((0.5, 0.8),)}, accuracy=0.7))

    zeros = {k: np.zeros_like(v) for k, v in params.items()}
    fleet = [EdgeClient("fleet", dict(zeros),
                        license_name="free" if i % 2 else "full")
             for i in range(6)]
    for c in fleet:
        c.request_update(server)

    # three incremental server versions while half the fleet sleeps
    cur = params
    rng = np.random.default_rng(1)
    for v in range(3):
        cur = {k: np.array(a, copy=True) for k, a in cur.items()}
        flat = cur["layer2/kernel"].reshape(-1)
        flat[rng.choice(flat.size, 50, replace=False)] += 0.05
        server.publish("fleet", cur, tag=f"v1.{v + 1}")
        for c in fleet[: 3]:  # only awake clients sync each round
            c.request_update(server)
    for c in fleet[3:]:       # sleepers catch up in ONE combined packet
        c.request_update(server)

    for i, c in enumerate(fleet):
        acc = mlp_accuracy(unflatten_like(nested, c.params), x, y)
        print(f"client {i} [{c.license_name:4s}] v{c.version} "
              f"downloads={c.updates} bytes={c.bytes_downloaded} "
              f"acc={acc:.3f}")

    # shard-aware distribution: a 4-way sharded serving pod pulls the delta
    packet = server.handle_update("fleet", fleet[0].version - 3)
    size = params["layer2/kernel"].size
    print("\nshard-aware pull of the combined delta (layer2/kernel):")
    for host in range(4):
        lo, hi = host * size // 4, (host + 1) * size // 4
        part = shard_delta(packet, {"layer2/kernel": (lo, hi)})
        print(f"  host{host}: {part.nbytes:5d}B "
              f"({part.num_entries} entries) of {packet.nbytes}B total")


if __name__ == "__main__":
    main()
