"""Licensed batched serving across the architecture zoo.

Instantiates reduced variants of three assigned archs (dense GQA, MoE,
SSM), builds a tier ladder per model, and serves mixed-tier request
batches — the paper's dynamic-licensing deployment (Fig. 2) generalized
from a single edge MLP to modern LM families.

Run:  PYTHONPATH=src python examples/licensed_serving.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier, license_stats
from repro.models import init_params
from repro.serving import Request, ServingEngine


def main():
    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    for arch in ("qwen2.5-3b", "deepseek-moe-16b", "mamba2-130m"):
        cfg = smoke_variant(get_config(arch))
        params = init_params(key, cfg)
        tiers = {
            "free": LicenseTier(name="free", masks={"*": ((0.0, 0.006),)}),
            "pro": LicenseTier(name="pro", masks={"*": ((0.0, 0.002),)}),
        }
        engine = ServingEngine(cfg, params, tiers=tiers)
        reqs = [
            Request(prompt=rng.integers(0, cfg.vocab_size, 24, dtype=np.int32),
                    max_new_tokens=6, license=lic)
            for lic in ("full", "pro", "free", "free")
        ]
        t0 = time.perf_counter()
        engine.generate(reqs)
        dt = time.perf_counter() - t0
        st = license_stats(params, tiers["free"])
        print(f"{arch:22s} served 4 reqs x 6 tok in {dt:.2f}s; "
              f"free tier hides {st['masked_frac'] * 100:.1f}% of weights")
        for r in reqs[:3]:
            print(f"   [{r.license:4s}] {r.out_tokens}")


if __name__ == "__main__":
    main()
