"""End-to-end driver: train a ~small LM for a few hundred steps with the
WeightStore as the checkpoint plane, then roll back to the best version.

The arch is a reduced qwen2.5 (same family; GQA + SwiGLU + QKV-bias); the
data is the structured synthetic stream (models actually learn it).
Every N steps the trainer commits a *delta* checkpoint — unchanged weights
are stored once across all versions (paper §3.4).

Run:  PYTHONPATH=src python examples/train_lm_with_versioned_checkpoints.py [--steps 300]
"""
import argparse


from repro.configs import get_config, smoke_variant
from repro.core.weightstore import WeightStore
from repro.data import LMDataConfig, lm_batches
from repro.training import OptimizerConfig, train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()

    cfg = smoke_variant(get_config(args.arch)).replace(vocab_size=512)
    store = WeightStore(":memory:", row_limit=1 << 30)  # row mode for clarity
    store.register_model(cfg.name, cfg.arch_type)

    data = lm_batches(LMDataConfig(vocab_size=cfg.vocab_size, seq_len=128,
                                   batch_size=8, seed=0))
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    params, history = train_loop(
        cfg, ocfg, data, args.steps, store=store, store_model=cfg.name,
        checkpoint_every=max(args.steps // 4, 1), log_every=25,
    )
    losses = history["loss"]
    print(f"\nloss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'LEARNED' if losses[-1] < losses[0] - 0.2 else 'check data/config'})")

    hist = store.history(cfg.name)
    print(f"checkpoints: {[h['id'] for h in hist]}")
    sizes = store.storage_bytes(cfg.name)
    print(f"store: {sizes['weight_rows']} rows / "
          f"{(sizes['payload']) / 1e6:.1f} MB payload for {len(hist)} versions")

    # rollback demo (paper §3.4): repoint production to the first checkpoint
    store.rollback(cfg.name, hist[0]["id"])
    print(f"rolled back production -> v{store.production_version(cfg.name)}")
    store.close()


if __name__ == "__main__":
    main()
