"""Kernel micro-benchmarks.  On this CPU container the Pallas bodies run
in interpret mode (pure-Python — not a performance datum), so throughput
is measured on the XLA-compiled ref path, which computes the identical
math the TPU kernel implements; interpret-mode correctness is covered by
tests/test_kernels.py."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref


def _time(fn, *args, iters=20) -> float:
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree_util.tree_leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters


def run() -> list:
    rows = []
    r = np.random.default_rng(0)

    x = jnp.asarray(r.standard_normal((256, 2048)), jnp.float32)
    codes = jnp.asarray(r.integers(-127, 128, (2048, 2048)), jnp.int8)
    scale = jnp.asarray(np.abs(r.standard_normal(2048)) * 0.02, jnp.float32)
    f = jax.jit(lambda a, b, c: ref.quant_matmul(a, b, c))
    dt = _time(f, x, codes, scale)
    flops = 2 * 256 * 2048 * 2048
    rows.append({"name": "kernel/quant_matmul_256x2048x2048",
                 "us_per_call": dt * 1e6,
                 "gflops_s": round(flops / dt / 1e9, 1)})

    codes2 = jnp.asarray(r.integers(-127, 128, (4096, 4096)), jnp.int8)
    scale2 = jnp.full((4096,), 0.01, jnp.float32)
    lo, hi = jnp.asarray([0.5] + [0.0] * 7, jnp.float32), jnp.asarray([0.8] + [0.0] * 7, jnp.float32)
    g = jax.jit(lambda c, s, l, h: ref.masked_dequant(c, s[None, :], l, h))
    dt = _time(g, codes2, scale2, lo, hi)
    gb = 4096 * 4096 * (1 + 4) / 1e9
    rows.append({"name": "kernel/masked_dequant_4096x4096",
                 "us_per_call": dt * 1e6,
                 "gb_s": round(gb / dt, 1)})

    buf = jnp.asarray(r.standard_normal(1 << 22), jnp.float32)
    idx = jnp.asarray(r.choice(1 << 22, 4096, replace=False), jnp.int32)
    vals = jnp.asarray(r.standard_normal(4096), jnp.float32)
    h = jax.jit(lambda b, i, v: ref.delta_apply(b, i, v))
    dt = _time(h, buf, idx, vals)
    rows.append({"name": "kernel/delta_apply_4M_buf_4k_delta",
                 "us_per_call": dt * 1e6,
                 "updates_per_s": round(4096 / dt)})
    return rows
