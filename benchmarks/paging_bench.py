"""Paged vs fixed-lane cache pool at equal memory: concurrency & throughput.

The tentpole claim of the paging subsystem: with the SAME cache memory
(``num_blocks * block_size == max_batch * capacity`` tokens), the
block-paged pool admits strictly more concurrent mixed-length requests
than the fixed-lane slab — short requests return their blocks instead of
stranding a full ``capacity`` lane — while producing bit-identical
per-step logits.

Reported rows:
  * ``paging/fixed_pool_total``  — wall time + aggregate tokens/s +
    peak concurrency through the contiguous ``CachePool``.
  * ``paging/paged_pool_total``  — same stream through ``PagedCachePool``
    with ``max_lanes > max_batch`` (same vmap width, same cache tokens),
    plus peak blocks in use and preemption count.
  * ``paging/logit_equivalence`` — max |Δlogits| paged vs contiguous
    over a mixed-length stream (asserted ≤ 1e-5).
  * ``paging/paged_attention_kernel`` — interpret-mode Pallas kernel vs
    its jnp oracle (asserted; the block-table gather is the kernel).

Asserted claims (the ISSUE's acceptance bar):
  concurrency(paged) > concurrency(fixed) at equal cache tokens;
  logits match to 1e-5; kernel matches its reference.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention
from repro.models import init_params
from repro.serving import LicensedGateway

ARCH = "qwen2.5-3b"
MAX_PROMPT = 8
MAX_NEW_CAP = 24
MAX_BATCH = 4
BLOCK = 8
MAX_LANES = 12                   # paged concurrency cap (same vmap width)
NEW_TOKENS = (4, 4, 4, 8, 24)    # mixed lengths: mostly short, some long


def _workload(rng, n_reqs):
    return [(rng.integers(0, 500, MAX_PROMPT, dtype=np.int32),
             NEW_TOKENS[i % len(NEW_TOKENS)]) for i in range(n_reqs)]


def _drain(gw, work):
    t0 = time.perf_counter()
    reqs = [gw.submit(p, license="free", max_new_tokens=n) for p, n in work]
    gw.run()
    dt = time.perf_counter() - t0
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    return reqs, dt


def run(smoke: bool = False) -> list:
    cfg = smoke_variant(get_config(ARCH))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {"free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})}
    rng = np.random.default_rng(0)
    n_reqs = 10 if smoke else 20
    work = _workload(rng, n_reqs)
    total_tokens = sum(n for _, n in work)
    mk = dict(tiers=tiers, max_batch=MAX_BATCH, max_prompt=MAX_PROMPT,
              max_new_cap=MAX_NEW_CAP)

    # ---- fixed-lane slab: concurrency == lanes == max_batch
    fixed = LicensedGateway(cfg, params, paged=False, **mk)
    _drain(fixed, work[:2])                           # warm the jit paths
    fixed = LicensedGateway(cfg, params, paged=False, **mk)
    _, dt_fixed = _drain(fixed, work)

    # ---- paged pool at EQUAL cache memory, more lanes than vmap width
    capacity = MAX_PROMPT + MAX_NEW_CAP
    num_blocks = fixed.pool.cache_tokens // BLOCK     # equal token memory
    pk = dict(paged=True, block_size=BLOCK, num_blocks=num_blocks,
              max_lanes=MAX_LANES, watermark_blocks=1)
    paged = LicensedGateway(cfg, params, **pk, **mk)
    _drain(paged, work[:2])
    paged = LicensedGateway(cfg, params, **pk, **mk)
    _, dt_paged = _drain(paged, work)

    assert paged.pool.cache_tokens == fixed.pool.cache_tokens == \
        MAX_BATCH * capacity
    fixed_conc = fixed.stats["max_running"]
    paged_conc = paged.stats["max_running"]
    # the tentpole claim: same memory, strictly more concurrent requests
    assert paged_conc > fixed_conc, (paged_conc, fixed_conc)

    # ---- per-step logit equivalence on a mixed-length stream
    eq_work = work[:6]
    outs = []
    for kw in (dict(paged=False), pk):
        gw = LicensedGateway(cfg, params, record_logits=True, **kw, **mk)
        reqs, _ = _drain(gw, eq_work)
        outs.append(reqs)
    max_err = 0.0
    for a, b in zip(*outs):
        assert a.out_tokens == b.out_tokens
        for ra, rb in zip(a.logits_rows, b.logits_rows):
            max_err = max(max_err, float(np.max(np.abs(ra - rb))))
    assert max_err <= 1e-5, max_err

    # ---- Pallas paged-attention kernel vs its oracle (interpret mode)
    r = np.random.default_rng(3)
    b, h, kh, hd, bs, t = 4, 8, 2, 64, 16, 4
    q = jnp.asarray(r.standard_normal((b, h, hd)), jnp.float32)
    kb = jnp.asarray(r.standard_normal((b * t + 2, bs, kh, hd)), jnp.float32)
    vb = jnp.asarray(r.standard_normal((b * t + 2, bs, kh, hd)), jnp.float32)
    tables = jnp.asarray(
        r.permutation(b * t + 2)[: b * t].reshape(b, t), jnp.int32)
    lens = jnp.asarray(r.integers(1, t * bs + 1, b), jnp.int32)
    t0 = time.perf_counter()
    got = np.asarray(paged_attention(q, kb, vb, tables, lens, interpret=True))
    dt_kernel = time.perf_counter() - t0
    kerr = float(np.max(np.abs(
        got - np.asarray(ref.paged_attention(q, kb, vb, tables, lens)))))
    assert kerr <= 2e-3, kerr

    return [
        {"name": "paging/fixed_pool_total", "us_per_call": dt_fixed * 1e6,
         "tokens_per_s": round(total_tokens / dt_fixed, 1),
         "max_concurrent": fixed_conc,
         "cache_tokens": fixed.pool.cache_tokens},
        {"name": "paging/paged_pool_total", "us_per_call": dt_paged * 1e6,
         "tokens_per_s": round(total_tokens / dt_paged, 1),
         "max_concurrent": paged_conc,
         "cache_tokens": paged.pool.cache_tokens,
         "block_size": BLOCK, "num_blocks": num_blocks,
         "max_blocks_in_use": paged.stats["max_blocks_in_use"],
         "preempted": paged.stats["preempted"],
         "concurrency_vs_fixed": round(paged_conc / max(1, fixed_conc), 2)},
        {"name": "paging/logit_equivalence", "us_per_call": 0.0,
         "max_abs_err": max_err, "requests": len(eq_work)},
        {"name": "paging/paged_attention_kernel",
         "us_per_call": dt_kernel * 1e6, "max_abs_err_vs_ref": kerr,
         "interpret": True},
    ]
