"""Dynamic-licensing accuracy ladder (paper §3.5): train the paper's
3-layer MLP to ~98% on a separable classification task, then

  1. reproduce the freemium example: mask |w| in [0.5, 0.8) of layer 1 and
     report the accuracy drop (paper: 98% -> 70%);
  2. run Algorithm 1 to calibrate tiers at several target accuracies and
     report (target, achieved, masked fraction).
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs.paper_mlp import TABLE1_A
from repro.core.licensing import LicenseTier, apply_license, calibrate_license, license_stats
from repro.data import classification_data
from repro.training import mlp_accuracy, train_mlp


def run() -> list:
    rows = []
    x, y = classification_data(8000, TABLE1_A.in_dim, TABLE1_A.num_classes, seed=0)
    xtr, ytr, xte, yte = x[:6000], y[:6000], x[6000:], y[6000:]
    t0 = time.perf_counter()
    params = train_mlp(TABLE1_A, xtr, ytr, steps=600)
    base_acc = mlp_accuracy(params, xte, yte)
    rows.append({"name": "license/base_model", "us_per_call": (time.perf_counter() - t0) * 1e6,
                 "accuracy": round(base_acc, 4)})

    # paper freemium example: hide layer-1 weights with |w| in [0.5, 0.8).
    # The paper's absolute interval assumes ITS weight scale; we report the
    # literal interval AND the scale-equivalent one (the same |w|-quantile
    # band [q55, q95) of layer 1) — the mechanism, adapted to our weights.
    tier = LicenseTier(name="paper-freemium", masks={"layer1": ((0.5, 0.8),)})
    acc = mlp_accuracy(apply_license(params, tier), xte, yte)
    st = license_stats(params, tier)
    rows.append({"name": "license/freemium_literal_0.5_0.8", "us_per_call": 0.0,
                 "accuracy": round(acc, 4), "masked_frac": round(st["masked_frac"], 4),
                 "note": "our trained |w| rarely exceeds 0.5"})

    w1 = np.abs(np.asarray(params["layer1"]["kernel"])).reshape(-1)
    lo_q, hi_q = float(np.quantile(w1, 0.55)), float(np.quantile(w1, 0.95))
    tier_q = LicenseTier(name="paper-freemium-scaled",
                         masks={"layer1": ((lo_q, hi_q),)})
    acc_q = mlp_accuracy(apply_license(params, tier_q), xte, yte)
    st_q = license_stats(params, tier_q)
    rows.append({"name": "license/freemium_scaled_q55_q95", "us_per_call": 0.0,
                 "interval": [round(lo_q, 4), round(hi_q, 4)],
                 "accuracy": round(acc_q, 4),
                 "masked_frac": round(st_q["masked_frac"], 4),
                 "paper_claim": "98% -> 70%"})

    # Algorithm 1 ladders
    def eval_fn(p):
        return mlp_accuracy(p, xte, yte)

    for target in (0.9, 0.8, 0.7, 0.5):
        t0 = time.perf_counter()
        tier, trace = calibrate_license(params, eval_fn, target, k_intervals=12,
                                        tier_name=f"tier{int(target * 100)}")
        dt = time.perf_counter() - t0
        st = license_stats(params, tier)
        rows.append({
            "name": f"license/alg1_target_{target}",
            "us_per_call": dt * 1e6,
            "target": target,
            "achieved": round(tier.accuracy or 0.0, 4),
            "masked_frac": round(st["masked_frac"], 4),
            "calibration_evals": len(trace),
        })
        # beyond paper: bisection refinement of the final interval
        t0 = time.perf_counter()
        tier_r, trace_r = calibrate_license(
            params, eval_fn, target, k_intervals=12, refine_steps=6,
            tier_name=f"tier{int(target * 100)}r")
        rows.append({
            "name": f"license/alg1_refined_target_{target}",
            "us_per_call": (time.perf_counter() - t0) * 1e6,
            "target": target,
            "achieved": round(tier_r.accuracy or 0.0, 4),
            "calibration_evals": len(trace_r),
        })
    return rows
