"""Paper Table 1 reproduction: DB storage cost of ~100k-param MLPs under
full / pruned-80% / pruned+quantized storage.

Paper numbers (64-bit values in Postgres): 109386 params -> 13 MB full,
2.92 MB pruned, 2.34 MB pruned+quant; 101770 -> 12 / 2.65 / 2.09 MB.
We report the same three columns from our sqlite WeightStore (row mode,
8B REAL values like the paper's baseline) plus the pipeline's accounting.
"""
from __future__ import annotations

import time

import jax

from repro.configs.paper_mlp import TABLE1_A, TABLE1_B
from repro.core import compress_pipeline
from repro.core.weightstore import WeightStore
from repro.training import init_mlp_params


def _store_size(params) -> dict:
    """Commit to an on-disk sqlite DB and report BOTH the pure payload
    accounting and the actual database file size (the paper's 13 MB for
    109k params is Postgres file cost incl. tuple/index overhead — the
    honest comparison is file-to-file)."""
    import os
    import tempfile

    fd, path = tempfile.mkstemp(suffix=".db")
    os.close(fd)
    os.unlink(path)
    store = WeightStore(path)
    store.register_model("m", "mlp")
    store.commit("m", params)
    store.conn.commit()
    store.conn.execute("VACUUM")
    out = store.storage_bytes("m")
    out["file_bytes"] = os.path.getsize(path)
    store.close()
    os.unlink(path)
    return out


def run() -> list:
    rows = []
    for mlp_cfg in (TABLE1_A, TABLE1_B):
        key = jax.random.PRNGKey(0)
        params = init_mlp_params(key, mlp_cfg)
        n_params = mlp_cfg.num_params

        t0 = time.perf_counter()
        full = _store_size(params)
        t_full = time.perf_counter() - t0

        pruned, quant, stats = compress_pipeline(params, sparsity=0.8)
        pruned_sz = _store_size(pruned)

        mb = 1e6
        rows.append({
            "name": f"table1/{mlp_cfg.name}",
            "us_per_call": t_full * 1e6,
            "n_params": n_params,
            "full_file_MB": round(full["file_bytes"] / mb, 2),
            "pruned_file_MB": round(pruned_sz["file_bytes"] / mb, 2),
            "full_payload_MB": round(full["row_bytes"] / mb, 2),
            "pruned_payload_MB": round(pruned_sz["row_bytes"] / mb, 2),
            "pruned_quant_MB": round(stats.quantized_bytes / mb, 2),
            "shared_MB": round(stats.shared_bytes / mb, 2),
            "sparsity": round(stats.sparsity, 3),
            "paper_full_MB": 13.0 if mlp_cfg is TABLE1_A else 12.0,
            "paper_pruned_MB": 2.92 if mlp_cfg is TABLE1_A else 2.65,
            "paper_quant_MB": 2.34 if mlp_cfg is TABLE1_A else 2.09,
        })
    return rows
