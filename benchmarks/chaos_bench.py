"""Fault-tolerant staged sync benchmark: decode stall under a seeded
fault schedule vs. the fault-free staged sync.

ISSUE 9's tentpole claim is that faults on the gateway↔license-server
wire cost *retries and lease state*, never correctness and never an
unbounded serving stall.  Method: two gateways serve the identical
request stream while the server publishes v2 mid-stream and a staged
sync carries it in; one gateway syncs over a :class:`DirectTransport`,
the other over a :class:`ChaosTransport` at a ≥20% mixed fault rate
(timeouts + mid-stream disconnects + corrupted pages + duplicate
deliveries).  Every scheduler step is individually timed.

Asserted claims (the CI gate behind ``BENCH_chaos.json``):
  * p99 per-step decode stall under faults ≤ 2× the fault-free staged
    stall (floor-interpolated; retry/backoff sleeps are injected no-ops
    so the comparison isolates protocol overhead — reopen, re-fetch,
    checksum re-verification — not wall-clock sleeping);
  * emitted tokens are bit-identical between the chaos run and the
    fault-free run, and both land exactly one version flip;
  * the fault schedule really fired (wire faults > 0, retries > 0).
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.core.protocol import LicenseServer
from repro.core.transport import ChaosTransport, RetryPolicy
from repro.core.weightstore import WeightStore
from repro.models import init_params

ARCH = "qwen2.5-3b"
MAX_PROMPT = 8
MAX_BATCH = 4
N_REQS = 8
NEW_TOKENS = 24
SYNC_AT_STEP = 4                 # publish + begin_sync after this many steps
MAX_STEP_BYTES = 256 << 10
CHUNK_ELEMS = 8 << 10            # 32 KiB pages < MAX_STEP_BYTES
CHAOS_SEED = 7
FAULT_RATE = 0.25                # ≥20% of wire calls fault
DUP_RATE = 0.1


def _boot(cfg, server, params):
    from repro.serving import LicensedGateway

    template = jax.tree_util.tree_map(lambda x: np.zeros_like(x), params)
    return LicensedGateway.from_server(
        cfg, server, "lm", template, max_batch=MAX_BATCH,
        max_prompt=MAX_PROMPT, max_new_cap=NEW_TOKENS)


def _submit_all(gw, n_reqs):
    return [gw.submit(np.random.default_rng(i).integers(
                          0, 500, MAX_PROMPT, dtype=np.int32),
                      license="free", max_new_tokens=NEW_TOKENS)
            for i in range(n_reqs)]


def _drive(gw, n_reqs, *, publish, sync_kw) -> tuple:
    """Serve the stream; at SYNC_AT_STEP publish v2 and begin the staged
    sync.  Returns (per-step seconds, requests)."""
    reqs = _submit_all(gw, n_reqs)
    steps: List[float] = []
    i = 0
    while gw.scheduler.waiting or gw.scheduler.running or gw.sync_active:
        begin = False
        if i == SYNC_AT_STEP:
            publish()
            begin = True
        t0 = time.perf_counter()
        if begin:
            assert gw.begin_sync(max_step_bytes=MAX_STEP_BYTES,
                                 **sync_kw) is True
        gw.step()
        steps.append(time.perf_counter() - t0)
        i += 1
    return steps, reqs


def run(smoke: bool = False) -> list:
    n_reqs = 4 if smoke else N_REQS
    cfg = smoke_variant(get_config(ARCH))
    params = jax.device_get(init_params(jax.random.PRNGKey(0), cfg))
    tier = LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})

    def fresh_server():
        store = WeightStore(":memory:", row_limit=2048,
                            chunk_elems=CHUNK_ELEMS)
        server = LicenseServer(store)
        server.publish("lm", params, tag="v1")
        server.publish_tier("lm", tier)
        return server

    from repro.core.pytree_io import flatten_params

    flat = flatten_params(params)
    warmp = {k: (v * 1.001 if i % 3 == 0 else v)
             for i, (k, v) in enumerate(flat.items())}
    newp = {k: (v * 1.01 if i % 3 == 0 else v)
            for i, (k, v) in enumerate(flat.items())}

    def _warm(gw, server):
        """Warm serving AND the sync path (same batch shape and the same
        touched layers / page shapes as the measured run) outside timing
        so JIT compilation never lands in either arm's timed region."""
        ws = [gw.submit(np.zeros(MAX_PROMPT, np.int32), license="free",
                        max_new_tokens=NEW_TOKENS) for _ in range(MAX_BATCH)]
        gw.run()
        assert all(w.out_tokens for w in ws)
        server.publish("lm", warmp, tag="v1.1")
        assert gw.begin_sync(max_step_bytes=MAX_STEP_BYTES) is True
        while gw.sync_active:
            gw.sync_step()

    def _arm(sync_kw_fn):
        server = fresh_server()
        gw = _boot(cfg, server, params)
        _warm(gw, server)
        flips0 = len(gw.audit.events("version_flip"))  # warm's own flip
        steps, reqs = _drive(
            gw, n_reqs, sync_kw=sync_kw_fn(server),
            publish=lambda: server.publish("lm", newp, tag="v2"))
        assert len(gw.audit.events("version_flip")) - flips0 == 1
        return gw, steps, reqs

    # retry backoffs are injected no-ops in BOTH arms: the bench compares
    # protocol overhead (reopen, re-fetch, re-verify), not sleep()
    no_sleep_retry = RetryPolicy(max_attempts=10, base_delay_s=0.0,
                                 jitter=0.0, sleep=lambda _s: None)

    # ---- fault-free staged sync (the reference arm)
    direct, steps_d, reqs_d = _arm(lambda server: {"retry": no_sleep_retry})
    v_after = direct.version

    # ---- chaos arm: every wire call of the sync may fault
    chaos_tr = {}

    def chaos_kw(server):
        chaos_tr["t"] = ChaosTransport(
            server, seed=CHAOS_SEED, fault_rate=FAULT_RATE,
            dup_rate=DUP_RATE, sleep=lambda _s: None)
        return {"transport": chaos_tr["t"], "retry": no_sleep_retry}

    chaos, steps_c, reqs_c = _arm(chaos_kw)

    # ---- claims ---------------------------------------------------------
    # token equivalence: the fault schedule never touches outputs
    for r, rr in zip(reqs_c, reqs_d):
        assert r.out_tokens == rr.out_tokens, "faults changed tokens"
    assert chaos.version == chaos._client.version == v_after
    st = chaos.metrics()["staged_update"]
    wire = st["wire"]
    assert st["flips"] == 1
    assert wire["faults"] > 0 and st["retries"] > 0, \
        "the chaos schedule never fired"
    # landed weights identical to the fault-free arm's
    for x, y in zip(jax.tree_util.tree_leaves(chaos._client.params),
                    jax.tree_util.tree_leaves(direct._client.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    # the gate: p99 decode stall under faults ≤ 2× the fault-free staged
    # stall (floor interpolation: ~2nd-worst of ~50 steps, so one CI
    # container hiccup cannot flip the verdict)
    p99_d = float(np.percentile(steps_d, 99, method="lower"))
    p99_c = float(np.percentile(steps_c, 99, method="lower"))
    assert p99_c <= 2.0 * p99_d, (p99_c, p99_d)

    rows = [
        {"name": "chaos/staged_sync_fault_free",
         "us_per_call": float(np.sum(steps_d)) * 1e6 / max(len(steps_d), 1),
         "decode_stall_p99_ms": round(p99_d * 1e3, 2),
         "decode_stall_max_ms": round(float(np.max(steps_d)) * 1e3, 2),
         "steps": len(steps_d)},
        {"name": "chaos/staged_sync_faulted",
         "us_per_call": float(np.sum(steps_c)) * 1e6 / max(len(steps_c), 1),
         "decode_stall_p99_ms": round(p99_c * 1e3, 2),
         "decode_stall_max_ms": round(float(np.max(steps_c)) * 1e3, 2),
         "stall_vs_fault_free_x": round(p99_c / max(p99_d, 1e-9), 2),
         "stall_bound_x": 2.0,
         "steps": len(steps_c),
         "fault_rate": FAULT_RATE,
         "wire_calls": wire["calls"],
         "wire_faults": wire["faults"],
         "timeouts": wire["timeouts"],
         "disconnects": wire["disconnects"],
         "corruptions": wire["corruptions"],
         "duplicates": wire["duplicates"],
         "retries": st["retries"],
         "resumes": st["resumes"],
         "tokens_equivalent": True},
    ]
    return rows
