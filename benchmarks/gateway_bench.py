"""Gateway vs single-stream engine: throughput & latency across license tiers.

Measures the tentpole claim of the continuous-batching licensed gateway:
with N license tiers' requests arriving as one stream, the gateway's
tier-homogeneous micro-batches + (tier, version)-keyed view cache beat
the seed ``ServingEngine`` serving each tier's request streams one at a
time (its admission model: one stream per ``generate`` call).

Workload: ``TIERS`` tiers x ``REQS_PER_TIER`` requests with mixed decode
lengths (continuous batching's best case AND the realistic one — real
request lengths are heterogeneous).  Both sides are warmed first so jit
compilation is excluded.

Reported rows:
  * ``gateway/engine_single_stream_total``  — baseline wall time; per-tier
    sequential, one request stream at a time (b=1 decodes).
  * ``gateway/continuous_batching_total``   — gateway wall time draining
    the same workload, plus p50/p99 request latency and the speedup.
  * ``gateway/view_cache``                  — hit/miss/invalidation
    counters proving masking is paid once per (tier, version), not once
    per request.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_params
from repro.serving import LicensedGateway, Request, ServingEngine

ARCH = "qwen2.5-3b"
TIERS = ("full", "free", "pro")
REQS_PER_TIER = 4
PROMPT_LEN = 8
MAX_BATCH = 8
NEW_TOKENS = (4, 8, 12, 16)      # heterogeneous decode lengths


def _workload(rng):
    reqs = []
    for tier in TIERS:
        for i in range(REQS_PER_TIER):
            reqs.append((tier,
                         rng.integers(0, 500, PROMPT_LEN, dtype=np.int32),
                         NEW_TOKENS[i % len(NEW_TOKENS)]))
    return reqs


def run() -> list:
    cfg = smoke_variant(get_config(ARCH))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {
        "free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)}),
        "pro": LicenseTier(name="pro", masks={"*": ((0.0, 0.002),)}),
    }
    rng = np.random.default_rng(0)
    work = _workload(rng)
    total_tokens = sum(n for _, _, n in work)
    max_new_cap = max(NEW_TOKENS)

    # ---- baseline: seed engine, one request stream at a time, per tier
    engine = ServingEngine(cfg, params, tiers=tiers)
    warm = Request(prompt=work[0][1].copy(), max_new_tokens=2, license="full")
    engine.generate([warm])                            # compile b=1 path
    lat_engine = []
    t0 = time.perf_counter()
    for tier in TIERS:                                 # tier-sequential
        for t, prompt, n_new in work:
            if t != tier:
                continue
            r = Request(prompt=prompt.copy(), max_new_tokens=n_new, license=tier)
            t1 = time.perf_counter()
            engine.generate([r])
            lat_engine.append(time.perf_counter() - t1)
    dt_engine = time.perf_counter() - t0

    # ---- gateway: continuous batching over the same stream
    gw = LicensedGateway(cfg, params, tiers=tiers, max_batch=MAX_BATCH,
                         max_prompt=PROMPT_LEN, max_new_cap=max_new_cap)
    warm_req = gw.submit(work[0][1], license="full", max_new_tokens=2)
    gw.run()                                           # compile lane paths
    assert warm_req.out_tokens, "gateway warmup failed"
    t0 = time.perf_counter()
    reqs = [gw.submit(prompt, license=tier, max_new_tokens=n_new)
            for tier, prompt, n_new in work]
    gw.run()
    dt_gw = time.perf_counter() - t0
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    lats = [r.latency for r in reqs]
    vc = gw.views.stats()
    concurrent_tiers = len({t for t, _, _ in work})

    rows = [
        {"name": "gateway/engine_single_stream_total",
         "us_per_call": dt_engine * 1e6,
         "tokens_per_s": round(total_tokens / dt_engine, 1),
         "request_p50_ms": round(float(np.percentile(lat_engine, 50)) * 1e3, 2),
         "request_p99_ms": round(float(np.percentile(lat_engine, 99)) * 1e3, 2)},
        {"name": "gateway/continuous_batching_total",
         "us_per_call": dt_gw * 1e6,
         "tokens_per_s": round(total_tokens / dt_gw, 1),
         "request_p50_ms": round(float(np.percentile(lats, 50)) * 1e3, 2),
         "request_p99_ms": round(float(np.percentile(lats, 99)) * 1e3, 2),
         "speedup_vs_single_stream": round(dt_engine / dt_gw, 2),
         "concurrent_tiers": concurrent_tiers,
         "decode_steps": gw.stats["decode_steps"],
         "prefill_batches": gw.stats["prefill_batches"]},
        {"name": "gateway/view_cache",
         "us_per_call": 0.0,
         "hits": vc["hits"], "misses": vc["misses"],
         "entries": vc["entries"]},
    ]
    # the claims the ISSUE pins: >= 2 concurrent tiers, higher aggregate
    # throughput than tier-sequential single-stream serving, and masking
    # amortized across requests (cache hits observed)
    assert concurrent_tiers >= 2
    assert dt_gw < dt_engine, (dt_gw, dt_engine)
    assert vc["hits"] > 0 and vc["misses"] <= len(TIERS)
    return rows
