"""Assemble the §Roofline table from dryrun_results/*.json into markdown
(printed and written to benchmarks/roofline_table.md)."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "dryrun_results"
OUT = Path(__file__).resolve().parent / "roofline_table.md"

COLS = ("arch", "shape", "mesh", "dominant", "compute_s", "memory_s",
        "collective_s", "useful_flops_ratio", "bytes_per_device", "note")


def rows():
    out = []
    for f in sorted(RESULTS.glob("*.json")):
        out.append(json.loads(f.read_text()))
    return out


def render(data=None) -> str:
    data = data or rows()
    lines = ["| arch | shape | mesh | dominant | compute (s) | memory (s) | "
             "collective (s) | useful-FLOP ratio | GiB/dev | note |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(data, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | **{r['dominant']}** | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | {r['collective_s']:.4f} | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{(r.get('bytes_per_device') or 0) / 2**30:.2f} | {r.get('note', '')} |"
        )
    return "\n".join(lines)


def run() -> list:
    data = rows()
    md = render(data)
    OUT.write_text(md + "\n")
    agg = {}
    for r in data:
        agg.setdefault(r["dominant"], 0)
        agg[r["dominant"]] += 1
    return [{"name": "roofline/table", "us_per_call": 0.0,
             "combos": len(data), "dominant_histogram": agg,
             "written": str(OUT)}]


if __name__ == "__main__":
    print(render())
