"""Observability overhead gate: decode tokens/s, telemetry on vs off.

The observability layer (serving/telemetry.py + tracing.py) is designed
to be ALWAYS ON in production: pull-model counters/gauges read existing
``stats`` dicts only at export time, histogram observes are one bisect +
one counter bump, trace events are O(1) tuple appends onto a bounded
deque.  This bench measures the end-to-end price on the hot path — a
decode-heavy workload drained through two otherwise identical gateways,
``telemetry=False`` (the do-nothing baseline: no spans, no observes)
vs ``telemetry=True`` (full tracing + histograms + audit) — and
ASSERTS the instrumented gateway sustains >= ``MIN_RATIO`` (0.97x,
i.e. <3% overhead) of the baseline's decode tokens/s.

Each side is warmed first (jit + view materialization excluded), then
measured as INTERLEAVED off/on trial pairs; the gate takes the best
per-pair ratio.  Pairing + best-of damps the two noise sources that
would otherwise dominate a 3% gate on a shared box: per-drain
scheduler/allocator jitter, and machine-wide drift between the off and
on measurement windows.

Set ``TELEMETRY_TRACE_OUT=/path/trace.json`` to also dump the
instrumented run's whole-gateway Chrome trace (Perfetto-loadable; CI
uploads it as an artifact).  The tape is validated either way.

Rows: ``telemetry/decode_off`` and ``telemetry/decode_on`` (us per
generated token + tokens/s), ``telemetry/overhead`` (the ratio the gate
asserts, plus trace/audit volumes for scale).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_params
from repro.serving import LicensedGateway, validate_chrome_trace

ARCH = "qwen2.5-3b"
PROMPT_LEN = 8
MIN_RATIO = 0.97                 # the <3% decode-overhead gate


def _gateway(cfg, params, tiers, telemetry, max_new):
    return LicensedGateway(cfg, params, tiers=tiers, max_batch=8,
                           max_prompt=PROMPT_LEN, max_new_cap=max_new,
                           telemetry=telemetry)


def _drain(gw, n_reqs, max_new, rng):
    """Submit a decode-heavy wave and drain it; returns tokens/s."""
    reqs = [gw.submit(rng.integers(0, 500, PROMPT_LEN, dtype=np.int32),
                      license="free" if i % 2 else "full",
                      max_new_tokens=max_new, seed=i)
            for i in range(n_reqs)]
    t0 = time.perf_counter()
    gw.run()
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out_tokens) for r in reqs)
    assert tokens == n_reqs * max_new
    return tokens / dt


def run(smoke: bool = False) -> list:
    # drains must be long enough that one scheduler hiccup cannot move a
    # 3% gate: ~0.5s+ of decode per drain even at smoke scale
    n_reqs, max_new, trials = (16, 24, 3) if smoke else (24, 48, 4)
    cfg = smoke_variant(get_config(ARCH))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {"free": LicenseTier(name="free",
                                 masks={"*": ((0.0, 0.004),)})}
    rng = np.random.default_rng(0)

    gw_off = _gateway(cfg, params, tiers, telemetry=False, max_new=max_new)
    gw_on = _gateway(cfg, params, tiers, telemetry=True, max_new=max_new)
    # warm with the MEASURED workload shape: a different wave size would
    # leave batch-shape compilations to land inside the first trial
    _drain(gw_off, n_reqs, max_new, rng)
    _drain(gw_on, n_reqs, max_new, rng)

    pairs = [(_drain(gw_off, n_reqs, max_new, rng),
              _drain(gw_on, n_reqs, max_new, rng))
             for _ in range(trials)]
    best_off = max(off for off, _ in pairs)
    best_on = max(on for _, on in pairs)
    ratio = max(on / off for off, on in pairs)

    # the tape produced under load is a well-formed Chrome trace
    trace = gw_on.chrome_trace()
    events = validate_chrome_trace(trace)
    out = os.environ.get("TELEMETRY_TRACE_OUT")
    if out:
        with open(out, "w") as f:
            f.write(trace)

    assert ratio >= MIN_RATIO, (
        f"telemetry overhead gate: instrumented decode ran at "
        f"{ratio:.4f}x of baseline tokens/s (gate {MIN_RATIO}x) — "
        f"{best_on:.0f} vs {best_off:.0f} tok/s")

    return [
        {"name": "telemetry/decode_off", "us_per_call": 1e6 / best_off,
         "tokens_per_s": round(best_off, 1), "trials": trials},
        {"name": "telemetry/decode_on", "us_per_call": 1e6 / best_on,
         "tokens_per_s": round(best_on, 1), "trials": trials},
        {"name": "telemetry/overhead", "us_per_call":
         1e6 / best_on - 1e6 / best_off,
         "on_over_off_ratio": round(ratio, 4), "gate": MIN_RATIO,
         "trace_events": len(events),
         "histogram_observes": gw_on.h_ttft.count + gw_on.h_gap.count
         + gw_on.h_queue.count + gw_on.h_prefill.count
         + gw_on.h_decode.count,
         "audit_events": len(gw_on.audit_events()),
         "trace_dumped": bool(out)},
    ]


if __name__ == "__main__":
    for row in run(smoke=True):
        print(row)
