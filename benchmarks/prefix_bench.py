"""Shared-prefix radix cache vs the PR 2 paged baseline on a
shared-system-prompt workload.

The tentpole claim of the prefix subsystem: tier-homogeneous traffic
whose prompts share a system prefix should pay prefill FLOPs for each
distinct suffix ONCE per prefix, not once per request — with identical
logits, because a cached block holds exactly the KV a cold prefill would
recompute (same tokens, same absolute positions, same (tier, version)
weight view).

Workload: ``N_CONVOS`` distinct prompts sharing a ``SHARED``-token
system prompt, served cold (wave 1, populates the radix cache) and then
re-served across ``REPEAT_WAVES`` follow-up waves mixing suffix-sharing
prompts and exact repeats (the full-match path that exercises
copy-on-write of the shared partial tail block — ``MAX_PROMPT`` is
deliberately not block-aligned).

Reported rows:
  * ``prefix/paged_baseline``   — the stream with ``prefix_cache=False``
    (PR 2 behavior): wall time, tokens/s, prefill lane-tokens, blocks
    allocated.
  * ``prefix/prefix_cache``     — same stream with the radix cache: hit
    rate, prefix tokens reused, retained blocks, CoW copies, and the
    savings ratios.
  * ``prefix/logit_equivalence``— max |Δlogits| prefix-hit vs cold
    prefill over the stream (asserted ≤ 1e-5, identical tokens).

Asserted claims (the ISSUE's acceptance bar):
  prefill lane-tokens(baseline) ≥ 2x prefill lane-tokens(prefix);
  blocks allocated strictly fewer; per-step logits match to 1e-5.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_params
from repro.serving import LicensedGateway, RequestState

ARCH = "qwen2.5-3b"
SHARED = 24                # system-prompt tokens (3 full blocks of 8)
MAX_PROMPT = 30            # NOT block-aligned: partial tail block -> CoW
MAX_NEW_CAP = 16
MAX_BATCH = 4
BLOCK = 8
N_CONVOS = 4
REPEAT_WAVES = 3


def _workload(rng, n_convos, waves):
    """[(prompt, max_new), ...] per wave: wave 0 cold, later waves mix
    fresh suffixes on the shared system prompt with exact repeats."""
    head = rng.integers(0, 500, SHARED, dtype=np.int32)
    tail = MAX_PROMPT - SHARED

    def fresh():
        return np.concatenate([head, rng.integers(0, 500, tail,
                                                  dtype=np.int32)])

    convos = [fresh() for _ in range(n_convos)]
    out = [[(p, 4) for p in convos]]
    for w in range(waves):
        wave = [(fresh(), 4) for _ in range(n_convos - 1)]
        wave.append((convos[w % n_convos].copy(), 4))   # exact repeat
        out.append(wave)
    return out


def _drain(gw, waves):
    t0 = time.perf_counter()
    reqs = []
    for wave in waves:
        reqs += [gw.submit(p, license="free", max_new_tokens=n)
                 for p, n in wave]
        gw.run()
    dt = time.perf_counter() - t0
    assert all(r.state == RequestState.DONE for r in reqs), \
        [r.error for r in reqs]
    return reqs, dt


def run(smoke: bool = False) -> list:
    cfg = smoke_variant(get_config(ARCH))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {"free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})}
    rng = np.random.default_rng(0)
    # >= 2 repeat waves even at smoke scale: one cold wave must be
    # amortized far enough for the asserted 2x prefill-token savings
    waves = _workload(rng, N_CONVOS, 2 if smoke else REPEAT_WAVES)
    total_new = sum(n for wave in waves for _, n in wave)
    mk = dict(tiers=tiers, max_batch=MAX_BATCH, max_prompt=MAX_PROMPT,
              max_new_cap=MAX_NEW_CAP, block_size=BLOCK)

    # ---- PR 2 paged baseline: every prompt prefills cold
    base = LicensedGateway(cfg, params, prefix_cache=False, **mk)
    _drain(base, waves)                               # warm the jit paths
    base = LicensedGateway(cfg, params, prefix_cache=False, **mk)
    _, dt_base = _drain(base, waves)

    # ---- shared-prefix radix cache over the same stream (full-stream
    # warmup: the suffix-prefill jit specializes per suffix width, and the
    # widths only appear once the cache is populated)
    warm = LicensedGateway(cfg, params, prefix_cache=True, **mk)
    _drain(warm, waves)
    warm = LicensedGateway(cfg, params, prefix_cache=True, **mk)
    _, dt_warm = _drain(warm, waves)

    pm = warm.metrics()["prefix_cache"]
    lane_base = base.stats["prefill_lane_tokens"]
    lane_warm = warm.stats["prefill_lane_tokens"]
    alloc_base = base.pool.allocator.alloc_count
    alloc_warm = warm.pool.allocator.alloc_count
    # the acceptance bar: >= 2x prefill-token savings, strictly fewer blocks
    assert lane_base >= 2 * lane_warm, (lane_base, lane_warm)
    assert alloc_warm < alloc_base, (alloc_warm, alloc_base)
    assert pm["hits"] > 0 and pm["prefix_tokens_reused"] > 0
    if not smoke:
        assert pm["cow_copies"] > 0                   # full-match tail CoW

    # ---- per-step logit equivalence: prefix hits vs cold prefill
    eq_waves = waves[:2]
    outs = []
    for prefix in (False, True):
        gw = LicensedGateway(cfg, params, prefix_cache=prefix,
                             record_logits=True, **mk)
        reqs, _ = _drain(gw, eq_waves)
        outs.append(reqs)
    max_err = 0.0
    for a, b in zip(*outs):
        assert a.out_tokens == b.out_tokens
        for ra, rb in zip(a.logits_rows, b.logits_rows):
            max_err = max(max_err, float(np.max(np.abs(ra - rb))))
    assert max_err <= 1e-5, max_err

    return [
        {"name": "prefix/paged_baseline", "us_per_call": dt_base * 1e6,
         "tokens_per_s": round(total_new / dt_base, 1),
         "prefill_lane_tokens": lane_base, "blocks_allocated": alloc_base},
        {"name": "prefix/prefix_cache", "us_per_call": dt_warm * 1e6,
         "tokens_per_s": round(total_new / dt_warm, 1),
         "prefill_lane_tokens": lane_warm, "blocks_allocated": alloc_warm,
         "prefill_savings_x": round(lane_base / max(1, lane_warm), 2),
         "hit_rate": pm["hit_rate"],
         "prefix_tokens_reused": pm["prefix_tokens_reused"],
         "retained_blocks": pm["retained_blocks"],
         "cow_copies": pm["cow_copies"],
         "evicted_blocks": pm["evicted_blocks"]},
        {"name": "prefix/logit_equivalence", "us_per_call": 0.0,
         "max_abs_err": max_err,
         "requests": sum(len(w) for w in eq_waves)},
    ]
