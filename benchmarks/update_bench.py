"""Staged weight sync benchmark: decode-stall p99 with vs. without staging.

The paper's headline is *low-latency dynamic licensing*: an edge pod
pulls §3.1.2 delta updates and flips versions without interrupting
service.  The blocking ``sync()`` pays the whole delta-apply (plus, on
the int8 path, a whole-model requantize) between two scheduler steps —
one giant stall.  The staged path (``serving/updates.py``) interleaves
bounded stager steps with decode, so no scheduler step ever carries the
full update.

Method: two gateways serve the identical request stream while the
server publishes a new production version mid-stream.  Every scheduler
step is individually timed; the blocking gateway runs the pre-staging
sync (whole packet pulled, applied, whole-model requantize — spelled
out in ``_blocking_sync`` because the gateway's ``sync()`` itself now
drives the staged machinery) inline between two steps, the staged
gateway runs ``begin_sync()`` and lets ``step()`` carry the bounded
work.  An update-free reference run pins token equivalence.

Asserted claims (the CI gate behind ``BENCH_update.json``):
  * staged p99 per-step stall (floor-interpolated, ~2nd-worst of ~50
    steps so one CI-container contention outlier cannot flip the
    verdict; the raw max is reported alongside) < the blocking sync
    stall — no scheduler step is delayed by the full delta-apply;
  * per-stager-step applied bytes respect ``max_step_bytes`` (+ one
    indivisible chunk page);
  * in-flight requests produce bit-identical tokens across the staged
    flip (version pinning), and post-flip admissions serve the new
    version through a prewarmed view.
"""
from __future__ import annotations

import time
from typing import List

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.core.protocol import LicenseServer
from repro.core.weightstore import WeightStore
from repro.models import init_params

ARCH = "qwen2.5-3b"
MAX_PROMPT = 8
MAX_BATCH = 4
N_REQS = 8
NEW_TOKENS = 24
SYNC_AT_STEP = 4                 # publish + sync after this many steps
MAX_STEP_BYTES = 256 << 10
REQUANT_PER_STEP = 4
CHUNK_ELEMS = 8 << 10            # 32 KiB pages < MAX_STEP_BYTES


def _boot(cfg, server, params, **kw):
    from repro.serving import LicensedGateway

    template = jax.tree_util.tree_map(lambda x: np.zeros_like(x), params)
    return LicensedGateway.from_server(
        cfg, server, "lm", template, max_batch=MAX_BATCH,
        max_prompt=MAX_PROMPT, max_new_cap=NEW_TOKENS, **kw)


def _submit_all(gw, n_reqs):
    return [gw.submit(np.random.default_rng(i).integers(
                          0, 500, MAX_PROMPT, dtype=np.int32),
                      license="free", max_new_tokens=NEW_TOKENS)
            for i in range(n_reqs)]


def _blocking_sync(gw, server) -> None:
    """The pre-staging ``sync()`` reproduced as the baseline: tier
    refresh, the whole packet pulled and applied in one call, then
    ``update_weights`` — which requantizes the WHOLE model on the int8
    path — all between two scheduler steps.  (The gateway's ``sync()``
    itself now drives the staged machinery, so the old behavior must be
    spelled out to be measured.)"""
    gw._refresh_server_tiers()
    gw._client.request_update(server)
    gw.update_weights(gw._client.params, version=gw._client.version)


def _drive(gw, n_reqs, *, publish, staged, server=None) -> tuple:
    """Serve the stream; at SYNC_AT_STEP publish v2 and sync.  Returns
    (per-step seconds, blocking-sync seconds or 0, requests)."""
    reqs = _submit_all(gw, n_reqs)
    steps: List[float] = []
    sync_s = 0.0
    i = 0
    while gw.scheduler.waiting or gw.scheduler.running or gw.sync_active:
        begin = False
        if i == SYNC_AT_STEP:
            publish()
            if staged:
                begin = True              # timed WITH this iteration's step:
            else:                         # the §4.2 delta query at begin is
                t0 = time.perf_counter()  # serving-thread work too
                _blocking_sync(gw, server)
                sync_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        if begin:
            assert gw.begin_sync(
                max_step_bytes=MAX_STEP_BYTES,
                requant_layers_per_step=REQUANT_PER_STEP) is True
        gw.step()
        steps.append(time.perf_counter() - t0)
        i += 1
    return steps, sync_s, reqs


def run(smoke: bool = False) -> list:
    n_reqs = 4 if smoke else N_REQS
    cfg = smoke_variant(get_config(ARCH))
    params = jax.device_get(init_params(jax.random.PRNGKey(0), cfg))
    tier = LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})

    def fresh_server():
        store = WeightStore(":memory:", row_limit=2048,
                            chunk_elems=CHUNK_ELEMS)
        server = LicenseServer(store)
        server.publish("lm", params, tag="v1")
        server.publish_tier("lm", tier)
        return server

    # a realistic §3.1.2 delta touches a fraction of the layers; the
    # blocking path still requantizes the WHOLE model (update_weights),
    # the staged path only the touched third (requantize_layers)
    from repro.core.pytree_io import flatten_params

    flat = flatten_params(params)
    warmp = {k: (v * 1.001 if i % 3 == 0 else v)
             for i, (k, v) in enumerate(flat.items())}
    newp = {k: (v * 1.01 if i % 3 == 0 else v)
            for i, (k, v) in enumerate(flat.items())}

    def _warm(gw, server, staged):
        """Warm serving AND the arm's own update path (same touched
        layers / page shapes as the measured delta) outside timing: the
        bench measures steady-state stalls, not first-sync jit cost."""
        w = gw.submit(np.zeros(MAX_PROMPT, np.int32), license="free",
                      max_new_tokens=2)
        gw.run()
        assert w.out_tokens
        server.publish("lm", warmp, tag="v1.1")
        if staged:
            assert gw.begin_sync(
                max_step_bytes=MAX_STEP_BYTES,
                requant_layers_per_step=REQUANT_PER_STEP) is True
            while gw.sync_active:
                gw.sync_step()
        else:
            _blocking_sync(gw, server)

    # ---- update-free reference: the token stream pinning must reproduce.
    # Boots from a server already at the warm version, so its weights
    # equal the synced gateways' pre-measurement state.
    server = fresh_server()
    server.publish("lm", warmp, tag="v1.1")
    ref = _boot(cfg, server, params, quantized=True)
    warm = ref.submit(np.zeros(MAX_PROMPT, np.int32), license="free",
                      max_new_tokens=2)
    ref.run()                                    # compile outside timing
    assert warm.out_tokens
    ref_reqs = _submit_all(ref, n_reqs)
    ref.run()

    # ---- blocking baseline: the stall is the whole update in one step.
    # quantized=True makes the blocking cost realistic: delta-apply PLUS
    # whole-model requantize land between two scheduler steps.
    server = fresh_server()
    blocking = _boot(cfg, server, params, quantized=True)
    _warm(blocking, server, staged=False)
    v_before = blocking.version
    steps_b, sync_s, reqs_b = _drive(
        blocking, n_reqs, staged=False, server=server,
        publish=lambda: server.publish("lm", newp, tag="v2"))

    # ---- staged sync: bounded stager work rides along with decode
    server2 = fresh_server()
    staged = _boot(cfg, server2, params, quantized=True)
    _warm(staged, server2, staged=True)
    assert staged.version == v_before
    steps_s, _, reqs_s = _drive(
        staged, n_reqs, staged=True,
        publish=lambda: server2.publish("lm", newp, tag="v2"))

    # ---- claims ---------------------------------------------------------
    # token equivalence: in-flight requests never see the new weights
    for r, rr in zip(reqs_s, ref_reqs):
        assert r.out_tokens == rr.out_tokens, "staged flip broke pinning"
        assert r.version == v_before
    for r, rr in zip(reqs_b, ref_reqs):
        assert r.out_tokens == rr.out_tokens, "blocking sync broke pinning"
    st = staged.metrics()["staged_update"]
    assert st["flips"] == 1 and staged.version == blocking.version
    # bounded bytes per stager step (+ one indivisible page; pages are
    # zlib-compressed and incompressible data can exceed raw size by a
    # few dozen bytes, plus 8 index bytes per page on the wire)
    page_bytes = CHUNK_ELEMS * 4 + 1024
    assert st["max_step_bytes_applied"] <= MAX_STEP_BYTES + page_bytes, st
    # the tentpole: no staged scheduler step carries the full update.
    # p99 with floor interpolation (~2nd-worst of ~50 steps) so a single
    # scheduler-step outlier from CI-container contention cannot flip
    # the verdict; the raw max is still reported below.
    stall_b = sync_s                              # the blocking stall
    stall_s = float(np.percentile(steps_s, 99, method="lower"))
    assert stall_s < stall_b, (stall_s, stall_b)
    # post-flip admission is warm: the hot tier was prewarmed
    misses = staged.views.misses
    post = staged.submit(np.random.default_rng(99).integers(
        0, 500, MAX_PROMPT, dtype=np.int32), license="free",
        max_new_tokens=2)
    staged.run()
    assert post.version == staged.version != v_before
    assert staged.views.misses == misses, "new-version view was cold"

    p99_b = float(np.percentile(steps_b, 99, method="lower"))
    rows = [
        {"name": "update/blocking_sync",
         "us_per_call": sync_s * 1e6,
         "decode_stall_p99_ms": round(p99_b * 1e3, 2),
         "decode_stall_max_ms": round(float(np.max(steps_b)) * 1e3, 2),
         "sync_stall_ms": round(stall_b * 1e3, 2),
         "steps": len(steps_b)},
        {"name": "update/staged_sync",
         "us_per_call": float(np.sum(steps_s)) * 1e6 / max(len(steps_s), 1),
         "decode_stall_p99_ms": round(stall_s * 1e3, 2),
         "decode_stall_max_ms": round(float(np.max(steps_s)) * 1e3, 2),
         "stall_vs_blocking_x": round(stall_b / max(stall_s, 1e-9), 1),
         "steps": len(steps_s),
         "stager_steps": st["steps"],
         "bytes_applied": st["bytes_applied"],
         "bytes_per_step_max": st["max_step_bytes_applied"],
         "max_step_bytes_bound": MAX_STEP_BYTES + page_bytes,
         "layers_requantized": st["layers_requantized"],
         "views_prewarmed": st["views_prewarmed"],
         "tokens_equivalent": True},
    ]
    return rows
