"""Serving-path benchmark: decode tokens/s for smoke-scale archs on CPU,
and the licensed-serving overhead (tier view materialization + masked
decode vs full decode) — the paper's one-model-many-tiers claim, measured.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_cache, init_params
from repro.serving import ServingEngine, Request, prefill_step, serve_step


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    for arch in ("qwen2.5-3b", "mamba2-130m", "deepseek-moe-16b"):
        cfg = smoke_variant(get_config(arch))
        params = init_params(key, cfg)
        b, prompt, cap = 4, 32, 64
        toks = jax.random.randint(key, (b, prompt), 0, cfg.vocab_size)
        cache = init_cache(cfg, b, cap)
        pre = jax.jit(lambda p, t, c: prefill_step(p, cfg, t, c))
        dec = jax.jit(lambda p, t, c, pos: serve_step(p, cfg, t, c, pos))
        logits, cache = pre(params, toks, cache)
        cur = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        logits, cache = dec(params, cur, cache, prompt)  # warm
        n = 16
        t0 = time.perf_counter()
        for i in range(n):
            logits, cache = dec(params, cur, cache, prompt + 1 + i)
        logits.block_until_ready()
        dt = (time.perf_counter() - t0) / n
        rows.append({"name": f"serve/decode_{arch}", "us_per_call": dt * 1e6,
                     "tokens_per_s": round(b / dt, 1)})

    # licensed serving: tier view cost + identical decode throughput
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(key, cfg)
    tier = LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})
    engine = ServingEngine(cfg, params, tiers={"free": tier})
    t0 = time.perf_counter()
    engine.params_for("free")
    view_dt = time.perf_counter() - t0
    reqs = [Request(prompt=np.arange(16, dtype=np.int32), max_new_tokens=4,
                    license=lic) for lic in ("full", "free")]
    t0 = time.perf_counter()
    engine.generate(reqs)
    gen_dt = time.perf_counter() - t0
    rows.append({"name": "serve/licensed_view_materialize",
                 "us_per_call": view_dt * 1e6})
    rows.append({"name": "serve/mixed_tier_generate_2x4tok",
                 "us_per_call": gen_dt * 1e6,
                 "full_tokens": reqs[0].out_tokens[:3],
                 "free_tokens": reqs[1].out_tokens[:3]})
    return rows
