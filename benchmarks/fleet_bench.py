"""Fleet serving vs isolated gateways: multi-model consolidation cost.

Measures the tentpole claim of ``FleetGateway``: serving three
heterogeneous models behind ONE submit/step/run loop (round-robin
micro-batches, global byte-denominated cache budget, shared tenant
enforcement) costs almost nothing versus running three isolated
``LicensedGateway``\\ s back to back at equal total cache memory — the
fleet only interleaves slots, every slot still runs its own unmodified
micro-batches.

Workload: three smoke configs (GQA transformer, pure SSM, sliding-window
hybrid) x ``REQS_PER_MODEL`` requests with heterogeneous decode lengths.
The fleet arm gets ``cache_budget_bytes`` equal to the summed paged-pool
bytes of the isolated arm, so total cache memory is identical and the
budget is live (gating) but exactly as roomy as the isolated pools.

Reported rows (asserted bars noted inline):
  * ``fleet/isolated_gateways_total`` — three gateways drained one after
    another (the no-fleet deployment: one process per model).
  * ``fleet/fleet_gateway_total``     — one FleetGateway draining the
    same workload; ``throughput_ratio`` asserted >= 0.9 in the full run
    (the smoke lane records it without asserting — tiny-model timing is
    noise-dominated).
  * Cross-model logit drift: every fleet request's tokens are asserted
    bit-identical to its isolated-gateway twin, both runs.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_params
from repro.serving import FleetGateway, LicensedGateway, RequestState

MODELS = ("qwen2.5-3b", "mamba2-130m", "recurrentgemma-2b")
PROMPT_LEN = 8
MAX_BATCH = 4
NEW_TOKENS = (4, 8, 12, 16)      # heterogeneous decode lengths
TIERS = {"free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})}


def _slot_kw():
    return dict(tiers=dict(TIERS), max_batch=MAX_BATCH,
                max_prompt=PROMPT_LEN, max_new_cap=max(NEW_TOKENS))


def _workload(rng, reqs_per_model):
    jobs = []
    for name in MODELS:
        for i in range(reqs_per_model):
            jobs.append((name,
                         rng.integers(0, 500, PROMPT_LEN, dtype=np.int32),
                         NEW_TOKENS[i % len(NEW_TOKENS)],
                         "free" if i % 2 else "full"))
    return jobs


def run(smoke: bool = False) -> list:
    reqs_per_model = 4 if smoke else 8
    setups = {}
    for i, name in enumerate(MODELS):
        cfg = smoke_variant(get_config(name))
        setups[name] = (cfg, init_params(jax.random.PRNGKey(i), cfg))
    rng = np.random.default_rng(0)
    jobs = _workload(rng, reqs_per_model)
    total_tokens = sum(n for _, _, n, _ in jobs)

    # warm every config's compiled paths (lru-shared across instances)
    for name, (cfg, params) in setups.items():
        warm = LicensedGateway(cfg, params, model=name, **_slot_kw())
        for lic in ("full", "free"):
            warm.submit(jobs[0][1], license=lic, max_new_tokens=2)
        warm.run()

    # ---- isolated arm: one gateway per model, drained back to back
    isolated_tokens = {}
    dt_isolated = 0.0
    pool_bytes = 0
    for name, (cfg, params) in setups.items():
        gw = LicensedGateway(cfg, params, model=name, **_slot_kw())
        if gw.paged:
            pool_bytes += gw.pool.num_blocks * gw.pool.block_bytes
        t0 = time.perf_counter()
        reqs = [(prompt, gw.submit(prompt, license=lic, max_new_tokens=n))
                for m, prompt, n, lic in jobs if m == name]
        gw.run()
        dt_isolated += time.perf_counter() - t0
        assert all(r.state == RequestState.DONE for _, r in reqs)
        isolated_tokens[name] = [r.out_tokens for _, r in reqs]

    # ---- fleet arm: one gateway, equal total cache memory (the budget
    # covers exactly the isolated pools' bytes, so it is live but fair)
    fleet = FleetGateway(cache_budget_bytes=pool_bytes)
    for name, (cfg, params) in setups.items():
        fleet.add_model(name, cfg, params, **_slot_kw())
    t0 = time.perf_counter()
    freqs = [(m, fleet.submit(m, prompt, license=lic, max_new_tokens=n))
             for m, prompt, n, lic in jobs]
    fleet.run()
    dt_fleet = time.perf_counter() - t0
    assert all(r.state == RequestState.DONE for _, r in freqs)

    # no cross-model logit drift: fleet tokens == isolated tokens, per
    # request, bit for bit
    for name in MODELS:
        got = [r.out_tokens for m, r in freqs if m == name]
        assert got == isolated_tokens[name], \
            f"{name}: fleet tokens drifted from isolated gateway"

    tps_isolated = total_tokens / dt_isolated
    tps_fleet = total_tokens / dt_fleet
    ratio = tps_fleet / tps_isolated
    m = fleet.metrics()
    rows = [
        {"name": "fleet/isolated_gateways_total",
         "us_per_call": dt_isolated * 1e6,
         "tokens_per_s": round(tps_isolated, 1),
         "models": len(MODELS), "requests": len(jobs),
         "cache_bytes": pool_bytes},
        {"name": "fleet/fleet_gateway_total",
         "us_per_call": dt_fleet * 1e6,
         "tokens_per_s": round(tps_fleet, 1),
         "throughput_ratio": round(ratio, 3),
         "models": len(MODELS), "requests": len(jobs),
         "cache_budget_bytes": pool_bytes,
         "fleet_steps": m["fleet"]["steps"],
         "logit_drift": False,
         "bound_asserted": not smoke},
    ]
    # the claims the ISSUE pins: equal total cache memory, zero drift
    # (asserted above), and consolidation costing < 10% throughput
    if not smoke:
        assert ratio >= 0.9, (tps_fleet, tps_isolated)
    return rows
