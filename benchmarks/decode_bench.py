"""Kernel-resident vs gather/scatter paged decode: bytes moved & tokens/s.

The tentpole claim of the kernel-resident decode path: the per-step
gather -> vmapped step -> scatter round trip of each lane's FULL logical
cache (O(capacity) HBM bytes per generated token) is replaced by a step
that reads each cache byte once through the (trimmed) block table and
writes exactly ONE K/V token per lane through its block index — decode
moves O(context) bytes where it moved O(capacity) several times over,
and at long contexts that is the dominant per-token cost.

Both paths run the same ≥512-token-context workload through the same
gateway (same prefill, same pool, same sampling); the decode phase is
timed per scheduler step so prefill cost never pollutes the comparison.
Cache bytes per step are computed analytically from the pool geometry:

  gather/scatter:   B * padded_capacity * token_bytes * 2   (materialize
                    the view + write it back) + the attention read of the
                    padded view (B * padded_capacity * token_bytes)
  kernel-resident:  B * context * token_bytes (the attention read IS the
                    table gather) + B * token_bytes (the one-token write)

Reported rows (all asserted — the ISSUE's acceptance bar):
  * ``decode/gather_scatter_total``   — decode-phase wall time, tokens/s,
    analytic cache bytes per step.
  * ``decode/kernel_resident_total``  — same stream, kernel-resident:
    strictly fewer bytes per step AND higher tokens/s at >=512-token
    contexts.
  * ``decode/logit_equivalence``      — max |Δlogits| between the paths
    over full generations (asserted <= 1e-5), identical tokens.
  * ``decode/paged_write_kernel``     — Pallas block-indexed write kernel
    vs its ``ref.py`` oracle, interpret mode (asserted exact).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.kernels import ref
from repro.kernels.paged_attention import paged_decode_write
from repro.models import init_params
from repro.serving import LicensedGateway

ARCH = "qwen2.5-3b"
MAX_PROMPT = 512                 # >= 512-token contexts throughout decode
BLOCK = 64
MAX_BATCH = 4


def _mk_gateway(cfg, params, tiers, *, kernel_decode, max_new_cap, **kw):
    return LicensedGateway(
        cfg, params, tiers=tiers, max_batch=MAX_BATCH,
        max_prompt=MAX_PROMPT, max_new_cap=max_new_cap, block_size=BLOCK,
        kernel_decode=kernel_decode, prefix_cache=False, **kw)


def _drain_timed(gw, work):
    """Submit + drain, timing the decode phase per scheduler step."""
    reqs = [gw.submit(p, license="free", max_new_tokens=n) for p, n in work]
    t_decode, decode_steps = 0.0, 0
    while True:
        t0 = time.perf_counter()
        act = gw.step()
        dt = time.perf_counter() - t0
        if act is None:
            break
        if act.kind == "decode":
            t_decode += dt
            decode_steps += 1
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs), \
        [r.error for r in reqs]
    return reqs, t_decode, decode_steps


def _cache_token_bytes(pool):
    """Per-token cache bytes summed over the pool's paged leaves."""
    total = 0
    for arr, (paged, _, _) in zip(pool._storage, pool._meta):
        if paged:
            total += arr.nbytes // (pool.num_blocks + 1) // pool.block_size
    return total


def run(smoke: bool = False) -> list:
    cfg = smoke_variant(get_config(ARCH))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {"free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})}
    rng = np.random.default_rng(0)
    max_new = 8 if smoke else 24
    n_reqs = MAX_BATCH if smoke else 2 * MAX_BATCH
    work = [(rng.integers(0, 500, MAX_PROMPT, dtype=np.int32), max_new)
            for _ in range(n_reqs)]
    total_new = sum(n for _, n in work)

    results = {}
    for kernel in (False, True):
        mk = dict(kernel_decode=kernel, max_new_cap=max_new)
        _drain_timed(_mk_gateway(cfg, params, tiers, **mk),
                     work[:MAX_BATCH])                  # warm the jit paths
        gw = _mk_gateway(cfg, params, tiers, **mk)
        assert gw.kernel_decode is kernel
        _, t_decode, steps = _drain_timed(gw, work)
        tok_bytes = _cache_token_bytes(gw.pool)
        b = MAX_BATCH
        if kernel:
            # attention read of the used blocks + the one-token write;
            # contexts span [MAX_PROMPT, MAX_PROMPT + max_new), so use the
            # mean used width (rounded up to whole blocks, as read)
            used = -(-(MAX_PROMPT + max_new // 2) // BLOCK) * BLOCK
            bytes_step = b * used * tok_bytes + b * tok_bytes
        else:
            # materialize the padded view + attention read + write-back
            bytes_step = 3 * b * gw.pool.padded_capacity * tok_bytes
        results[kernel] = dict(
            t=t_decode, steps=steps, bytes_step=bytes_step,
            tokens_per_s=total_new / t_decode,
            resident_steps=gw.stats["resident_decode_steps"])

    base, resident = results[False], results[True]
    assert base["resident_steps"] == 0
    assert resident["resident_steps"] == resident["steps"]
    # the acceptance bar: strictly fewer cache bytes per decode step
    # (deterministic, analytic), and faster decode at >=512-token
    # contexts.  The wall-clock half is asserted only in the full run —
    # the smoke lane's ~8-step sample on a shared CI runner is too noisy
    # to gate a merge on (tokens/s is still reported for the artifact).
    assert resident["bytes_step"] < base["bytes_step"], \
        (resident["bytes_step"], base["bytes_step"])
    if not smoke:
        assert resident["tokens_per_s"] > base["tokens_per_s"], \
            (resident["tokens_per_s"], base["tokens_per_s"])

    # ---- logit equivalence over full generations, both sampling modes
    eq_new = 4
    streams = []
    for kernel in (False, True):
        gw = _mk_gateway(cfg, params, tiers, kernel_decode=kernel,
                         max_new_cap=eq_new, record_logits=True)
        reqs = [gw.submit(p, license="free", max_new_tokens=eq_new)
                for p, _ in work[:MAX_BATCH]]
        gw.run()
        streams.append(reqs)
    max_err = 0.0
    for a, b_ in zip(*streams):
        assert a.out_tokens == b_.out_tokens
        for ra, rb in zip(a.logits_rows, b_.logits_rows):
            max_err = max(max_err, float(np.max(np.abs(ra - rb))))
    assert max_err <= 1e-5, max_err

    # ---- Pallas block-indexed write kernel vs its oracle (interpret)
    r = np.random.default_rng(5)
    p_blocks, bs, kh, hd, b = 12, 16, 2, 64, 5
    kb = jnp.asarray(r.standard_normal((p_blocks, bs, kh, hd)), jnp.float32)
    vb = jnp.asarray(r.standard_normal((p_blocks, bs, kh, hd)), jnp.float32)
    nk = jnp.asarray(r.standard_normal((b, kh, hd)), jnp.float32)
    nv = jnp.asarray(r.standard_normal((b, kh, hd)), jnp.float32)
    blocks = jnp.asarray(r.permutation(p_blocks)[:b], jnp.int32)
    offs = jnp.asarray(r.integers(0, bs, b), jnp.int32)
    t0 = time.perf_counter()
    gk, gv = paged_decode_write(kb, vb, nk, nv, blocks, offs, interpret=True)
    dt_kernel = time.perf_counter() - t0
    rk, rv = ref.paged_decode_write(kb, vb, nk, nv, blocks, offs)
    kerr = max(float(np.max(np.abs(np.asarray(gk) - np.asarray(rk)))),
               float(np.max(np.abs(np.asarray(gv) - np.asarray(rv)))))
    assert kerr == 0.0, kerr

    ctx = f"[{MAX_PROMPT}, {MAX_PROMPT + max_new})"
    return [
        {"name": "decode/gather_scatter_total",
         "us_per_call": base["t"] * 1e6 / max(1, base["steps"]),
         "tokens_per_s": round(base["tokens_per_s"], 1),
         "decode_steps": base["steps"],
         "cache_bytes_per_step": base["bytes_step"], "contexts": ctx},
        {"name": "decode/kernel_resident_total",
         "us_per_call": resident["t"] * 1e6 / max(1, resident["steps"]),
         "tokens_per_s": round(resident["tokens_per_s"], 1),
         "decode_steps": resident["steps"],
         "cache_bytes_per_step": resident["bytes_step"], "contexts": ctx,
         "speedup_x": round(resident["tokens_per_s"]
                            / base["tokens_per_s"], 2),
         "bytes_ratio": round(base["bytes_step"]
                              / resident["bytes_step"], 2)},
        {"name": "decode/logit_equivalence", "us_per_call": 0.0,
         "max_abs_err": max_err, "requests": MAX_BATCH,
         "new_tokens_each": eq_new},
        {"name": "decode/paged_write_kernel",
         "us_per_call": dt_kernel * 1e6, "max_abs_err_vs_ref": kerr,
         "interpret": True},
    ]
