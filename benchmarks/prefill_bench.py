"""Chunked prefill: bounded decode stalls + length-independent reuse.

The tentpole claim of left-aligned chunked prefill: admitting a long
prompt no longer freezes running decodes for the whole prompt — the
scheduler alternates one chunk with one decode step, so the worst-case
inter-token gap a decode lane sees is (one decode step + one chunk
step), not (one decode step + the entire prompt's prefill).  The chunk
step itself is trimmed to O(context) bytes (vmap width and gathered
table columns bucketed to powers of two), so a chunk costs no more than
the decode step it interleaves with.

``chunk_size`` is the latency SLO knob: halving it halves the stall a
chunk injects between two decode steps (and doubles the number of
chunks a prompt needs).  This bench pins it to one block.

Three phases, one gateway geometry (long-context decode lanes so the
baseline is honest — a decode step over three 4k-context lanes, not an
idle gateway):

  1. *Baseline*: three decode lanes at full ``N``-token context tick
     with no prefill in flight; per-token gaps are timed.
  2. *Concurrent*: a fresh ``N``-token prompt is submitted and chunks
     to completion while the same lanes keep decoding; gaps between
     consecutive decode steps now include one interleaved chunk each.
     Acceptance (full run): floor-interpolated p99 concurrent gap
     <= 2x the baseline p99.
  3. *Cross-length reuse*: two prompts share a block-aligned head but
     have different-length tails; the radix cache (keyed on true token
     ids, not padded buckets) must hand the second request the shared
     blocks with zero copy-on-write.

Reported rows (asserted bars noted inline):
  * ``prefill/decode_only_baseline``  — median/p99 inter-token gap.
  * ``prefill/concurrent_prefill``    — same, while the prompt chunks;
    p99 ratio vs baseline asserted <= 2.0 in the full run (the smoke
    lane's small sample on a shared CI runner is too noisy to gate on).
  * ``prefill/cross_length_reuse``    — reused prefix tokens > 0 across
    different prompt lengths, cow_copies == 0 (asserted both lanes).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_params
from repro.serving import LicensedGateway, RequestState

ARCH = "qwen2.5-3b"
BLOCK = 64
CHUNK = 64                       # the SLO knob: stall <= one 64-token chunk
MAX_BATCH = 4
N_DECODERS = 3


def _mk_gateway(cfg, params, tiers, *, max_prompt, max_new_cap):
    return LicensedGateway(
        cfg, params, tiers=tiers, max_batch=MAX_BATCH,
        max_prompt=max_prompt, max_new_cap=max_new_cap, block_size=BLOCK,
        chunk_size=CHUNK)


def _scenario(gw, n_ctx, window, rng):
    """Run baseline + concurrent phases; return (base_ts, conc_ts, chunks).

    ``base_ts``/``conc_ts`` are wall-clock timestamps of consecutive
    decode steps in each phase — their diffs are the inter-token gaps a
    streaming client observes.
    """
    prompts = [rng.integers(0, 500, n_ctx, dtype=np.int32)
               for _ in range(N_DECODERS + 1)]
    chunks_needed = -(-n_ctx // CHUNK)
    # decoders must outlive: baseline window + one decode per chunk of
    # the concurrent prompt (strict alternation) + drain slack
    max_new = window + chunks_needed + 8
    decoders = [gw.submit(p, license="free", max_new_tokens=max_new)
                for p in prompts[:N_DECODERS]]
    while not all(r.state is RequestState.RUNNING for r in decoders):
        assert gw.step() is not None
    base_ts = [time.perf_counter()]
    while len(base_ts) <= window:
        act = gw.step()
        assert act is not None and act.kind == "decode"
        base_ts.append(time.perf_counter())
    chunks0 = gw.stats["prefill_chunks"]
    long_req = gw.submit(prompts[-1], license="free", max_new_tokens=4)
    conc_ts = []
    while long_req.state in (RequestState.QUEUED, RequestState.PREFILLING):
        act = gw.step()
        assert act is not None
        # the measured gaps are decode-to-decode (each one includes the
        # chunk step interleaved between them); the decoders must not
        # drain before the prompt finishes chunking
        assert any(r.state is RequestState.RUNNING for r in decoders)
        if act.kind == "decode":
            conc_ts.append(time.perf_counter())
    assert long_req.state is RequestState.RUNNING
    chunks = gw.stats["prefill_chunks"] - chunks0
    assert chunks >= chunks_needed, (chunks, chunks_needed)
    gw.run()                               # drain the tail
    assert all(r.state is RequestState.DONE for r in decoders)
    return np.diff(base_ts), np.diff(conc_ts), chunks


def _p99(gaps):
    return float(np.percentile(gaps, 99, method="lower"))


def run(smoke: bool = False) -> list:
    cfg = smoke_variant(get_config(ARCH))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {"free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})}
    rng = np.random.default_rng(0)

    n_ctx = 1024 if smoke else 4096
    window = 12 if smoke else 32
    chunks_needed = -(-n_ctx // CHUNK)
    max_new_cap = window + chunks_needed + 16
    mk = dict(max_prompt=n_ctx, max_new_cap=max_new_cap)

    # warm EVERY jit specialization the measured run will hit — the
    # chunk step compiles per pow2 (lanes, table-cols) bucket, and one
    # compile inside the measured window would dominate p99
    _scenario(_mk_gateway(cfg, params, tiers, **mk), n_ctx, window, rng)
    gw = _mk_gateway(cfg, params, tiers, **mk)
    base, conc, chunks = _scenario(gw, n_ctx, window,
                                   np.random.default_rng(0))
    p99_base, p99_conc = _p99(base), _p99(conc)
    ratio = p99_conc / p99_base
    if not smoke:
        # the ISSUE's acceptance bar: a decode lane's p99 inter-token
        # gap while a 4k prompt chunks concurrently stays within 2x the
        # no-prefill baseline
        assert ratio <= 2.0, (p99_conc, p99_base, ratio)

    # ---- cross-length prefix reuse: shared head, different tails ----
    gw2 = LicensedGateway(cfg, params, tiers=tiers, max_batch=2,
                          max_prompt=512, max_new_cap=16, block_size=BLOCK,
                          chunk_size=CHUNK)
    # block-aligned lengths: a partial tail block is donated to the
    # radix too (exact-duplicate hits) at the cost of one CoW on the
    # first decode write — aligned tails are the zero-CoW case the
    # tentpole claims, so that is what this row asserts
    head = rng.integers(0, 500, 4 * BLOCK, dtype=np.int32)
    lens = (5 * BLOCK, 7 * BLOCK)
    reused = []
    for n in lens:
        tail = rng.integers(0, 500, n - len(head), dtype=np.int32)
        r = gw2.submit(np.concatenate([head, tail]), license="free",
                       max_new_tokens=4)
        gw2.run()
        assert r.state is RequestState.DONE
        reused.append(r.prefix_tokens)
    pm = gw2.metrics()["prefix_cache"]
    assert reused[1] == len(head), reused     # full aligned head adopted
    assert pm["prefix_tokens_reused"] >= len(head)
    assert pm["cow_copies"] == 0, pm          # aligned tails never CoW
    assert gw2.metrics()["chunked_prefill"]["enabled"]

    us = 1e6
    return [
        {"name": "prefill/decode_only_baseline",
         "us_per_call": float(np.median(base)) * us,
         "p99_gap_us": round(p99_base * us, 1),
         "decode_steps": len(base), "context": n_ctx,
         "decode_lanes": N_DECODERS},
        {"name": "prefill/concurrent_prefill",
         "us_per_call": float(np.median(conc)) * us,
         "p99_gap_us": round(p99_conc * us, 1),
         "p99_ratio_vs_baseline": round(ratio, 3),
         "prompt_tokens": n_ctx, "chunk_size": CHUNK,
         "prefill_chunks": chunks,
         "bound_asserted": not smoke},
        {"name": "prefill/cross_length_reuse",
         "us_per_call": 0.0,
         "shared_head_tokens": len(head), "prompt_lens": list(lens),
         "prefix_tokens_reused": int(pm["prefix_tokens_reused"]),
         "cow_copies": int(pm["cow_copies"])},
    ]
