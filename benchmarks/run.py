"""Benchmark harness — one module per paper table/claim + framework benches.

Prints ``name,us_per_call,derived...`` CSV rows.  Usage:
  PYTHONPATH=src python -m benchmarks.run [--only storage,licensing,...]
  PYTHONPATH=src python -m benchmarks.run --smoke       # CI smoke lane
  PYTHONPATH=src python -m benchmarks.run --json out/   # machine-readable

``--smoke`` runs every suite at reduced scale (suites whose ``run``
accepts a ``smoke`` kwarg shrink their workloads) so CI can assert the
perf scripts still execute end to end without burning minutes.

``--json DIR`` additionally writes one ``BENCH_<suite>.json`` per suite
(full row dicts plus run metadata) so the perf trajectory is tracked as
an artifact across PRs instead of scraped from CI logs.
"""
from __future__ import annotations

import argparse
import inspect
import json
import pathlib
import sys
import time
import traceback

SUITES = ("storage", "update-wire", "licensing", "kernels", "serving",
          "gateway", "paging", "prefix", "decode", "update", "prefill",
          "fleet", "telemetry", "chaos", "roofline")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help=f"comma list from {SUITES}")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced-scale run for CI (suites may shrink "
                         "workloads; all assertions still fire)")
    ap.add_argument("--json", default=None, metavar="DIR",
                    help="also write BENCH_<suite>.json result files "
                         "into DIR (created if missing)")
    args = ap.parse_args(argv)
    picked = args.only.split(",") if args.only else list(SUITES)
    json_dir = None
    if args.json is not None:
        json_dir = pathlib.Path(args.json)
        json_dir.mkdir(parents=True, exist_ok=True)

    from benchmarks import (chaos_bench, decode_bench, fleet_bench,
                            gateway_bench, kernel_bench, licensing_ladder,
                            paging_bench, prefill_bench, prefix_bench,
                            roofline_table, serving_bench, storage_cost,
                            telemetry_bench, update_bench, update_latency)

    modules = {
        "storage": storage_cost,        # paper Table 1
        "update-wire": update_latency,  # paper §4.3 bytes-on-the-wire
        "licensing": licensing_ladder,  # paper §3.5 / Algorithm 1
        "kernels": kernel_bench,
        "serving": serving_bench,
        "gateway": gateway_bench,       # continuous batching vs single-stream
        "paging": paging_bench,         # block-paged vs fixed-lane cache pool
        "prefix": prefix_bench,         # shared-prefix radix cache vs paged
        "decode": decode_bench,         # kernel-resident vs gather/scatter
        "update": update_bench,         # staged sync vs blocking decode stall
        "prefill": prefill_bench,       # chunked prefill decode-stall SLO
        "fleet": fleet_bench,           # multi-model fleet vs isolated
        "telemetry": telemetry_bench,   # observability <3% overhead gate
        "chaos": chaos_bench,           # fault-schedule stall + equivalence
        "roofline": roofline_table,     # deliverable (g)
    }

    failures = 0
    print("name,us_per_call,derived")
    for name in picked:
        mod = modules[name]
        kw = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kw["smoke"] = True
        try:
            rows = list(mod.run(**kw))
            for row in rows:
                derived = {k: v for k, v in row.items()
                           if k not in ("name", "us_per_call")}
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      + json.dumps(derived, default=str))
            if json_dir is not None:
                out = {"suite": name, "smoke": bool(args.smoke),
                       "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
                       "rows": rows}
                (json_dir / f"BENCH_{name}.json").write_text(
                    json.dumps(out, indent=2, default=str) + "\n")
        except Exception:  # noqa: BLE001 — report all suites
            failures += 1
            print(f"{name},FAILED,", file=sys.stdout)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
