"""Low-latency-update benchmark (paper §3.1.2 / §4.3): bytes + time for a
delta update vs a full re-download, across change fractions, including the
skip-intermediate-patches query (§4.2)."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core.protocol import EdgeClient, LicenseServer
from repro.core.weightstore import WeightStore
from repro.configs.paper_mlp import TABLE1_A
from repro.training import init_mlp_params


def run() -> list:
    rows = []
    key = jax.random.PRNGKey(0)
    from repro.core import flatten_params

    params = flatten_params(jax.device_get(init_mlp_params(key, TABLE1_A)))
    rng = np.random.default_rng(0)

    for frac in (0.001, 0.01, 0.1, 1.0):
        store = WeightStore(":memory:")
        store.register_model("m", "mlp")
        server = LicenseServer(store)
        v1 = server.publish("m", params)
        client = EdgeClient("m", {k: np.zeros_like(np.asarray(v))
                                  for k, v in params.items()})
        first = client.request_update(server)

        new = {k: np.array(v, copy=True) for k, v in params.items()}
        for k in new:
            flat = new[k].reshape(-1)
            n = max(1, int(frac * flat.size))
            idx = rng.choice(flat.size, n, replace=False)
            flat[idx] += 0.5
        server.publish("m", new, parent=v1)

        t0 = time.perf_counter()
        packet = client.request_update(server)
        dt = time.perf_counter() - t0
        rows.append({
            "name": f"update/delta_frac_{frac}",
            "us_per_call": dt * 1e6,
            "delta_bytes": packet.nbytes,
            "full_bytes": first.nbytes,
            "savings_x": round(first.nbytes / max(packet.nbytes, 1), 1),
            "entries": packet.num_entries,
        })
        store.close()

    # skip-intermediate-patches: 5 server versions, one client pull (§4.2)
    store = WeightStore(":memory:")
    store.register_model("m", "mlp")
    server = LicenseServer(store)
    v = server.publish("m", params)
    client = EdgeClient("m", {k: np.zeros_like(v) for k, v in params.items()})
    client.request_update(server)
    cur = params
    total_patch_bytes = 0
    for step in range(5):
        cur = {k: np.array(v, copy=True) for k, v in cur.items()}
        flat = cur["layer1/kernel"].reshape(-1)
        idx = rng.choice(flat.size, 100, replace=False)
        flat[idx] += 0.1
        server.publish("m", cur)
        total_patch_bytes += 100 * 12
    t0 = time.perf_counter()
    packet = client.request_update(server)
    dt = time.perf_counter() - t0
    rows.append({
        "name": "update/skip_5_patches",
        "us_per_call": dt * 1e6,
        "combined_bytes": packet.nbytes,
        "entries": packet.num_entries,
        "note": "<=500 entries since repeated indices collapse",
    })
    store.close()
    return rows
