"""Quantized licensed serving (serving/quantized.py) + hlo_cost model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier, apply_license
from repro.models import forward, init_cache, init_params
from repro.serving.quantized import (
    dequant_tree,
    is_qleaf,
    quantize_serving_params,
    tier_intervals,
)


@pytest.fixture(scope="module")
def setup():
    key = jax.random.PRNGKey(0)
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    return cfg, params, toks


def test_quantize_roundtrip_close(setup):
    cfg, params, toks = setup
    qp = quantize_serving_params(params)
    # structure preserved; eligible leaves became q-dicts
    q_leaves = [l for l in jax.tree_util.tree_leaves(
        qp, is_leaf=is_qleaf) if is_qleaf(l)]
    assert len(q_leaves) > 0
    for l in q_leaves:
        assert l["codes"].dtype == jnp.int8
    back = dequant_tree(qp, None, cfg.dtype)
    w0 = params["units"]["b0"]["mixer"]["wq"]
    w1 = back["units"]["b0"]["mixer"]["wq"]
    # per-channel int8: error bounded by half a step
    step = np.abs(np.asarray(w0, np.float32)).max(axis=-2, keepdims=True) / 127
    assert (np.abs(np.asarray(w1, np.float32) - np.asarray(w0, np.float32))
            <= step + 1e-6).all()


def test_quantized_forward_close_to_full(setup):
    cfg, params, toks = setup
    ref, _, _ = forward(params, cfg, toks)
    qout, _, _ = forward(quantize_serving_params(params), cfg, toks)
    corr = float(jnp.corrcoef(qout.reshape(-1), ref.reshape(-1))[0, 1])
    assert corr > 0.999


def test_fused_license_matches_mask_at_load(setup):
    """Fused in-scan masked-dequant == paper's mask-at-load on the same
    scope (the fused path licenses quantized BLOCK weights; embed/lm_head
    stay full — scope the oracle identically)."""
    from repro.serving.quantized import _eligible
    from repro.core.pytree_io import flatten_params

    cfg, params, toks = setup
    tier = LicenseTier(name="free", masks={"*": ((0.0, 0.003),)})
    qp = quantize_serving_params(params)
    deq = dequant_tree(qp, None, cfg.dtype)
    flat = flatten_params(params)

    def exclude(name):  # mask exactly what the fused path masks
        return not (name in flat and _eligible(name, flat[name]))

    masked_at_load = apply_license(deq, tier, exclude=exclude)
    ref, _, _ = forward(masked_at_load, cfg, toks)
    fused, _, _ = forward(qp, cfg, toks, license_intervals=tier_intervals(tier))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_quantized_decode_consistency(setup):
    cfg, params, toks = setup
    qp = quantize_serving_params(params)
    li = tier_intervals(LicenseTier(name="f", masks={"*": ((0.0, 0.002),)}))
    ref, _, _ = forward(qp, cfg, toks, license_intervals=li)
    cache = init_cache(cfg, 2, 16)
    pre, _, cache = forward(qp, cfg, toks[:, :15], cache=cache, pos=0,
                            license_intervals=li)
    dec, _, _ = forward(qp, cfg, toks[:, 15:16], cache=cache, pos=15,
                        license_intervals=li)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(ref[:, 15]),
                               rtol=2e-3, atol=2e-3)


def test_no_full_precision_weights_in_tree(setup):
    """Security property (§3.5): unlicensed full-precision weights never
    exist in a quantized serving tree."""
    cfg, params, _ = setup
    qp = quantize_serving_params(params)

    def check(path, leaf):
        if is_qleaf(leaf):
            return
        if hasattr(leaf, "ndim") and leaf.ndim >= 3 and not isinstance(leaf, dict):
            name = "/".join(str(getattr(p, "key", p)) for p in path)
            # only norms/biases/conv/embeds may remain float
            assert any(k in name for k in
                       ("norm", "bias", "conv", "bq", "bk", "bv", "A_log",
                        "dt_bias", "D_skip", "a_param")), name

    jax.tree_util.tree_map_with_path(check, qp, is_leaf=is_qleaf)


# ------------------------------------------------------- hlo_cost model
def test_hlo_cost_scan_equals_unrolled():
    from repro.launch import hlo_cost

    def body(x, w):
        return jnp.dot(x, w), ()

    def scanned(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(y)

    def unrolled(x, ws):
        for i in range(8):
            x = jnp.dot(x, ws[i])
        return jnp.sum(x)

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
    cs = hlo_cost.analyze(jax.jit(scanned).lower(x, ws).compile().as_text())
    cu = hlo_cost.analyze(jax.jit(unrolled).lower(x, ws).compile().as_text())
    expect = 2.0 * 8 * 256**3
    assert cs.flops == cu.flops == expect


def test_hlo_cost_counts_nested_scans():
    from repro.launch import hlo_cost

    def inner(x, w):
        return jnp.dot(x, w), ()

    def outer(x, ws):
        def step(c, _):
            y, _ = jax.lax.scan(inner, c, ws)
            return y, ()
        y, _ = jax.lax.scan(step, x, None, length=3)
        return jnp.sum(y)

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((4, 128, 128), jnp.float32)
    c = hlo_cost.analyze(jax.jit(outer).lower(x, ws).compile().as_text())
    assert c.flops == 2.0 * 3 * 4 * 128**3
