"""End-to-end client/server update protocol (paper §3.1.2, Fig. 2)."""
import numpy as np
import pytest

from repro.core import delta as delta_lib
from repro.core.licensing import LicenseTier
from repro.core.protocol import EdgeClient, LicenseServer
from repro.core.weightstore import WeightStore


def params(seed=0):
    r = np.random.default_rng(seed)
    return {
        "l1/kernel": r.standard_normal((16, 32)).astype(np.float32),
        "l2/kernel": r.standard_normal((32, 8)).astype(np.float32),
    }


@pytest.fixture
def server():
    store = WeightStore(":memory:")
    store.register_model("prod", "mlp")
    return LicenseServer(store)


def zeros_like(p):
    return {k: np.zeros_like(v) for k, v in p.items()}


def test_first_update_ships_full_model(server):
    p = params()
    server.publish("prod", p)
    client = EdgeClient("prod", zeros_like(p))
    packet = client.request_update(server)
    assert client.version == packet.to_version
    np.testing.assert_allclose(client.params["l1/kernel"], p["l1/kernel"], rtol=1e-6)


def test_second_update_ships_only_delta(server):
    p = params()
    v1 = server.publish("prod", p)
    client = EdgeClient("prod", zeros_like(p))
    first = client.request_update(server)

    p2 = {k: v.copy() for k, v in p.items()}
    p2["l2/kernel"][0, :4] += 1.0
    server.publish("prod", p2, parent=v1)
    second = client.request_update(server)

    assert second.num_entries == 4           # only the 4 changed weights
    assert second.nbytes < first.nbytes / 10  # low-latency update, §4.3
    np.testing.assert_allclose(client.params["l2/kernel"], p2["l2/kernel"], rtol=1e-6)


def test_skipped_patches_one_packet(server):
    p = params()
    server.publish("prod", p)
    client = EdgeClient("prod", zeros_like(p))
    client.request_update(server)
    # three server-side versions while the client is offline
    cur = p
    for step in range(3):
        cur = {k: v.copy() for k, v in cur.items()}
        cur["l1/kernel"][step, step] = float(step + 10)
        server.publish("prod", cur)
    packet = client.request_update(server)
    assert client.updates == 2  # one initial + ONE combined update
    assert packet.num_entries == 3
    np.testing.assert_allclose(client.params["l1/kernel"], cur["l1/kernel"], rtol=1e-6)


def test_license_masks_applied_server_side(server):
    p = params(7)
    server.publish("prod", p)
    tier = LicenseTier(name="free", masks={"l1": ((0.5, 0.8),)}, accuracy=0.7)
    server.publish_tier("prod", tier)

    free = EdgeClient("prod", zeros_like(p), license_name="free")
    free.request_update(server)
    got = free.params["l1/kernel"]
    mag = np.abs(p["l1/kernel"])
    banned = (mag >= 0.5) & (mag < 0.8)
    assert banned.any()
    assert (got[banned] == 0).all()          # unlicensed weights never shipped
    np.testing.assert_allclose(got[~banned], p["l1/kernel"][~banned], rtol=1e-6)

    paid = EdgeClient("prod", zeros_like(p), license_name="full")
    paid.request_update(server)
    np.testing.assert_allclose(paid.params["l1/kernel"], p["l1/kernel"], rtol=1e-6)


def test_rollback_pushes_old_weights(server):
    p = params()
    v1 = server.publish("prod", p)
    client = EdgeClient("prod", zeros_like(p))
    client.request_update(server)
    p2 = {k: v * 2 for k, v in p.items()}
    server.publish("prod", p2, parent=v1)
    client.request_update(server)
    server.store.rollback("prod", v1)
    client.request_update(server)
    assert client.version == v1
    np.testing.assert_allclose(client.params["l1/kernel"], p["l1/kernel"], rtol=1e-6)


def test_shard_delta_partitions_by_range():
    old = params(1)
    new = {k: v.copy() for k, v in old.items()}
    new["l1/kernel"][:, :] += 1.0  # all 512 entries change
    packet = delta_lib.encode_delta(old, new)
    size = old["l1/kernel"].size
    half0 = delta_lib.shard_delta(packet, {"l1/kernel": (0, size // 2)})
    half1 = delta_lib.shard_delta(packet, {"l1/kernel": (size // 2, size)})
    n0 = sum(len(d.indices) for d in half0.deltas if d.layer == "l1/kernel")
    n1 = sum(len(d.indices) for d in half1.deltas if d.layer == "l1/kernel")
    assert n0 + n1 == size
    assert half0.nbytes + half1.nbytes <= packet.nbytes + 16  # no duplication


def test_update_log_records_bytes(server):
    p = params()
    server.publish("prod", p)
    client = EdgeClient("prod", zeros_like(p))
    client.request_update(server)
    assert len(server.log) == 1
    assert server.log[0].bytes_sent == client.bytes_downloaded > 0
