"""Per-architecture smoke tests: reduced same-family variants (<=2 pattern
cycles of layers, d_model<=512, <=4 experts) run one forward + one train
step on CPU, asserting output shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, smoke_variant
from repro.models import forward, init_cache, init_params
from repro.training import OptimizerConfig, make_train_step
from repro.training import optimizer as opt_lib

BATCH, SEQ = 2, 32


def _inputs(cfg, key, seq=SEQ):
    toks = jax.random.randint(key, (BATCH, seq), 0, cfg.vocab_size)
    kw = {}
    if cfg.frontend == "vision":
        kw["patch_embeds"] = jax.random.normal(
            key, (BATCH, cfg.num_patches, cfg.d_model), jnp.float32) * 0.1
    return toks, kw


@pytest.fixture(scope="module")
def rngkey():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_shapes_and_finite(arch, rngkey):
    cfg = smoke_variant(get_config(arch))
    params = init_params(rngkey, cfg)
    toks, kw = _inputs(cfg, rngkey)
    logits, aux, _ = forward(params, cfg, toks, **kw)
    extra = cfg.num_patches if cfg.frontend == "vision" else 0
    assert logits.shape == (BATCH, SEQ + extra, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch, rngkey):
    cfg = smoke_variant(get_config(arch))
    params = init_params(rngkey, cfg)
    opt_state = opt_lib.init_state(params)
    toks, kw = _inputs(cfg, rngkey)
    batch = {"tokens": toks, "labels": toks}
    if kw:
        batch["patch_embeds"] = kw["patch_embeds"]
    step = jax.jit(make_train_step(cfg, OptimizerConfig(lr=1e-3, warmup_steps=1)))
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_opt.step) == 1
    # every updated parameter stays finite (catches NaN gradients)
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
    # params actually changed
    old = jax.tree_util.tree_leaves(params)[0]
    new = jax.tree_util.tree_leaves(new_params)[0]
    assert not np.array_equal(np.asarray(old), np.asarray(new))
    # loss is finite and reasonable for a random init (~log V)
    assert metrics["loss"] < 2 * np.log(cfg.vocab_size) + 5


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_consistency(arch, rngkey):
    """serve path == train path at matched positions for every arch."""
    cfg = smoke_variant(get_config(arch))
    params = init_params(rngkey, cfg)
    s = 24
    toks = jax.random.randint(rngkey, (BATCH, s + 1), 0, cfg.vocab_size)
    kw = {}
    off = 0
    if cfg.frontend == "vision":
        kw["patch_embeds"] = jax.random.normal(
            rngkey, (BATCH, cfg.num_patches, cfg.d_model), jnp.float32) * 0.1
        off = cfg.num_patches
    ref, _, _ = forward(params, cfg, toks, **kw)
    cache = init_cache(cfg, BATCH, s + 1 + off)
    pre, _, cache = forward(params, cfg, toks[:, :s], cache=cache, pos=0, **kw)
    dec, _, _ = forward(params, cfg, toks[:, s : s + 1], cache=cache, pos=s + off)
    np.testing.assert_allclose(
        np.asarray(pre[:, -1]), np.asarray(ref[:, off + s - 1]), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(
        np.asarray(dec[:, 0]), np.asarray(ref[:, off + s]), rtol=2e-3, atol=2e-3)


def test_grad_accum_matches_single_step(rngkey):
    """grad_accum=2 must equal one full-batch step (linearity of grads)."""
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(rngkey, cfg)
    toks = jax.random.randint(rngkey, (4, SEQ), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    s1 = jax.jit(make_train_step(cfg, OptimizerConfig(lr=1e-3)))
    s2 = jax.jit(make_train_step(cfg, OptimizerConfig(lr=1e-3, grad_accum=2)))
    p1, _, m1 = s1(params, opt_lib.init_state(params), batch)
    p2, _, m2 = s2(params, opt_lib.init_state(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    l1 = jax.tree_util.tree_leaves(p1)
    l2 = jax.tree_util.tree_leaves(p2)
    for a, b in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                                   rtol=5e-2, atol=5e-4)


def test_sliding_window_variant_matches_prefix():
    """window-limited attention == full attention when seq < window."""
    key = jax.random.PRNGKey(1)
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    full, _, _ = forward(params, cfg, toks)
    swa, _, _ = forward(params, cfg.replace(window=64), toks)
    np.testing.assert_allclose(np.asarray(full), np.asarray(swa), rtol=1e-5, atol=1e-5)


def test_int8_kv_cache_decode_close():
    """int8 KV cache (per-token-head scales) ~= bf16 cache decode."""
    key = jax.random.PRNGKey(5)
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    cfg8 = cfg.replace(kv_cache_int8=True)
    params = init_params(key, cfg)
    s = 24
    toks = jax.random.randint(key, (2, s + 1), 0, cfg.vocab_size)
    ref, _, _ = forward(params, cfg, toks)
    cache = init_cache(cfg8, 2, s + 1)
    _, _, cache = forward(params, cfg8, toks[:, :s], cache=cache, pos=0)
    dec, _, _ = forward(params, cfg8, toks[:, s:], cache=cache, pos=s)
    corr = float(jnp.corrcoef(dec[:, 0].reshape(-1), ref[:, s].reshape(-1))[0, 1])
    assert corr > 0.999
