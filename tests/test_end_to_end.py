"""End-to-end: the paper's lifecycle on a real (reduced) LM.

train -> compress -> publish (versioned store) -> licensed clients pull ->
delta update -> licensed LM serving (both mask-at-load and fused-int8).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core import (
    EdgeClient,
    LicenseServer,
    LicenseTier,
    WeightStore,
    compress_pipeline,
    flatten_params,
    unflatten_like,
)
from repro.data import LMDataConfig, lm_batches
from repro.models import forward
from repro.serving import Request, ServingEngine
from repro.training import OptimizerConfig, train_loop


@pytest.fixture(scope="module")
def trained():
    cfg = smoke_variant(get_config("qwen2.5-3b")).replace(vocab_size=256)
    data = lm_batches(LMDataConfig(vocab_size=256, seq_len=48, batch_size=8))
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    params, hist = train_loop(cfg, ocfg, data, 60, log_fn=lambda s: None)
    return cfg, jax.device_get(params), hist


def test_full_lifecycle(trained):
    cfg, params, hist = trained
    assert hist["loss"][-1] < hist["loss"][0]  # learned something

    # compress (Fig. 3) — prune block weights, keep quality reasonable
    pruned, quant, stats = compress_pipeline(params, sparsity=0.8)  # paper rate
    assert stats.sparsity > 0.6
    # Table-1 ordering: full > pruned(sparse) > pruned+quantized
    assert stats.full_bytes > stats.pruned_bytes > stats.quantized_bytes

    # publish + tier
    store = WeightStore(":memory:")
    store.register_model(cfg.name, cfg.arch_type)
    server = LicenseServer(store)
    v1 = server.publish(cfg.name, pruned, tag="v1")
    # band must exceed the 80% pruning threshold or it only re-masks zeros
    server.publish_tier(cfg.name, LicenseTier(
        name="free", masks={"*": ((0.0, 0.12),)}, accuracy=0.5))

    # two clients pull
    flat = flatten_params(pruned)
    zeros = {k: np.zeros_like(v) for k, v in flat.items()}
    paid = EdgeClient(cfg.name, dict(zeros), license_name="full")
    free = EdgeClient(cfg.name, dict(zeros), license_name="free")
    paid.request_update(server)
    free.request_update(server)

    toks = np.arange(16, dtype=np.int32)[None].repeat(2, 0)
    paid_params = unflatten_like(pruned, paid.params)
    free_params = unflatten_like(pruned, free.params)
    lp, _, _ = forward(paid_params, cfg, jnp.asarray(toks))
    lf, _, _ = forward(free_params, cfg, jnp.asarray(toks))
    assert bool(jnp.all(jnp.isfinite(lp))) and bool(jnp.all(jnp.isfinite(lf)))
    assert bool(jnp.any(jnp.abs(lp - lf) > 1e-4))  # tiers actually differ

    # delta update: change a handful of weights server-side
    newp = {k: np.array(v, copy=True) for k, v in flatten_params(pruned).items()}
    key = [k for k in newp if "lm_head" in k][0]
    newp[key].reshape(-1)[:10] += 0.05
    server.publish(cfg.name, newp, parent=v1, tag="v1.1")
    packet = paid.request_update(server)
    assert packet.num_entries == 10
    assert packet.nbytes < 1000  # §4.3 low-latency: bytes ∝ changed weights

    store.close()


def test_licensed_lm_serving_both_modes(trained):
    cfg, params, _ = trained
    tiers = {"free": LicenseTier(name="free", masks={"*": ((0.0, 0.002),)})}

    eng_load = ServingEngine(cfg, params, tiers=tiers)              # paper
    eng_q = ServingEngine(cfg, params, tiers=tiers, quantized=True)  # ours
    a = eng_load.generate([Request(prompt=np.arange(12, dtype=np.int32),
                                   max_new_tokens=4)])[0]
    b = eng_q.generate([Request(prompt=np.arange(12, dtype=np.int32),
                                max_new_tokens=4)])[0]
    assert len(a.out_tokens) == len(b.out_tokens) == 4
    # greedy decode from the same weights: int8 path matches argmax-ish;
    # don't assert equality (quantization can flip near-ties) — both valid
    assert all(0 <= t < cfg.padded_vocab for t in a.out_tokens + b.out_tokens)
