"""Fault-tolerance end-to-end: seeded chaos schedules over a staged
sync, version quarantine, and the license-lease state machine.

The correctness bar (ISSUE 9): under ANY seeded fault schedule the
emitted tokens are bit-identical to the fault-free run — faults may
change timing, retry counters, and lease state, never outputs — and a
sync that lands does so with exactly one ``version_flip`` audit event."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.core.protocol import LicenseServer
from repro.core.transport import (ChaosTransport, DirectTransport,
                                  RetryPolicy, TransportTimeout)
from repro.core.weightstore import WeightStore
from repro.models import init_params
from repro.serving import LicensedGateway, RequestState
from repro.serving.fleet import FleetGateway

MAX_PROMPT = 8


def _noop_sleep(_s):
    pass


def _fast_retry(attempts=10):
    return RetryPolicy(max_attempts=attempts, base_delay_s=0.0, jitter=0.0,
                       sleep=_noop_sleep)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = jax.device_get(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _server_with(params):
    store = WeightStore(":memory:", row_limit=2048)
    server = LicenseServer(store)
    server.publish("lm", params, tag="v1")
    server.publish_tier("lm", LicenseTier(name="free",
                                          masks={"*": ((0.0, 0.004),)}))
    return server


def _boot(cfg, server, params, **kw):
    template = jax.tree_util.tree_map(lambda x: np.zeros_like(x), params)
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_prompt", MAX_PROMPT)
    kw.setdefault("max_new_cap", 16)
    return LicensedGateway.from_server(cfg, server, "lm", template, **kw)


def _prompt(seed, n=MAX_PROMPT):
    return np.random.default_rng(seed).integers(0, 500, n, dtype=np.int32)


class FlakyTransport(DirectTransport):
    """Direct delivery with a kill switch — every op times out while
    ``down`` (the 'server unreachable' condition for lease tests)."""

    def __init__(self, server):
        super().__init__(server)
        self.down = False

    def _call(self, op, thunk):
        if self.down:
            raise TransportTimeout(f"{op}: server unreachable")
        return super()._call(op, thunk)


# ------------------------------------------------------ seeded-fault differential
def _staged_sync_run(cfg, params, chaos_seed=None):
    """Mid-stream staged v1→v2 sync with two requests in flight; returns
    (gateway, req_a, req_b).  ``chaos_seed`` routes the whole sync
    through a ChaosTransport at a 25% fault rate."""
    server = _server_with(params)
    gw = _boot(cfg, server, params)
    a = gw.submit(_prompt(1), license="free", max_new_tokens=12)
    b = gw.submit(_prompt(2), license="free", max_new_tokens=12)
    gw.step()                                # prefill: a, b in flight
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")
    kw = {"max_step_bytes": 24 << 10}
    if chaos_seed is not None:
        kw["transport"] = ChaosTransport(
            server, seed=chaos_seed, fault_rate=0.25, dup_rate=0.15,
            sleep=_noop_sleep)
        kw["retry"] = _fast_retry()
    assert gw.begin_sync(**kw) is True
    for _ in range(50_000):
        if not (gw.sync_active or gw.scheduler.waiting
                or gw.scheduler.running):
            break
        gw.step()
    assert a.state == b.state == RequestState.DONE
    return gw, a, b


@pytest.mark.parametrize("chaos_seed", [0, 7])
def test_seeded_fault_schedule_is_token_invariant(setup, chaos_seed):
    cfg, params = setup
    ref, a0, b0 = _staged_sync_run(cfg, params, chaos_seed=None)
    gw, a, b = _staged_sync_run(cfg, params, chaos_seed=chaos_seed)

    # bit-identical outputs: faults changed retry counters, never tokens
    assert a.out_tokens == a0.out_tokens
    assert b.out_tokens == b0.out_tokens
    assert (a.version, b.version) == (1, 1)  # pinned across the flip

    # the sync landed, exactly once, despite the faults
    assert gw.version == gw._client.version == ref.version != 1
    assert len(gw.audit.events("version_flip")) == 1
    st = gw.metrics()["staged_update"]
    assert st["flips"] == 1
    assert st["wire"]["faults"] > 0          # the schedule really fired
    assert st["retries"] > 0
    assert gw.metrics()["sync_retries"] > 0  # surfaced on the slot too
    assert gw.audit.events("sync_retry")     # and in the audit stream
    if st["wire"]["disconnects"] or st["wire"]["corruptions"]:
        assert st["resumes"] > 0             # lost deliveries resumed

    # the landed weights are exactly the fault-free ones
    for x, y in zip(jax.tree_util.tree_leaves(gw._client.params),
                    jax.tree_util.tree_leaves(ref._client.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    # post-flip admissions behave identically too
    want = ref.submit(_prompt(9), license="free", max_new_tokens=4)
    ref.run()
    got = gw.submit(_prompt(9), license="free", max_new_tokens=4)
    gw.run()
    assert got.out_tokens == want.out_tokens


def test_chaos_covers_every_fault_kind(setup):
    """Across a handful of seeds the schedule exercises timeouts,
    disconnects, AND corrupted pages (the ≥20% mixed-fault criterion) —
    every run still landing the sync."""
    cfg, params = setup
    totals = {"timeouts": 0, "disconnects": 0, "corruptions": 0}
    for seed in (0, 7, 13):
        gw, _, _ = _staged_sync_run(cfg, params, chaos_seed=seed)
        wire = gw.metrics()["staged_update"]["wire"]
        for k in totals:
            totals[k] += wire[k]
    assert all(v > 0 for v in totals.values()), totals


# ------------------------------------------------------------------- quarantine
def test_repeated_failed_syncs_quarantine_version(setup):
    cfg, params = setup
    server = _server_with(params)
    gw = _boot(cfg, server, params, quarantine_after=1)
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")

    # the wire drops every fetch: retries exhaust, the session aborts,
    # and v2 is quarantined — the gateway keeps serving v1
    dead = ChaosTransport(server, seed=0, fault_rate=1.0,
                          disconnect_weight=0, corrupt_weight=0,
                          fault_ops=("fetch_update",), sleep=_noop_sleep)
    assert gw.begin_sync(transport=dead, retry=_fast_retry(3)) is True
    for _ in range(1000):
        if not gw.sync_active:
            break
        gw.step()                            # step() swallows TransportError
    assert not gw.sync_active
    assert gw.version == 1 and gw._staging_version is None
    assert gw.quarantined_versions == {2}
    assert gw.metrics()["sync_quarantines"] == 1
    assert gw.audit.events("sync_quarantine")
    r = gw.submit(_prompt(1), license="free", max_new_tokens=2)
    gw.run()
    assert r.state == RequestState.DONE and r.version == 1

    # quarantined: a new sync toward v2 refuses to start, even though
    # the wire is healthy again
    assert gw.begin_sync() is False
    assert gw.version == 1

    # operator override: clear the quarantine and the sync lands clean
    gw.clear_quarantine()
    assert gw.sync() is True
    assert gw.version == gw._client.version == 2
    assert len(gw.audit.events("version_flip")) == 1


# ------------------------------------------------------------------ lease state
def test_license_lease_state_machine(setup):
    cfg, params = setup
    server = _server_with(params)
    now = [0.0]
    tr = FlakyTransport(server)
    gw = _boot(cfg, server, params, transport=tr, clock=lambda: now[0],
               lease_ttl_s=10.0, lease_grace_s=20.0,
               retry_policy=_fast_retry(2))
    assert gw.metrics()["lease"]["state"] == "healthy"

    warm = gw.submit(_prompt(0), license="free", max_new_tokens=1)
    gw.run()
    assert warm.state == RequestState.DONE

    # server goes dark; past the ttl the lease degrades
    tr.down = True
    now[0] = 11.0
    gw.step()
    assert gw.metrics()["lease"]["state"] == "degraded"
    assert gw.audit.events("lease_degraded")
    # DEGRADED keeps serving already-granted tiers...
    r = gw.submit(_prompt(1), license="free", max_new_tokens=2)
    gw.run()
    assert r.state == RequestState.DONE
    # ...but refuses NEW tier grants, even ones the server would honor
    server.publish_tier("lm", LicenseTier(name="pro",
                                          masks={"*": ((0.0, 0.002),)}))
    rej = gw.submit(_prompt(2), license="pro", max_new_tokens=2)
    assert rej.state == RequestState.REJECTED
    assert "refusing new tier grant" in rej.error

    # past the grace window: OFFLINE, default policy rejects admissions
    now[0] = 31.5
    gw.step()
    assert gw.metrics()["lease"]["state"] == "offline"
    assert gw.audit.events("lease_offline")
    rej = gw.submit(_prompt(3), license="free", max_new_tokens=2)
    assert rej.state == RequestState.REJECTED
    assert "lease offline" in rej.error

    # server back: the self-heal probe restores the lease
    tr.down = False
    now[0] = 33.0
    gw.step()
    lease = gw.metrics()["lease"]
    assert lease["state"] == "healthy"
    assert gw.audit.events("lease_restored")
    # degraded span was 11.0 -> 33.0 on the frozen clock
    assert lease["degraded_seconds_total"] == pytest.approx(22.0)
    ok = gw.submit(_prompt(4), license="free", max_new_tokens=2)
    gw.run()
    assert ok.state == RequestState.DONE
    # and the deferred new-tier grant now resolves from the server
    ok2 = gw.submit(_prompt(5), license="pro", max_new_tokens=1)
    assert ok2.state != RequestState.REJECTED


def test_lease_offline_floor_policy_substitutes_tier(setup):
    cfg, params = setup
    server = _server_with(params)
    now = [0.0]
    tr = FlakyTransport(server)
    gw = _boot(cfg, server, params, transport=tr, clock=lambda: now[0],
               lease_ttl_s=1.0, lease_grace_s=1.0,
               lease_policy="floor", lease_floor_tier="free",
               retry_policy=_fast_retry(2))
    # reference tokens for a straight "free" admission
    ref = gw.submit(_prompt(1), license="free", max_new_tokens=4)
    gw.run()
    assert ref.state == RequestState.DONE

    tr.down = True
    now[0] = 5.0
    gw.step()
    assert gw.metrics()["lease"]["state"] == "offline"
    # "full" can't be validated offline — the floor tier serves instead
    r = gw.submit(_prompt(1), license="full", max_new_tokens=4)
    assert r.state != RequestState.REJECTED
    assert r.license == "free"
    gw.run()
    assert r.state == RequestState.DONE
    assert r.out_tokens == ref.out_tokens    # really served under the floor


def test_fleet_surfaces_lease_and_sync_counters(setup):
    cfg, params = setup
    server = _server_with(params)
    fleet = FleetGateway()
    gw = _boot(cfg, server, params)
    fleet.attach(gw)
    m = fleet.metrics()["models"]["lm"]
    assert m["lease"]["state"] == "healthy"
    assert m["lease"]["server_attached"] is True
    assert m["sync_retries"] == 0 and m["sync_quarantines"] == 0
    page = gw.telemetry.render_prometheus()
    assert "serving_license_lease_state" in page
    assert "serving_sync_retries_total" in page
    assert "serving_degraded_seconds_total" in page
