"""Serving engine: batched generation, licensed views, determinism."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_params
from repro.serving import Request, ServingEngine


@pytest.fixture(scope="module")
def engine():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {"free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})}
    return ServingEngine(cfg, params, tiers=tiers)


def _req(seed, n=6, lic="full"):
    rng = np.random.default_rng(seed)
    return Request(prompt=rng.integers(0, 500, 16, dtype=np.int32),
                   max_new_tokens=n, license=lic)


def test_generate_fills_requested_tokens(engine):
    reqs = [_req(0), _req(1, n=4)]
    engine.generate(reqs)
    assert len(reqs[0].out_tokens) == 6
    assert len(reqs[1].out_tokens) == 4
    assert all(0 <= t < engine.cfg.padded_vocab for r in reqs for t in r.out_tokens)


def test_greedy_decode_deterministic(engine):
    a, b = _req(3), _req(3)
    engine.generate([a])
    engine.generate([b])
    assert a.out_tokens == b.out_tokens


def test_licensed_view_differs_and_is_cached(engine):
    full = engine.params_for("full")
    free1 = engine.params_for("free")
    free2 = engine.params_for("free")
    assert free1 is free2  # cached view
    # some weights masked in at least one leaf
    diff = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(full),
                        jax.tree_util.tree_leaves(free1))
    )
    assert diff


def test_mixed_tier_batch_grouped(engine):
    reqs = [_req(0, lic="full"), _req(0, lic="free")]
    engine.generate(reqs)
    assert len(reqs[0].out_tokens) == len(reqs[1].out_tokens) == 6
    # same prompt, different tiers — outputs may differ (masked weights)
    # (not asserted: masking CAN preserve argmax on tiny models)


def test_unknown_tier_raises(engine):
    with pytest.raises(KeyError):
        engine.params_for("enterprise")


def test_quantized_engine_one_store_many_tiers():
    """Beyond-paper mode: a single int8 store serves every tier."""
    from repro.serving.quantized import is_qleaf

    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {"free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})}
    eng = ServingEngine(cfg, params, tiers=tiers, quantized=True)
    # the same object serves both tiers — zero extra weight memory
    assert eng.params_for("full") is eng.params_for("free")
    assert eng.intervals_for("full") is None
    assert eng.intervals_for("free") is not None
    reqs = [_req(0, lic="full"), _req(0, lic="free")]
    eng.generate(reqs)
    assert len(reqs[0].out_tokens) == 6 and len(reqs[1].out_tokens) == 6
    leaves = jax.tree_util.tree_leaves(eng.base_params, is_leaf=is_qleaf)
    assert any(is_qleaf(l) for l in leaves)
