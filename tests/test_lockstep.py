"""Deterministic lockstep race checker: no-op hooks when inactive,
wrong-role touches caught, seeded schedules replayable, and the real
staged-sync worker/serving thread pair running clean under perturbed
interleavings across several seeds."""
import threading

import jax
import numpy as np
import pytest

from repro.analysis import lockstep
from repro.analysis.lockstep import LockstepScheduler, LockstepViolation
from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.core.protocol import LicenseServer
from repro.core.weightstore import WeightStore
from repro.models import init_params
from repro.serving import LicensedGateway, RequestState


# ---------------------------------------------------------------- unit layer
def test_hooks_are_noops_when_inactive():
    assert lockstep.active() is None
    lockstep.checkpoint("anything", touches=("_cursor",))
    lockstep.transfer_ownership(("_cursor",), "worker")   # both: no effect
    assert lockstep.active() is None


def test_one_scheduler_at_a_time():
    with LockstepScheduler():
        with pytest.raises(RuntimeError, match="already active"):
            LockstepScheduler().__enter__()
    assert lockstep.active() is None                      # cleaned up on exit


def test_serve_thread_touch_of_worker_field_raises():
    with LockstepScheduler(max_pause_s=0.001) as sched:
        lockstep.transfer_ownership(("_cursor", "_pos"), "worker")
        lockstep.checkpoint("free_field", touches=("_other",))  # undeclared: ok
        with pytest.raises(LockstepViolation, match="_cursor.*owned by 'worker'"):
            lockstep.checkpoint("serve.read", touches=("_cursor",))
        assert len(sched.violations) == 1
        # handed back: the same touch is legal again
        lockstep.transfer_ownership(("_cursor", "_pos"), "serve")
        lockstep.checkpoint("serve.read", touches=("_cursor",))


def test_worker_thread_touch_of_serve_field_raises():
    caught = []

    def worker():
        try:
            lockstep.checkpoint("w.touch", touches=("_applied",))
        except LockstepViolation as exc:
            caught.append(exc)

    with LockstepScheduler(max_pause_s=0.001):
        lockstep.transfer_ownership(("_applied",), "serve")
        t = threading.Thread(target=worker, name="update-stager-fetch")
        t.start()
        t.join(timeout=5)
    assert len(caught) == 1 and "owned by 'serve'" in str(caught[0])


def test_pause_schedule_is_seed_deterministic():
    def drive(seed):
        with LockstepScheduler(seed=seed, switch_rate=0.5,
                               max_pause_s=0.0005) as sched:
            for _ in range(40):
                lockstep.checkpoint("toy.a")
                lockstep.checkpoint("toy.b")
        return sched.pauses, dict(sched.visits)

    p0, v0 = drive(seed=7)
    p1, v1 = drive(seed=7)
    assert (p0, v0) == (p1, v1)                     # same seed: same schedule
    assert v0 == {"toy.a": 40, "toy.b": 40}
    assert 0 < p0 < 80                              # rate 0.5: some, not all
    assert len({drive(seed=s)[0] for s in range(6)}) > 1   # seeds differ


def test_paused_thread_resumes_on_peer_checkpoint():
    """A pause must end when another thread checkpoints — not only by
    timeout — so the harness can force real overlap windows."""
    order = []

    def peer():
        for _ in range(200):
            lockstep.checkpoint("peer.tick")
        order.append("peer-done")

    with LockstepScheduler(seed=0, switch_rate=1.0, max_pause_s=5.0):
        t = threading.Thread(target=peer, name="update-stager-peer")
        t.start()
        for _ in range(200):
            lockstep.checkpoint("main.tick")   # rate 1.0: every visit pauses
        t.join(timeout=10)
    assert not t.is_alive()                    # nobody served a 5 s timeout
    assert order == ["peer-done"]


# ------------------------------------------------------- staged sync, seeded
MAX_PROMPT = 8


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = jax.device_get(init_params(jax.random.PRNGKey(0), cfg))
    return cfg, params


def _prompt(seed, n=MAX_PROMPT):
    return np.random.default_rng(seed).integers(0, 500, n, dtype=np.int32)


def _booted(cfg, params):
    store = WeightStore(":memory:", row_limit=2048)
    server = LicenseServer(store)
    server.publish("lm", params, tag="v1")
    server.publish_tier("lm", LicenseTier(name="free",
                                          masks={"*": ((0.0, 0.004),)}))
    template = jax.tree_util.tree_map(lambda x: np.zeros_like(x), params)
    gw = LicensedGateway.from_server(cfg, server, "lm", template,
                                     max_batch=2, max_prompt=MAX_PROMPT,
                                     max_new_cap=16)
    return server, gw


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_staged_sync_clean_under_lockstep(setup, seed):
    """The real worker/serving pair: a staged sync with decode traffic in
    flight, interleaving perturbed per seed, must finish with zero
    ownership violations — and the pauses must not deadlock the bounded
    fetch queue (the whole point of bounded waits)."""
    cfg, params = setup
    server, gw = _booted(cfg, params)
    a = gw.submit(_prompt(1), license="free", max_new_tokens=8)
    gw.step()                                  # prefill before the publish
    newp = jax.tree_util.tree_map(lambda x: np.asarray(x) * 1.01, params)
    server.publish("lm", newp, tag="v2")

    with LockstepScheduler(seed=seed, switch_rate=0.7,
                           max_pause_s=0.005) as sched:
        assert gw.begin_sync(max_step_bytes=16 << 10) is True
        for _ in range(10_000):
            if not (gw.sync_active or gw.scheduler.running
                    or gw.scheduler.waiting):
                break
            gw.step()
    assert sched.violations == []
    assert gw.version == gw._client.version != 1
    assert a.state == RequestState.DONE

    # the harness actually exercised the protocol: the stager checkpoints
    # fired on both threads and ownership made the full round trip
    assert any(k.startswith("stager.") for k in sched.visits)
    roles = [role for role, _ in sched.transfers]
    assert "worker" in roles and "serve" in roles
    assert roles[-1] == "serve"                # handed back after the join
