"""Per-kernel allclose vs the pure-jnp oracles, swept over shapes/dtypes.

All Pallas bodies execute via interpret=True on CPU (the kernel *body* is
what is validated; compiled TPU lowering is exercised by the dry-run).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; plain tests still run
    from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def rng(seed=0):
    return np.random.default_rng(seed)


# --------------------------------------------------------------- quant_matmul
@pytest.mark.parametrize("m,k,n", [(128, 512, 128), (256, 512, 256), (128, 1024, 384), (8, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_matches_ref(m, k, n, dtype):
    r = rng(m * 7 + n)
    x = jnp.asarray(r.standard_normal((m, k)), dtype=dtype)
    codes = jnp.asarray(r.integers(-127, 128, (k, n)), dtype=jnp.int8)
    scale = jnp.asarray(np.abs(r.standard_normal(n)) * 0.02 + 1e-4, dtype=jnp.float32)
    got = ops.quant_matmul(x, codes, scale, out_dtype=jnp.float32, interpret=True)
    want = ref.quant_matmul(x, codes, scale, jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-2, atol=2e-2)


def test_quant_matmul_unaligned_shapes_pad():
    r = rng(3)
    x = jnp.asarray(r.standard_normal((130, 700)), dtype=jnp.float32)
    codes = jnp.asarray(r.integers(-127, 128, (700, 200)), dtype=jnp.int8)
    scale = jnp.asarray(np.abs(r.standard_normal(200)) + 0.01, dtype=jnp.float32)
    got = ops.quant_matmul(x, codes, scale, interpret=True)
    want = ref.quant_matmul(x, codes, scale, jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-2, atol=1e-2)


def test_quant_matmul_batched_leading_dims():
    r = rng(5)
    x = jnp.asarray(r.standard_normal((4, 64, 512)), dtype=jnp.float32)
    codes = jnp.asarray(r.integers(-127, 128, (512, 128)), dtype=jnp.int8)
    scale = jnp.ones(128, jnp.float32) * 0.02
    got = ops.quant_matmul(x, codes, scale, interpret=True)
    assert got.shape == (4, 64, 128)
    want = ref.quant_matmul(x.reshape(-1, 512), codes, scale, jnp.float32).reshape(4, 64, 128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------- masked_dequant
@pytest.mark.parametrize("r_,c", [(256, 256), (512, 768), (300, 200), (64, 64)])
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_masked_dequant_matches_ref(r_, c, out_dtype):
    r = rng(r_ + c)
    codes = jnp.asarray(r.integers(-127, 128, (r_, c)), dtype=jnp.int8)
    scale = jnp.asarray(np.abs(r.standard_normal((1, c))) * 0.02 + 1e-3, dtype=jnp.float32)
    lo, hi = ops.pack_intervals([(0.5, 0.8), (1.2, 1.5)])
    got = ops.masked_dequant(codes, scale, [(0.5, 0.8), (1.2, 1.5)],
                             out_dtype=out_dtype, interpret=True)
    want = ref.masked_dequant(codes, jnp.broadcast_to(scale, codes.shape), lo, hi, out_dtype)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=1e-2, atol=1e-2
    )


def test_masked_dequant_no_intervals_is_plain_dequant():
    r = rng(11)
    codes = jnp.asarray(r.integers(-127, 128, (256, 256)), dtype=jnp.int8)
    scale = jnp.full((1, 256), 0.01, jnp.float32)
    got = ops.masked_dequant(codes, scale, [], interpret=True)
    want = codes.astype(jnp.float32) * scale
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_masked_dequant_row_scale():
    r = rng(13)
    codes = jnp.asarray(r.integers(-127, 128, (512, 256)), dtype=jnp.int8)
    scale = jnp.asarray(np.abs(r.standard_normal((512, 1))) * 0.02 + 1e-3, jnp.float32)
    got = ops.masked_dequant(codes, scale, [(0.3, 0.6)], interpret=True)
    lo, hi = ops.pack_intervals([(0.3, 0.6)])
    want = ref.masked_dequant(codes, jnp.broadcast_to(scale, codes.shape), lo, hi, jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_masked_dequant_zeroes_exactly_the_interval():
    codes = jnp.asarray(np.arange(-127, 129).reshape(1, -1).repeat(256, 0), dtype=jnp.int8)
    scale = jnp.full((1, 256), 0.01, jnp.float32)
    out = np.asarray(ops.masked_dequant(codes, scale, [(0.5, 0.8)], interpret=True))
    mag = np.abs(np.asarray(codes, np.float32) * 0.01)
    assert (out[(mag >= 0.5) & (mag < 0.8)] == 0).all()
    live = (mag < 0.5) | (mag >= 0.8)
    np.testing.assert_allclose(out[live], (np.asarray(codes, np.float32) * 0.01)[live])


# ---------------------------------------------------------------- delta_apply
@pytest.mark.parametrize("n,k", [(8192, 100), (4096, 1), (16384, 997), (100, 10)])
def test_delta_apply_matches_ref(n, k):
    r = rng(n + k)
    buf = jnp.asarray(r.standard_normal(n), dtype=jnp.float32)
    idx = jnp.asarray(r.choice(n, size=k, replace=False), dtype=jnp.int32)
    vals = jnp.asarray(r.standard_normal(k), dtype=jnp.float32)
    got = ops.delta_apply(buf, idx, vals, interpret=True)
    want = ref.delta_apply(buf, idx, vals)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


def test_delta_apply_bf16_buffer():
    r = rng(77)
    buf = jnp.asarray(r.standard_normal(8192), dtype=jnp.bfloat16)
    idx = jnp.asarray(r.choice(8192, size=64, replace=False), dtype=jnp.int32)
    vals = jnp.asarray(r.standard_normal(64), dtype=jnp.bfloat16)
    got = ops.delta_apply(buf, idx, vals, interpret=True)
    want = ref.delta_apply(buf, idx, vals)
    np.testing.assert_array_equal(np.asarray(got, np.float32), np.asarray(want, np.float32))


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([4096, 8192]),
    k=st.integers(min_value=1, max_value=256),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_delta_apply_property(n, k, seed):
    """Property: after apply, buf[idx]==vals and everything else unchanged."""
    r = rng(seed)
    buf = jnp.asarray(r.standard_normal(n), dtype=jnp.float32)
    idx_np = r.choice(n, size=k, replace=False)
    vals = jnp.asarray(r.standard_normal(k), dtype=jnp.float32)
    out = np.asarray(ops.delta_apply(buf, jnp.asarray(idx_np, jnp.int32), vals, interpret=True))
    np.testing.assert_array_equal(out[idx_np], np.asarray(vals))
    mask = np.ones(n, bool)
    mask[idx_np] = False
    np.testing.assert_array_equal(out[mask], np.asarray(buf)[mask])


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([8, 64, 128]),
    k=st.sampled_from([512, 1024]),
    n=st.sampled_from([128, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_quant_matmul_property(m, k, n, seed):
    r = rng(seed)
    x = jnp.asarray(r.standard_normal((m, k)), dtype=jnp.float32)
    codes = jnp.asarray(r.integers(-127, 128, (k, n)), dtype=jnp.int8)
    scale = jnp.asarray(np.abs(r.standard_normal(n)) * 0.05 + 1e-4, jnp.float32)
    got = ops.quant_matmul(x, codes, scale, interpret=True)
    want = ref.quant_matmul(x, codes, scale, jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)
