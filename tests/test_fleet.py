"""Fleet serving: ModelSlot composition, global cache budget, tenant
licensing quotas.

The tentpole invariants under test:

* A :class:`FleetGateway` serving N heterogeneous configs produces
  BIT-IDENTICAL tokens per model to N isolated ``LicensedGateway``\\ s —
  the fleet loop only interleaves slots, it never changes what a slot
  computes.
* Every executed micro-batch belongs to exactly one (model, tier,
  version): actions carry their slot's model name.
* The global byte-denominated cache budget gates admission fleet-wide
  while per-slot pools stay untouched: contention on one model never
  starves another that has headroom, and the budget is never exceeded.
* :class:`TenantRegistry` enforcement happens at submit (entitlement +
  concurrency + rate) AND at batch formation (revocation while queued),
  while already-decoding requests always drain to completion.
"""
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_params
from repro.serving import (FleetGateway, LicensedGateway, RequestState,
                           TenantRegistry, validate_fleet_metrics,
                           validate_gateway_metrics)

MAX_PROMPT = 8
MAX_NEW = 4

TIERS = {"free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)})}

TRIO_NAMES = ("qwen2.5-3b", "mamba2-130m", "recurrentgemma-2b")


@pytest.fixture(scope="module")
def trio():
    """Three heterogeneous smoke configs: GQA transformer (paged +
    chunked prefill), pure SSM (contiguous slab fallback), and a
    sliding-window/recurrent hybrid (paged, unchunked)."""
    out = {}
    for i, name in enumerate(TRIO_NAMES):
        cfg = smoke_variant(get_config(name))
        out[name] = (cfg, init_params(jax.random.PRNGKey(i), cfg))
    return out


def _prompt(seed, n=MAX_PROMPT):
    return np.random.default_rng(seed).integers(0, 500, n, dtype=np.int32)


def _slot_kw(**kw):
    kw.setdefault("tiers", dict(TIERS))
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_prompt", MAX_PROMPT)
    kw.setdefault("max_new_cap", MAX_NEW)
    return kw


def _fleet(trio, **fleet_kw):
    fleet = FleetGateway(**fleet_kw)
    for name, (cfg, params) in trio.items():
        fleet.add_model(name, cfg, params, **_slot_kw())
    return fleet


# ------------------------------------------------------------ differential
def test_fleet_matches_isolated_gateways(trio):
    """Acceptance criterion: three heterogeneous configs served by one
    FleetGateway produce bit-identical tokens per model versus three
    isolated gateways fed the same per-model request streams."""
    jobs = []  # (model, seed, license, max_new_tokens)
    for i, name in enumerate(TRIO_NAMES):
        for j in range(3):
            jobs.append((name, 10 * i + j,
                         "free" if (i + j) % 2 else "full", 2 + j % 3))

    fleet = _fleet(trio)
    fleet_reqs = [fleet.submit(m, _prompt(s), license=lic,
                               max_new_tokens=mn, seed=s)
                  for (m, s, lic, mn) in jobs]
    fleet.run()
    assert all(r.state == RequestState.DONE for r in fleet_reqs)
    assert all(len(r.out_tokens) == mn
               for r, (_, _, _, mn) in zip(fleet_reqs, jobs))

    for name, (cfg, params) in trio.items():
        gw = LicensedGateway(cfg, params, model=name, **_slot_kw())
        for (m, s, lic, mn), fr in zip(jobs, fleet_reqs):
            if m != name:
                continue
            r = gw.submit(_prompt(s), license=lic,
                          max_new_tokens=mn, seed=s)
            gw.run()
            assert r.state == RequestState.DONE
            assert r.out_tokens == fr.out_tokens, \
                f"{name}: fleet tokens diverge from isolated gateway"


def test_fleet_actions_are_model_tagged_and_interleaved(trio):
    """Every executed action names exactly one slot, every slot runs,
    and the round-robin interleaves rather than draining one model
    first."""
    fleet = _fleet(trio)
    for i, name in enumerate(TRIO_NAMES):
        fleet.submit(name, _prompt(i), license="full", max_new_tokens=3)
    acts = []
    while True:
        act = fleet.step()
        if act is None:
            break
        acts.append(act)
    assert {a.model for a in acts} == set(TRIO_NAMES)
    # with one ready prompt per slot, the first three actions hit three
    # distinct slots — round-robin, not drain-one-model-first
    assert len({a.model for a in acts[:3]}) == 3
    # micro-batches stay (model, tier, version)-homogeneous: each slot's
    # own trace never mixes tiers within an action (single-tier feed
    # here, so every trace row carries that one tier)
    for gw in fleet.gateways.values():
        for kind, tier, version, n in gw.trace:
            assert tier == "full" and n >= 1


# ---------------------------------------------------------- global budget
def test_global_budget_contention_spares_other_model(trio):
    """Two tenants contend for the last admissible blocks of model "a"
    while model "b" has headroom: the budget is never exceeded, "a"'s
    overflow request waits (no cross-slot preemption), "b" is never
    starved, and everyone eventually completes."""
    cfg, params = trio["qwen2.5-3b"]
    params_b = init_params(jax.random.PRNGKey(9), cfg)
    tenants = TenantRegistry()
    tenants.register("t1", entitlements=("a:*",))
    tenants.register("t2", entitlements=("a:*",))
    tenants.register("t3", entitlements=("b:*",))

    # per-request need is one block (capacity 12 < block_size 16), so a
    # two-block budget holds exactly one live request per slot
    probe = LicensedGateway(cfg, params, model="probe",
                            **_slot_kw(max_batch=1, prefix_cache=False))
    budget = 2 * probe.pool.block_bytes

    fleet = FleetGateway(cache_budget_bytes=budget, tenants=tenants)
    gw_a = fleet.add_model("a", cfg, params,
                           **_slot_kw(max_batch=1, prefix_cache=False))
    fleet.add_model("b", cfg, params_b,
                    **_slot_kw(max_batch=1, prefix_cache=False))

    r1 = fleet.submit("a", _prompt(0), tenant="t1", license="full",
                      max_new_tokens=MAX_NEW)
    r2 = fleet.submit("a", _prompt(1), tenant="t2", license="full",
                      max_new_tokens=MAX_NEW)
    r3 = fleet.submit("b", _prompt(2), tenant="t3", license="full",
                      max_new_tokens=MAX_NEW)
    assert all(r.state != RequestState.REJECTED for r in (r1, r2, r3))

    saw_contention = False
    for _ in range(10_000):
        act = fleet.step()
        used = fleet.used_cache_bytes()
        assert used <= budget, "global cache budget exceeded"
        if used == budget and len(gw_a.scheduler.waiting) == 1:
            saw_contention = True            # r2 gated while budget full
        if act is None:
            break
    assert saw_contention
    assert all(r.state == RequestState.DONE for r in (r1, r2, r3))
    stats = tenants.stats()
    assert all(stats[t]["completed"] == 1 and stats[t]["inflight"] == 0
               for t in ("t1", "t2", "t3"))


def test_budget_must_hold_one_request_per_paged_slot(trio):
    """A budget that cannot cover one full-capacity request per paged
    slot would admit requests nothing can ever finish — attach refuses
    it up front."""
    cfg, params = trio["qwen2.5-3b"]
    fleet = FleetGateway(cache_budget_bytes=1)
    with pytest.raises(ValueError, match="cannot hold"):
        fleet.add_model("a", cfg, params, **_slot_kw())


# ------------------------------------------------------- tenant enforcement
def test_unknown_model_and_unknown_tenant_rejected(trio):
    fleet = _fleet(trio)
    r = fleet.submit("no-such-model", _prompt(0))
    assert r.state == RequestState.REJECTED
    assert "unknown model" in r.error
    r2 = fleet.submit("qwen2.5-3b", _prompt(0), tenant="ghost")
    assert r2.state == RequestState.REJECTED
    assert "unknown tenant" in r2.error


def test_zero_quota_tenant_never_admitted(trio):
    """max_concurrent=0: entitled on paper, admitted never — and the
    rejection is visible in tenant, model, and fleet metrics."""
    cfg, params = trio["qwen2.5-3b"]
    tenants = TenantRegistry()
    tenants.register("broke", max_concurrent=0)
    fleet = FleetGateway(tenants=tenants)
    fleet.add_model("lm", cfg, params, **_slot_kw())

    r = fleet.submit("lm", _prompt(0), tenant="broke", license="free")
    assert r.state == RequestState.REJECTED
    assert "quota" in r.error
    s = tenants.stats()["broke"]
    assert (s["submitted"], s["admitted"], s["quota_rejections"]) == (1, 0, 1)
    m = fleet.metrics()
    assert m["models"]["lm"]["quota_rejections"] == 1
    assert m["fleet"]["quota_rejections"] == 1
    assert m["fleet"]["completed"] == 0


def test_entitlement_not_held_rejected_at_submit(trio):
    cfg, params = trio["qwen2.5-3b"]
    tenants = TenantRegistry()
    tenants.register("narrow", entitlements=("lm:free",))
    fleet = FleetGateway(tenants=tenants)
    fleet.add_model("lm", cfg, params, **_slot_kw())

    ok = fleet.submit("lm", _prompt(0), tenant="narrow", license="free",
                      max_new_tokens=2)
    bad = fleet.submit("lm", _prompt(1), tenant="narrow", license="full",
                       max_new_tokens=2)
    assert ok.state != RequestState.REJECTED
    assert bad.state == RequestState.REJECTED
    assert "not entitled" in bad.error
    fleet.run()
    assert ok.state == RequestState.DONE


def test_revocation_while_queued_drains_inflight(trio):
    """Mid-flight entitlement revocation: the decoding request always
    completes (never cancelled mid-generation); the queued one is
    rejected at the next batch formation."""
    cfg, params = trio["qwen2.5-3b"]
    tenants = TenantRegistry()
    tenants.register("acme", entitlements=("lm:free",))
    fleet = FleetGateway(tenants=tenants)
    fleet.add_model("lm", cfg, params, **_slot_kw(max_batch=1))

    r1 = fleet.submit("lm", _prompt(0), tenant="acme", license="free",
                      max_new_tokens=MAX_NEW)
    r2 = fleet.submit("lm", _prompt(1), tenant="acme", license="free",
                      max_new_tokens=MAX_NEW)
    # step until r1 holds a lane and decodes while r2 still queues
    for _ in range(10_000):
        fleet.step()
        if r1.state == RequestState.RUNNING:
            break
    assert r1.state == RequestState.RUNNING
    assert r2.state == RequestState.QUEUED

    tenants.revoke("acme", "lm", "free")
    fleet.run()
    assert r1.state == RequestState.DONE          # drained, not cancelled
    assert len(r1.out_tokens) == MAX_NEW
    assert r2.state == RequestState.REJECTED
    assert "revoked while queued" in r2.error
    s = tenants.stats()["acme"]
    assert (s["completed"], s["quota_rejections"], s["inflight"]) == (1, 1, 0)
    # revoke removed the covering pattern: nothing left to submit under
    assert not tenants.entitled("acme", "lm", "free")
    r3 = fleet.submit("lm", _prompt(2), tenant="acme", license="free")
    assert r3.state == RequestState.REJECTED


def test_token_bucket_burst_then_drain():
    """rate=1/s with burst 2 under an injected clock: the burst spends,
    the bucket refills at the advertised rate, and caps at burst."""
    now = {"t": 0.0}
    reg = TenantRegistry(clock=lambda: now["t"])
    reg.register("u", rate=1.0, burst=2.0)

    assert reg.acquire("u", "m", "full") is None       # burst token 1
    assert reg.acquire("u", "m", "full") is None       # burst token 2
    denied = reg.acquire("u", "m", "full")
    assert denied is not None and "rate-limited" in denied

    now["t"] += 1.0                                    # refills one token
    assert reg.acquire("u", "m", "full") is None
    assert "rate-limited" in reg.acquire("u", "m", "full")

    now["t"] += 30.0                                   # caps at burst=2
    assert reg.acquire("u", "m", "full") is None
    assert reg.acquire("u", "m", "full") is None
    assert "rate-limited" in reg.acquire("u", "m", "full")

    s = reg.stats()["u"]
    assert s["quota_rejections"] == 3
    assert s["rate_tokens_available"] < 1.0


def test_rate_limit_enforced_at_fleet_submit(trio):
    cfg, params = trio["qwen2.5-3b"]
    now = {"t": 0.0}
    tenants = TenantRegistry(clock=lambda: now["t"])
    tenants.register("slow", rate=0.5, burst=1.0)
    fleet = FleetGateway(tenants=tenants)
    fleet.add_model("lm", cfg, params, **_slot_kw())

    a = fleet.submit("lm", _prompt(0), tenant="slow", license="free",
                     max_new_tokens=2)
    b = fleet.submit("lm", _prompt(1), tenant="slow", license="free",
                     max_new_tokens=2)
    assert a.state != RequestState.REJECTED
    assert b.state == RequestState.REJECTED and "rate-limited" in b.error
    now["t"] += 2.0                                    # one token back
    c = fleet.submit("lm", _prompt(2), tenant="slow", license="free",
                     max_new_tokens=2)
    assert c.state != RequestState.REJECTED
    fleet.run()
    assert a.state == RequestState.DONE
    assert c.state == RequestState.DONE


# ----------------------------------------------------------------- metrics
def test_fleet_metrics_schema(trio):
    """Satellite: the three-section metrics schema — fleet totals,
    per-model breakdown, per-tenant usage — asserted by the SAME shared
    validator that guards ``LicensedGateway.metrics()``.  Each
    ``models.<name>`` section embeds the exact single-gateway schema
    (plus a fleet-computed ``tokens_per_s``), so one dashboard/parser
    serves both deployments."""
    tenants = TenantRegistry()
    tenants.register("acme")
    fleet = _fleet(trio, tenants=tenants)
    reqs = [fleet.submit(name, _prompt(i), tenant="acme", license="free",
                         max_new_tokens=2)
            for i, name in enumerate(TRIO_NAMES)]
    reqs.append(fleet.submit("qwen2.5-3b", _prompt(7), license="full",
                             max_new_tokens=2))       # tenant-less
    fleet.run()
    assert all(r.state == RequestState.DONE for r in reqs)

    m = fleet.metrics()
    validate_fleet_metrics(m)
    assert m["fleet"]["models"] == len(TRIO_NAMES)
    assert m["fleet"]["completed"] == 4

    assert set(m["models"]) == set(TRIO_NAMES)
    for name, mm in m["models"].items():
        validate_gateway_metrics(mm, extra=("tokens_per_s",))
        assert mm["model"] == name
    assert m["fleet"]["tokens_generated"] == sum(
        mm["tokens_generated"] for mm in m["models"].values())

    assert set(m["tenants"]) == {"acme"}
    t = m["tenants"]["acme"]
    for key in ("inflight", "submitted", "admitted", "completed",
                "tokens_generated", "quota_rejections", "max_concurrent",
                "rate", "rate_tokens_available", "entitlements",
                "blocks_held", "oldest_wait_s", "tokens_per_s"):
        assert key in t, f"tenants[acme] missing {key}"
    assert t["completed"] == 3 and t["inflight"] == 0
    assert t["tokens_generated"] == 6
    # the tenant-less request is absent from tenant accounting but
    # present in the per-model tenant breakdown only under its tenants
    assert m["models"]["qwen2.5-3b"]["tenants"].get(
        "acme", {}).get("completed") == 1


def test_queue_waits_are_per_slot(trio):
    """Satellite fix: oldest_wait_s / queue_wait_by_tier come from each
    slot's OWN queue — load on one model never shows up as wait on an
    idle one."""
    fleet = _fleet(trio)
    fleet.submit("qwen2.5-3b", _prompt(0), license="free",
                 max_new_tokens=2)
    time.sleep(0.02)
    m = fleet.metrics()
    assert m["models"]["qwen2.5-3b"]["oldest_wait_s"] > 0.0
    assert m["models"]["mamba2-130m"]["oldest_wait_s"] == 0.0
    assert m["models"]["recurrentgemma-2b"]["oldest_wait_s"] == 0.0
    assert "free" in m["models"]["qwen2.5-3b"]["queue_wait_by_tier"]
    assert m["models"]["mamba2-130m"]["queue_wait_by_tier"] == {}
    assert m["fleet"]["oldest_wait_s"] == \
        m["models"]["qwen2.5-3b"]["oldest_wait_s"]
    fleet.run()


# ------------------------------------------------------ stager interleaving
class _FakeStager:
    """Stand-in with the two members the fleet loop touches (``active``,
    ``step``) — counts how many bounded steps it was given."""

    def __init__(self, n):
        self.left = n

    @property
    def active(self):
        return self.left > 0

    def step(self):
        assert self.left > 0
        self.left -= 1
        return "stage"


def test_at_most_one_stager_step_per_fleet_iteration(trio):
    """Per-slot staged-sync interleaving: each fleet iteration advances
    AT MOST one slot's stager, round-robin, so concurrent version flips
    on different models never stack their bounded work into one step."""
    fleet = _fleet(trio)
    gws = list(fleet.gateways.values())[:2]
    fakes = [_FakeStager(3), _FakeStager(3)]
    gws[0]._stager = fakes[0]
    gws[1]._stager = fakes[1]
    for i in range(6):
        fleet.step()
        done = sum(3 - f.left for f in fakes)
        assert done == i + 1, "more than one stager stepped this iteration"
    assert fakes[0].left == 0 and fakes[1].left == 0
    assert not any(g.sync_active for g in fleet.gateways.values())
