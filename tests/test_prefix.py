"""Shared-prefix radix cache: radix-tree invariants, prefix-hit logit
equivalence vs cold prefill, copy-on-write of shared tail blocks,
(tier, version) scoping, LRU eviction under watermark pressure, and
preemption of requests holding shared blocks."""
import jax
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_params
from repro.serving import (BlockAllocator, LicensedGateway, PrefixCache,
                           RequestState)

MAX_PROMPT = 8
MAX_NEW = 8
BLOCK = 4


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {
        "free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)}),
        "pro": LicenseTier(name="pro", masks={"*": ((0.0, 0.002),)}),
    }
    return cfg, params, tiers


def _gateway(setup, **kw):
    cfg, params, tiers = setup
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_prompt", MAX_PROMPT)
    kw.setdefault("max_new_cap", MAX_NEW)
    kw.setdefault("block_size", BLOCK)
    return LicensedGateway(cfg, params, tiers=tiers, **kw)


def _shared_prompts(seed, n, shared=BLOCK, total=MAX_PROMPT):
    """n prompts sharing their first ``shared`` tokens (one system prompt),
    each with a distinct tail — the tier-homogeneous traffic shape."""
    rng = np.random.default_rng(seed)
    head = rng.integers(0, 500, shared, dtype=np.int32)
    return [np.concatenate([head,
                            rng.integers(0, 500, total - shared,
                                         dtype=np.int32)])
            for _ in range(n)]


def _recount_reclaimable(pc):
    """Ground truth for the O(1) reclaimable counter: a full walk."""
    return sum(1 for b in pc._by_block
               if pc.allocator.refcount(b) == 1)


def _release(pc, blocks):
    """Release request references the way the gateway does: decref plus
    the note_release() hook that keeps the reclaimable counter exact."""
    for b in blocks:
        if pc.allocator.decref(b) == 1:
            pc.note_release(b)


def _drain(gw, prompts, *, license="free", max_new=4, waves=1):
    """Submit prompts in ``waves`` rounds (draining between rounds so later
    rounds see the populated cache) and return the requests."""
    reqs = []
    per = -(-len(prompts) // waves)
    for w in range(waves):
        chunk = prompts[w * per: (w + 1) * per]
        reqs += [gw.submit(p, license=license, max_new_tokens=max_new)
                 for p in chunk]
        gw.run()
    assert all(r.state == RequestState.DONE for r in reqs), \
        [r.error for r in reqs]
    if getattr(gw, "prefix", None) is not None:
        # the admission budget rides this counter: it must never drift
        assert gw.prefix.reclaimable() == _recount_reclaimable(gw.prefix)
    return reqs


# --------------------------------------------------------------- radix tree
def test_radix_match_insert_refcounts():
    a = BlockAllocator(16)
    pc = PrefixCache(a, block_size=4)
    toks = list(range(10))                       # 2 full blocks + fill-2 tail
    blocks = a.alloc(3)
    assert pc.match("s", toks) == ([], 0)        # cold: miss
    assert pc.insert("s", toks, blocks) == 3     # tree takes its refs
    assert all(a.refcount(b) == 2 for b in blocks)
    _release(pc, blocks)                         # request finishes
    assert pc.reclaimable() == 3                 # tree-only now

    got, n = pc.match("s", toks)                 # full chain incl. partial
    assert got == blocks and n == 10
    assert all(a.refcount(b) == 2 for b in got)  # incref'd for the caller
    got2, n2 = pc.match("s", toks[:8] + [99, 98])  # diverging tail
    assert got2 == blocks[:2] and n2 == 8
    got3, n3 = pc.match("s", [77] + toks[1:])    # diverges at block 0
    assert got3 == [] and n3 == 0
    # a shorter query must not match a longer partial tail
    got4, n4 = pc.match("s", toks[:9])
    assert got4 == blocks[:2] and n4 == 8
    st = pc.stats()
    assert st["hits"] == 3 and st["misses"] == 2  # cold + diverged-at-0


def test_radix_insert_keeps_existing_nodes():
    """Two same-prompt chains: the second donation is skipped (the tree
    keeps the first), and the duplicate stays the caller's to release."""
    a = BlockAllocator(8)
    pc = PrefixCache(a, block_size=4)
    toks = list(range(8))
    first, second = a.alloc(2), a.alloc(2)
    assert pc.insert("s", toks, first) == 2
    assert pc.insert("s", toks, second) == 0
    assert a.refcount(second[0]) == 1            # still private
    a.free(second)                               # dies with its request
    got, n = pc.match("s", toks)
    assert got == first and n == 8


def test_radix_lru_eviction_leaf_first():
    a = BlockAllocator(16)
    pc = PrefixCache(a, block_size=4)
    chains = {}
    for s in range(3):
        toks = [100 * s + i for i in range(8)]
        blocks = a.alloc(2)
        pc.insert("s", toks, blocks)
        _release(pc, blocks)
        chains[s] = (toks, blocks)
    pc.match("s", chains[0][0])                  # chain 0 recently used
    free_before = a.num_free
    # release the match's refs so everything is tree-only again
    _release(pc, chains[0][1])
    assert pc.evict(2) == 2                      # LRU chain (1) goes first
    assert a.num_free == free_before + 2
    assert pc.match("s", chains[1][0]) == ([], 0)
    got, n = pc.match("s", chains[0][0])         # survivor intact
    assert n == 8
    # pinned chains are skipped: chain 0 is request-held via the match
    assert pc.evict(10) == 2                     # only chain 2 reclaimable
    got2, n2 = pc.match("s", chains[0][0])
    assert n2 == 8


def test_radix_scope_isolation_and_drop():
    a = BlockAllocator(8)
    pc = PrefixCache(a, block_size=4)
    toks = list(range(8))
    blocks = a.alloc(2)
    pc.insert(("free", 1), toks, blocks)
    _release(pc, blocks)
    assert pc.match(("pro", 1), toks) == ([], 0)   # tier boundary
    assert pc.match(("free", 2), toks) == ([], 0)  # version boundary
    assert pc.match(("free", 1), toks)[1] == 8
    _release(pc, blocks)
    assert pc.drop_scope(version=1) == 2
    assert a.num_free == 8
    assert pc.match(("free", 1), toks) == ([], 0)


# ------------------------------------------------- gateway: hit equivalence
def test_prefix_hits_match_cold_prefill_logits(setup):
    """The acceptance bar: a shared-system-prompt stream served through
    the prefix cache produces per-step logits equal (1e-5) to cold
    serving, with identical tokens, while actually reusing blocks."""
    prompts = _shared_prompts(0, 6) + [None]
    prompts[-1] = prompts[0].copy()              # exact repeat: full match
    streams, gws = [], []
    for prefix in (False, True):
        gw = _gateway(setup, prefix_cache=prefix, record_logits=True)
        streams.append(_drain(gw, prompts, waves=2))
        gws.append(gw)
    for a, b in zip(*streams):
        assert a.out_tokens == b.out_tokens
        for ra, rb in zip(a.logits_rows, b.logits_rows):
            np.testing.assert_allclose(ra, rb, atol=1e-5, rtol=0)
    cold, warm = gws
    assert warm.stats["prefix_tokens_reused"] > 0
    assert warm.metrics()["prefix_cache"]["hits"] > 0
    # strictly less prefill compute and strictly fewer block allocations
    assert warm.stats["prefill_lane_tokens"] < cold.stats["prefill_lane_tokens"]
    assert warm.pool.allocator.alloc_count < cold.pool.allocator.alloc_count


def test_cow_on_shared_tail_block(setup):
    """Non-block-aligned prompt bucket: the donated partial tail block is
    shared between the radix tree and the request, so decode's first
    write into it must copy-on-write — and tokens must still match the
    prefix-disabled run exactly."""
    rng = np.random.default_rng(3)
    p = rng.integers(0, 500, 6, dtype=np.int32)
    prompts = [p.copy() for _ in range(4)]
    streams, gws = [], []
    for prefix in (False, True):
        gw = _gateway(setup, max_prompt=6, max_new_cap=6,
                      prefix_cache=prefix, record_logits=True)
        streams.append(_drain(gw, prompts, max_new=3, waves=2))
        gws.append(gw)
    for a, b in zip(*streams):
        assert a.out_tokens == b.out_tokens
        for ra, rb in zip(a.logits_rows, b.logits_rows):
            np.testing.assert_allclose(ra, rb, atol=1e-5, rtol=0)
    m = gws[1].metrics()["prefix_cache"]
    assert m["cow_copies"] > 0
    assert gws[0].stats["cow_copies"] == 0


def test_tier_and_version_isolation(setup):
    """The same prompt under another tier — or after a weight update —
    must not hit: cached blocks encode one masked view's activations."""
    gw = _gateway(setup)
    prompts = [_shared_prompts(1, 1)[0]] * 2
    _drain(gw, prompts[:1], license="free")
    hits0 = gw.prefix.hits
    _drain(gw, prompts[:1], license="pro")       # same tokens, other tier
    assert gw.prefix.hits == hits0               # no cross-tier reuse
    _drain(gw, prompts[:1], license="free")      # same tier: hit
    assert gw.prefix.hits == hits0 + 1

    cfg, params, _ = setup
    scopes0 = gw.prefix.stats()["scopes"]
    assert scopes0 == 2
    gw.update_weights(jax.tree_util.tree_map(lambda x: x * 1.01, params))
    _drain(gw, prompts[:1], license="free")      # new version: no hit
    assert gw.prefix.hits == hits0 + 1
    # the old version drained, so its scopes (and retained chains) are gone
    assert all(s[1] == gw.version for s in gw.prefix._scopes)


def test_eviction_under_watermark_pressure(setup):
    """A pool too small to retain every chain must keep serving: retained
    refcount-0 chains are evicted LRU-first when admission or decode
    growth needs blocks, and admission's budget counts them as free."""
    # 6 blocks of 4 = 24 cache tokens; each request needs up to 4 blocks
    gw = _gateway(setup, max_lanes=3, num_blocks=6, watermark_blocks=1)
    prompts = [np.random.default_rng(10 + i).integers(0, 500, MAX_PROMPT,
                                                      dtype=np.int32)
               for i in range(6)]
    _drain(gw, prompts, max_new=4, waves=3)
    st = gw.metrics()["prefix_cache"]
    assert st["evicted_blocks"] > 0
    alloc = gw.pool.allocator
    # accounting: every live block is tree-retained (no requests remain)
    assert alloc.num_held == st["retained_blocks"] == st["cached_blocks"]
    assert alloc.num_free + alloc.num_held == gw.pool.num_blocks


def test_preempted_shared_holder_restarts_equivalently(setup):
    """Preempting a request that holds shared (adopted) blocks releases
    references, not blocks; on restart it re-matches the cache and must
    reproduce the tokens of an uncontended run."""
    prompts = _shared_prompts(5, 5)
    ref = _drain(_gateway(setup, prefix_cache=True), prompts, max_new=5)
    # legacy one-shot prefill: chunked admission budgets blocks per
    # request up front and this geometry never oversubscribes (chunked
    # preempt/restart equivalence lives in test_chunked_prefill.py)
    gw = _gateway(setup, prefix_cache=True, max_batch=2, max_lanes=4,
                  num_blocks=7, chunk_size=0)    # oversubscribed: 28 tokens
    reqs = _drain(gw, prompts, max_new=5)
    assert gw.stats["preempted"] > 0
    preempted = [r for r in reqs if r.preemptions]
    assert preempted
    for a, b in zip(ref, reqs):
        assert a.out_tokens == b.out_tokens
    # every request reference came back; only tree retention holds blocks
    st = gw.metrics()["prefix_cache"]
    assert gw.pool.allocator.num_held == st["retained_blocks"]


def test_prefix_disabled_paths_untouched(setup):
    """prefix_cache=False and paged=False keep the PR 2 contract: no
    retention, every block freed on finish, no prefix metrics surprises."""
    gw = _gateway(setup, prefix_cache=False)
    _drain(gw, _shared_prompts(7, 3))
    assert gw.prefix is None
    assert gw.pool.allocator.num_held == 0
    assert gw.metrics()["prefix_cache"] == {"enabled": False}
    gw = _gateway(setup, paged=False)
    _drain(gw, _shared_prompts(8, 3))
    assert gw.prefix is None
    assert gw.metrics()["prefix_cache"] == {"enabled": False}


def test_fully_provisioned_pool_never_preempts(setup):
    """PR 2's guarantee must survive retention: with the default
    fully-provisioned pool (zero spare blocks), a donated tail block's
    first decode write steals the tree's reference back (write in place)
    instead of preempting a running request to afford a CoW copy."""
    # default num_blocks = max_lanes * blocks_per_lane: no headroom at all
    gw = _gateway(setup, block_size=16)      # 1 block per request
    prompts = [np.random.default_rng(20 + i).integers(0, 500, MAX_PROMPT,
                                                      dtype=np.int32)
               for i in range(4)]
    _drain(gw, prompts, max_new=3)
    assert gw.stats["preempted"] == 0
    assert gw.stats["cow_copies"] == 0       # stolen back, not copied
    # every decode step covered the full running group (no thrash)
    assert gw.stats["prefill_batches"] == 2  # 4 requests, 2 lanes


def test_one_token_bucket_releases_unusable_matches(setup):
    """max_prompt=1: every match is capped to 0 reusable tokens (the last
    position must recompute), so the gateway must release the match's
    references instead of leaking them — repeated identical prompts must
    not strand the block."""
    gw = _gateway(setup, max_prompt=1, max_new_cap=4)
    prompt = np.asarray([7], np.int32)
    for _ in range(3):
        _drain(gw, [prompt.copy()], max_new=2)
    alloc = gw.pool.allocator
    st = gw.metrics()["prefix_cache"]
    assert st["matched_tokens"] > 0                  # matches did happen
    assert gw.stats["prefix_tokens_reused"] == 0     # but nothing reusable
    # the retained block is still evictable: only the tree holds it
    assert alloc.num_held == st["retained_blocks"] == 1


# ------------------------------------------- persistent eviction structure
def test_evictable_dict_matches_recount_under_pressure(setup):
    """The incrementally maintained evictable dict (and the O(1)
    reclaimable counter) must agree with a full tree walk at every
    eviction of a real eviction-heavy workload — debug mode asserts
    inside evict(); we recheck at the end for good measure."""
    gw = _gateway(setup, max_lanes=3, num_blocks=6, watermark_blocks=1)
    gw.prefix.debug = True
    prompts = [np.random.default_rng(30 + i).integers(0, 500, MAX_PROMPT,
                                                      dtype=np.int32)
               for i in range(6)]
    # two shared-prefix rounds in the middle so match/insert/CoW churn
    # the structure, not just insert/evict
    prompts[2] = prompts[0].copy()
    prompts[4] = prompts[1].copy()
    _drain(gw, prompts, max_new=4, waves=3)
    assert gw.prefix.evicted_blocks > 0
    gw.prefix._check()
    st = gw.prefix.stats()
    assert st["evictable_leaves"] <= st["retained_blocks"]


def test_evict_order_is_lru_with_chain_promotion():
    """Release order defines the LRU front; a drained chain's parent is
    promoted to the front so whole chains drain before newer leaves."""
    a = BlockAllocator(16)
    pc = PrefixCache(a, block_size=4)
    pc.debug = True
    chains = {}
    for s in range(3):
        toks = [100 * s + i for i in range(8)]
        blocks = a.alloc(2)
        pc.insert("s", toks, blocks)
        chains[s] = (toks, blocks)
    # release in order 1, 2, 0 -> eviction must follow that order
    for s in (1, 2, 0):
        _release(pc, chains[s][1])
    assert pc.stats()["evictable_leaves"] == 3      # one leaf per chain
    assert pc.evict(2) == 2                          # chain 1, leaf first
    assert pc.match("s", chains[1][0]) == ([], 0)
    got, n = pc.match("s", chains[2][0])             # chain 2 untouched
    assert n == 8
    _release(pc, chains[2][1])
    # re-donating an evictable chunk refreshes its LRU position: chain 2
    # moves behind chain 0, so chain 0 drains next
    pc.insert("s", chains[2][0], chains[2][1])
    assert pc.evict(2) == 2
    assert pc.match("s", chains[0][0]) == ([], 0)
    assert pc.match("s", chains[2][0])[1] == 8


def test_evict_one_pops_without_walk():
    """evict(1) must not rebuild anything: exactly one pop from the
    persistent dict, exactly one block freed, structure still exact."""
    a = BlockAllocator(64)
    pc = PrefixCache(a, block_size=4)
    pc.debug = True
    for s in range(10):
        toks = [100 * s + i for i in range(8)]
        blocks = a.alloc(2)
        pc.insert("s", toks, blocks)
        _release(pc, blocks)
    free0 = a.num_free
    assert pc.evict(1) == 1
    assert a.num_free == free0 + 1
    assert pc.stats()["evictable_leaves"] == 9 + 1  # 9 leaves + 1 promoted
    pc._check()


def test_peek_is_side_effect_free():
    a = BlockAllocator(8)
    pc = PrefixCache(a, block_size=4)
    toks = list(range(8))
    blocks = a.alloc(2)
    pc.insert("s", toks, blocks)
    _release(pc, blocks)
    st0 = pc.stats()
    assert pc.peek("s", toks) == 8
    assert pc.peek("s", toks[:4] + [9, 9, 9, 9]) == 4
    assert pc.peek("s", [9] * 8) == 0
    assert pc.peek("other", toks) == 0
    assert pc.stats() == st0                       # no hits/misses/touches
    assert all(a.refcount(b) == 1 for b in blocks)  # no references taken


# ------------------------------------------------ prefix-aware admission
def test_full_match_lane_gets_its_own_narrow_batch(setup):
    """A full-match request must not pad to a cold request's suffix
    width: the scheduler groups prefills by cached-suffix bucket, so the
    hit prefills 1 lane-token while the cold one prefills max_prompt.
    (Suffix-width grouping is the legacy bucket path: pin chunk_size=0.)"""
    gw = _gateway(setup, chunk_size=0)
    a = _shared_prompts(40, 1)[0]
    _drain(gw, [a.copy()], max_new=2)              # wave 1: populate
    lane_tokens0 = gw.stats["prefill_lane_tokens"]
    assert lane_tokens0 == MAX_PROMPT
    b = _shared_prompts(41, 1, shared=0)[0]        # unrelated cold prompt
    _drain(gw, [a.copy(), b], max_new=2)           # wave 2: hit + cold
    # grouped: 1 (full match, W=1) + 8 (cold) — ungrouped would be 16
    assert gw.stats["prefill_lane_tokens"] == lane_tokens0 + 1 + MAX_PROMPT
    m = gw.metrics()["admission_grouping"]
    assert m["enabled"] is True
    assert m["batches_by_suffix_width"] == {MAX_PROMPT: 2, 1: 1}
    assert gw.stats["prefill_batches"] == 3


def test_stale_suffix_probe_revalidated_at_formation(setup):
    """A cached suffix-bucket probe is a scheduling hint that can go
    stale between probe and admission (eviction, epoch desync).  Batch
    formation must re-probe every selected member fresh: a forged stale
    probe claiming the full-match bucket must NOT drag a cold prompt
    into the W=1 batch — it gets requeued into its own wide batch."""
    gw = _gateway(setup, chunk_size=0)
    a = _shared_prompts(60, 1)[0]
    _drain(gw, [a.copy()], max_new=2)              # populate: a full-matches
    r1 = gw.submit(a.copy(), license="free", max_new_tokens=2)
    r2 = gw.submit(_shared_prompts(61, 1, shared=0)[0], license="free",
                   max_new_tokens=2)
    # forge a stale-but-current-epoch probe on the cold request claiming
    # the anchor's full-match bucket (suffix width 1)
    r2._suffix_probe = (gw.prefix.epoch, 1)
    gw.run()
    assert r1.state == RequestState.DONE and r2.state == RequestState.DONE
    m = gw.metrics()["admission_grouping"]
    # populate wave (W=8) + full-match batch (W=1) + the re-validated
    # cold request's own wide batch (W=8) — never a cold prompt at W=1
    assert m["batches_by_suffix_width"] == {MAX_PROMPT: 2, 1: 1}
    assert gw.stats["prefill_batches"] == 3


def test_grouping_decision_exposed_and_inert_when_disabled(setup):
    gw = _gateway(setup, prefix_cache=False)
    _drain(gw, _shared_prompts(42, 2), max_new=2)
    m = gw.metrics()["admission_grouping"]
    assert m["enabled"] is False
    assert m["batches_by_suffix_width"] == {}


def test_pure_ssm_model_disables_prefix_cache():
    """A model whose cache can't be block-seeded (recurrent state) falls
    back to the contiguous pool — prefix caching silently off, serving
    still correct."""
    cfg = smoke_variant(get_config("mamba2-130m"))
    params = init_params(jax.random.PRNGKey(1), cfg)
    gw = LicensedGateway(cfg, params, max_batch=2, max_prompt=4,
                         max_new_cap=2, paged=True, prefix_cache=True)
    assert gw.paged is False and gw.prefix is None
    r = gw.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=2)
    gw.run()
    assert r.state == RequestState.DONE
