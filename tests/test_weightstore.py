"""WeightStore: versioning, delta updates, rollback, tiers (paper Fig. 4)."""
import numpy as np
import pytest

from repro.core.weightstore import WeightStore


def small_params(seed=0, scale=1.0):
    r = np.random.default_rng(seed)
    return {
        "dense1/kernel": (r.standard_normal((8, 16)) * scale).astype(np.float32),
        "dense1/bias_vec": np.zeros((16,), np.float32),
        "dense2/kernel": (r.standard_normal((16, 4)) * scale).astype(np.float32),
    }


@pytest.fixture
def store():
    s = WeightStore(":memory:")
    yield s
    s.close()


def test_commit_checkout_roundtrip(store):
    p = small_params()
    store.register_model("mlp", "dense")
    v1 = store.commit("mlp", p)
    out = store.checkout("mlp", v1)
    for k in p:
        np.testing.assert_allclose(out[k], p[k], rtol=1e-6)


def test_incremental_commit_stores_only_changes(store):
    p = small_params()
    store.register_model("mlp", "dense")
    v1 = store.commit("mlp", p)
    rows_v1 = store.storage_bytes("mlp")["weight_rows"]

    p2 = {k: v.copy() for k, v in p.items()}
    p2["dense2/kernel"][0, 0] += 1.0  # single weight change
    v2 = store.commit("mlp", p2, parent=v1)
    rows_v2 = store.storage_bytes("mlp")["weight_rows"]
    assert rows_v2 == rows_v1 + 1  # paper §3.1.2: only changed weights stored

    out = store.checkout("mlp", v2)
    np.testing.assert_allclose(out["dense2/kernel"], p2["dense2/kernel"])
    np.testing.assert_allclose(out["dense1/kernel"], p["dense1/kernel"])


def test_zeroed_weight_is_recorded_as_change(store):
    p = small_params()
    store.register_model("mlp", "dense")
    v1 = store.commit("mlp", p)
    p2 = {k: v.copy() for k, v in p.items()}
    p2["dense1/kernel"][3, 3] = 0.0
    v2 = store.commit("mlp", p2, parent=v1)
    out = store.checkout("mlp", v2)
    assert out["dense1/kernel"][3, 3] == 0.0


def test_delta_since_skips_intermediate_patches(store):
    """Paper §4.2: client on v1 gets all v2+v3 changes in ONE packet."""
    p = small_params()
    store.register_model("mlp", "dense")
    v1 = store.commit("mlp", p)
    p2 = {k: v.copy() for k, v in p.items()}
    p2["dense1/kernel"][0, 0] = 7.0
    v2 = store.commit("mlp", p2, parent=v1)
    p3 = {k: v.copy() for k, v in p2.items()}
    p3["dense1/kernel"][0, 1] = 9.0
    p3["dense2/kernel"][1, 1] = -3.0
    v3 = store.commit("mlp", p3, parent=v2)

    packet = store.delta_since("mlp", v1)
    assert packet.to_version == v3
    assert packet.num_entries == 3
    layers = {d.layer for d in packet.deltas}
    assert layers == {"dense1/kernel", "dense2/kernel"}


def test_delta_latest_version_wins(store):
    p = small_params()
    store.register_model("mlp", "dense")
    v1 = store.commit("mlp", p)
    p2 = {k: v.copy() for k, v in p.items()}
    p2["dense1/kernel"][0, 0] = 7.0
    store.commit("mlp", p2, parent=v1)
    p3 = {k: v.copy() for k, v in p2.items()}
    p3["dense1/kernel"][0, 0] = 8.0  # same index changed again
    store.commit("mlp", p3)
    packet = store.delta_since("mlp", v1)
    d = [d for d in packet.deltas if d.layer == "dense1/kernel"][0]
    assert len(d.indices) == 1 and d.values[0] == 8.0


def test_rollback_repoints_production(store):
    p = small_params()
    store.register_model("mlp", "dense")
    v1 = store.commit("mlp", p)
    p2 = {k: v * 2 for k, v in p.items()}
    v2 = store.commit("mlp", p2, parent=v1)
    assert store.production_version("mlp") == v2
    store.rollback("mlp", v1)
    assert store.production_version("mlp") == v1
    out = store.checkout("mlp")
    np.testing.assert_allclose(out["dense1/kernel"], p["dense1/kernel"])


def test_major_version_is_full_snapshot(store):
    p = small_params(0)
    store.register_model("mlp", "dense")
    v1 = store.commit("mlp", p)
    q = small_params(1)
    v2 = store.commit("mlp", q, major=True)
    out = store.checkout("mlp", v2)
    np.testing.assert_allclose(out["dense1/kernel"], q["dense1/kernel"])
    # client on the other major branch gets a full snapshot
    packet = store.delta_since("mlp", v1)
    assert packet.to_version == v2


def test_pruned_zeros_not_stored(store):
    p = small_params()
    p["dense1/kernel"][np.abs(p["dense1/kernel"]) < 0.5] = 0.0
    store.register_model("mlp", "dense")
    store.commit("mlp", p)
    nz = sum(int(np.count_nonzero(v)) for v in p.values())
    assert store.storage_bytes("mlp")["weight_rows"] == nz


def test_chunk_mode_for_large_layers():
    s = WeightStore(":memory:", row_limit=100, chunk_elems=64)
    r = np.random.default_rng(0)
    p = {"big/kernel": r.standard_normal((32, 32)).astype(np.float32)}  # 1024 > 100
    s.register_model("big", "dense")
    v1 = s.commit("big", p)
    out = s.checkout("big", v1)
    np.testing.assert_allclose(out["big/kernel"], p["big/kernel"], rtol=1e-6)
    # single-element change touches exactly one chunk
    p2 = {"big/kernel": p["big/kernel"].copy()}
    p2["big/kernel"][0, 0] += 1.0
    v2 = s.commit("big", p2, parent=v1)
    packet = s.delta_since("big", v1)
    d = packet.deltas[0]
    assert d.chunks is not None and len(d.chunks) == 1
    out2 = s.checkout("big", v2)
    np.testing.assert_allclose(out2["big/kernel"], p2["big/kernel"], rtol=1e-6)
    s.close()


def test_history_and_tiers(store):
    p = small_params()
    store.register_model("mlp", "dense")
    v1 = store.commit("mlp", p, tag="v1.0", message="init")
    hist = store.history("mlp")
    assert len(hist) == 1 and hist[0]["tag"] == "v1.0"
    store.register_tier("mlp", v1, "free", 0.70, {"dense1": [(0.5, 0.8)]})
    acc, masks = store.get_tier("mlp", "free")
    assert acc == 0.70 and masks["dense1"] == [(0.5, 0.8)]
    assert store.list_tiers("mlp") == [("free", 0.70)]
