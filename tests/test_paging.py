"""Block-paged cache pool: allocator invariants, paged-vs-contiguous
equivalence, preemption round-trips, fused sampling, scheduler fairness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; plain tests still run
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.models import init_params
from repro.serving import (BlockAllocator, GatewayRequest, LicensedGateway,
                           PagedCachePool, RequestState, Scheduler)

MAX_PROMPT = 8
MAX_NEW = 8          # capacity 16: divisible by block sizes 4/8/16, so the
                     # paged pools share one decode compilation with the
                     # contiguous pool (padded capacity == capacity)


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {
        "free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)}),
        "pro": LicenseTier(name="pro", masks={"*": ((0.0, 0.002),)}),
    }
    return cfg, params, tiers


def _gateway(setup, **kw):
    cfg, params, tiers = setup
    kw.setdefault("max_batch", 4)
    kw.setdefault("max_prompt", MAX_PROMPT)
    kw.setdefault("max_new_cap", MAX_NEW)
    return LicensedGateway(cfg, params, tiers=tiers, **kw)


def _prompt(seed, n=MAX_PROMPT):
    return np.random.default_rng(seed).integers(0, 500, n, dtype=np.int32)


# ------------------------------------------------------------ BlockAllocator
def test_allocator_basic_invariants():
    a = BlockAllocator(8)
    got = a.alloc(5)
    assert got is not None and len(got) == 5 and len(set(got)) == 5
    assert a.num_free == 3 and a.num_held == 5
    assert a.alloc(4) is None                 # all-or-nothing: no partials
    assert a.num_free == 3                    # failed alloc takes nothing
    more = a.alloc(3)
    assert not set(got) & set(more)           # never double-allocated
    a.free(got + more)
    assert a.num_free == 8 and a.num_held == 0
    with pytest.raises(ValueError):
        a.free([got[0]])                      # double-free detected


def test_allocator_rejects_foreign_and_bad_sizes():
    a = BlockAllocator(4)
    with pytest.raises(ValueError):
        a.free([99])
    with pytest.raises(ValueError):
        a.alloc(-1)
    with pytest.raises(ValueError):
        BlockAllocator(0)
    assert a.alloc(0) == []


def test_allocator_refcount_guards():
    """The double-alloc/free guards extend to the sharing paths: incref
    on a freed block raises, free with live shared refs raises, and a
    block only returns to the pool when the last reference drops."""
    a = BlockAllocator(4)
    (b,) = a.alloc(1)
    assert a.refcount(b) == 1
    assert a.incref(b) == 2                   # second holder (prefix cache)
    with pytest.raises(ValueError):
        a.free([b])                           # live refs: hard free refused
    assert a.num_held == 1 and a.num_free == 3
    assert a.decref(b) == 1                   # still held by one
    assert a.num_free == 3
    assert a.decref(b) == 0                   # last ref: back to the pool
    assert a.num_free == 4 and a.refcount(b) == 0
    with pytest.raises(ValueError):
        a.incref(b)                           # incref on a freed block
    with pytest.raises(ValueError):
        a.decref(b)                           # over-release
    with pytest.raises(ValueError):
        a.incref(99)                          # foreign id
    # free() still works for exclusively-held blocks (the non-shared path)
    got = a.alloc(2)
    a.free(got)
    assert a.num_free == 4


@settings(max_examples=30, deadline=None)
@given(
    num_blocks=st.integers(min_value=1, max_value=32),
    ops=st.lists(st.integers(min_value=0, max_value=11), max_size=60),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_allocator_property(num_blocks, ops, seed):
    """Property: live allocations stay disjoint; freeing everything always
    restores the full pool; accounting never drifts."""
    r = np.random.default_rng(seed)
    a = BlockAllocator(num_blocks)
    live = []                                  # list of allocation lists
    for op in ops:
        if op % 2 == 0 or not live:            # alloc of size op//2
            before = a.num_free
            got = a.alloc(op // 2)
            if got is None:
                assert op // 2 > before        # fails only when short
                assert a.num_free == before    # and takes nothing
            else:
                live.append(got)
        else:                                  # free a random allocation
            a.free(live.pop(int(r.integers(len(live)))))
        held = [b for alloc in live for b in alloc]
        assert len(held) == len(set(held)) == a.num_held
        assert a.num_free + a.num_held == num_blocks
    for alloc in live:
        a.free(alloc)
    assert a.num_free == num_blocks


# ------------------------------------------------------------ PagedCachePool
def test_pool_gather_scatter_roundtrip(setup):
    cfg, _, _ = setup
    pool = PagedCachePool(cfg, num_lanes=3, capacity=16, block_size=4,
                          num_blocks=12)
    t0 = pool.allocator.alloc(4)
    t1 = pool.allocator.alloc(4)
    lanes = pool.pad_lanes([0, 1], 2)
    tables = pool.pad_tables([t0, t1], 2)
    view = pool.gather(lanes, tables)
    # write distinct per-lane payloads through the tables
    marked = jax.tree_util.tree_map(
        lambda x: (jnp.zeros_like(x)
                   + jnp.arange(1, 3, dtype=jnp.float32).reshape(
                       2, *([1] * (x.ndim - 1))).astype(x.dtype)),
        view)
    pool.scatter(lanes, tables, marked)
    back = pool.gather(lanes, tables)
    for a, b in zip(jax.tree_util.tree_leaves(marked),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # disjoint tables: lane 0's writes must not leak into lane 1's blocks
    solo = pool.gather([1], tables[1:])
    for leaf in jax.tree_util.tree_leaves(solo):
        vals = np.unique(np.asarray(leaf, np.float32))
        assert 1.0 not in vals


def test_pool_rejects_undersized_and_pageless(setup):
    cfg, _, _ = setup
    with pytest.raises(ValueError):
        PagedCachePool(cfg, 2, capacity=16, block_size=4, num_blocks=3)
    ssm = smoke_variant(get_config("mamba2-130m"))
    with pytest.raises(ValueError):   # no per-token leaves to page
        PagedCachePool(ssm, 2, capacity=16, block_size=4, num_blocks=8)


def test_gateway_falls_back_to_contiguous_for_pure_ssm():
    cfg = smoke_variant(get_config("mamba2-130m"))
    params = init_params(jax.random.PRNGKey(1), cfg)
    gw = LicensedGateway(cfg, params, max_batch=2, max_prompt=4,
                         max_new_cap=2, paged=True)
    assert gw.paged is False
    r = gw.submit(_prompt(0, 4), max_new_tokens=2)
    gw.run()
    assert r.state == RequestState.DONE


# ------------------------------------------------- paged == contiguous logits
def test_paged_matches_contiguous_logits_mixed_lengths(setup):
    """The acceptance bar: same mixed-length stream through both pools,
    per-step logits equal to 1e-5 and identical sampled tokens."""
    streams = []
    for paged in (False, True):
        gw = _gateway(setup, max_batch=2, paged=paged, block_size=4,
                      record_logits=True)
        reqs = [gw.submit(_prompt(i), license=lic, max_new_tokens=2 + 2 * (i % 3))
                for i, lic in enumerate(["full", "free", "free", "full", "pro"])]
        gw.run()
        assert all(r.state == RequestState.DONE for r in reqs)
        streams.append(reqs)
    for a, b in zip(*streams):
        assert a.out_tokens == b.out_tokens
        assert len(a.logits_rows) == len(b.logits_rows) == a.max_new_tokens
        for ra, rb in zip(a.logits_rows, b.logits_rows):
            np.testing.assert_allclose(ra, rb, atol=1e-5, rtol=0)


def test_admission_bounds_sampling_params(setup):
    """A bad seed is REJECTED (not a mid-service crash in the fused lane
    arrays); an oversized top_k is clamped to the vocab, where both
    samplers agree it truncates nothing."""
    gw = _gateway(setup, max_batch=2)
    r = gw.submit(_prompt(0), license="free", seed=2**31)
    assert r.state == RequestState.REJECTED and "seed" in r.error
    r = gw.submit(_prompt(0), license="free", seed=-2**31 - 1)
    assert r.state == RequestState.REJECTED
    cfg = gw.cfg
    r = gw.submit(_prompt(1), license="free", max_new_tokens=2,
                  top_k=cfg.padded_vocab + 5, temperature=0.5)
    assert r.state != RequestState.REJECTED
    assert r.top_k == cfg.padded_vocab
    gw.run()
    assert r.state == RequestState.DONE


def test_fused_sampling_matches_host_sampling(setup):
    """Fused on-device sampling returns the same tokens as the
    return-logits escape hatch, greedy AND stochastic (temp + top-k)."""
    outs = []
    for fuse in (True, False):
        gw = _gateway(setup, max_batch=2, fuse_sampling=fuse)
        rs = [gw.submit(_prompt(3), license="free", max_new_tokens=4),
              gw.submit(_prompt(4), license="free", max_new_tokens=4,
                        temperature=0.8, top_k=5, seed=7)]
        gw.run()
        outs.append([r.out_tokens for r in rs])
    assert outs[0] == outs[1]


# ------------------------------------------------------- preemption/requeue
def test_preemption_requeue_roundtrip(setup):
    """An oversubscribed pool must preempt (youngest first), requeue, and
    still complete every request with exactly its token budget — and the
    restarted requests must reproduce the tokens of an uncontended run."""
    want = {}
    # prefix_cache=False: this test pins the PR 2 free-everything contract
    # (every block returns on finish); retention semantics are covered in
    # test_prefix.py
    gw = _gateway(setup, max_batch=2, paged=True, block_size=4,
                  prefix_cache=False)
    for i in range(5):
        r = gw.submit(_prompt(i), license="free", max_new_tokens=3 + 2 * (i % 2))
        want[i] = r
    gw.run()
    assert gw.stats["preempted"] == 0          # fully provisioned

    # 28 tokens for 4 lanes of 16: chunked admission budgets blocks per
    # request up front, so the pool must be this tight before decode
    # growth outruns what admission reserved and preemption fires
    gw2 = _gateway(setup, max_batch=2, paged=True, block_size=4,
                   prefix_cache=False,
                   max_lanes=4, num_blocks=7)
    reqs = [gw2.submit(_prompt(i), license="free", max_new_tokens=3 + 2 * (i % 2))
            for i in range(5)]
    gw2.run()
    assert gw2.stats["preempted"] > 0
    # replayed tokens must not inflate the delivered-token counter
    assert gw2.stats["tokens_generated"] == \
        sum(r.max_new_tokens for r in reqs)
    for i, r in enumerate(reqs):
        assert r.state == RequestState.DONE
        assert len(r.out_tokens) == r.max_new_tokens
        assert r.out_tokens == want[i].out_tokens   # restart is deterministic
    assert gw2.pool.allocator.num_held == 0         # every block came back
    preempted = [r for r in reqs if r.preemptions]
    assert preempted and all(r.state == RequestState.DONE for r in preempted)


def test_preemption_guard_single_request():
    """The constructor refuses pools that cannot hold one full request."""
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        LicensedGateway(cfg, params, max_batch=2, max_prompt=8,
                        max_new_cap=8, paged=True, block_size=4,
                        num_blocks=3)


def test_watermark_cannot_deadlock_admission():
    """A watermark that would leave admission permanently starved is a
    config error at construction, not a gateway that serves nothing."""
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError):
        LicensedGateway(cfg, params, max_batch=2, max_prompt=8,
                        max_new_cap=8, paged=True, block_size=4,
                        num_blocks=4, watermark_blocks=3)


# ------------------------------------------------------- scheduler fairness
def test_prefill_serves_oldest_group_not_queue_head():
    """A requeued hot-tier request at the deque head must not starve an
    older cold-tier request sitting behind it (queue-wait aging)."""
    s = Scheduler(num_lanes=4, max_batch=4)
    hot = GatewayRequest(prompt=np.zeros(4, np.int32), license="hot")
    hot.version = 1
    hot.submit_t = 10.0
    cold = GatewayRequest(prompt=np.zeros(4, np.int32), license="cold")
    cold.version = 1
    cold.submit_t = 1.0
    s.submit(cold)
    s.submit(hot)
    s.waiting.rotate(1)                       # hot now at the head (requeue)
    assert s.waiting[0] is hot
    act = s.next_action()
    assert act.kind == "prefill"
    assert [r.license for r in act.requests] == ["cold"]


def test_equal_age_falls_back_to_fifo_order():
    s = Scheduler(num_lanes=4, max_batch=4)
    for lic in ["b_tier", "a_tier"]:
        r = GatewayRequest(prompt=np.zeros(4, np.int32), license=lic)
        r.version = 1
        s.submit(r)                           # both submit_t == 0.0
    act = s.next_action()
    assert [r.license for r in act.requests] == ["b_tier"]  # head wins ties


def test_wait_age_metrics_exposed(setup):
    gw = _gateway(setup, max_batch=2)
    for i in range(4):
        gw.submit(_prompt(i), license="free", max_new_tokens=2)
    m = gw.metrics()
    assert m["oldest_wait_s"] >= 0.0
    assert "free" in m["queue_wait_by_tier"]
    assert m["cache_pool"]["paged"] is True
    gw.run()
    m = gw.metrics()
    assert m["oldest_wait_s"] == 0.0          # queue drained
    assert m["max_running"] >= 2
