"""Per-rule bad/good fixtures for the serving-invariant lint pass.

Each rule gets (at least) one fixture tree that must trip it with a
``path:line`` diagnostic and one that must stay clean; plus the
suppression syntax, the CLI exit-code contract, and the meta-check that
the repo's own ``src/`` tree lints clean (satellite: zero suppressions
in serving/).
"""
import subprocess
import sys
from pathlib import Path

import pytest

from repro.analysis.lint import main, run_paths

REPO = Path(__file__).resolve().parent.parent


def _write(root: Path, rel: str, text: str) -> Path:
    p = root / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(text)
    return p


def _diags(root, rule=None):
    from repro.analysis.rules import ALL_RULES

    rules = None
    if rule is not None:
        rules = [r for r in ALL_RULES if r.name == rule]
        assert rules, f"unknown rule {rule}"
    return run_paths([root], rules)


# ----------------------------------------------------------------- RULE-CLOCK
def test_clock_flags_bare_calls_in_serving(tmp_path):
    _write(tmp_path, "serving/gateway.py", (
        "import time\n"
        "def wait():\n"
        "    t0 = time.monotonic()\n"
        "    return t0\n"))
    out = _diags(tmp_path, "clock")
    assert len(out) == 1
    assert out[0].line == 3 and out[0].rule == "clock"
    assert "serving/gateway.py" in out[0].path


def test_clock_allows_injection_references(tmp_path):
    # references (injection-point defaults) are the sanctioned idiom —
    # only *calls* are flagged
    _write(tmp_path, "serving/gateway.py", (
        "import time\n"
        "def make(clock=time.perf_counter):\n"
        "    return clock()\n"))
    assert _diags(tmp_path, "clock") == []


def test_clock_ignores_out_of_scope_files(tmp_path):
    _write(tmp_path, "training/loop.py", (
        "import time\n"
        "t = time.time()\n"))
    assert _diags(tmp_path, "clock") == []


def test_clock_suppression_comment(tmp_path):
    _write(tmp_path, "serving/gateway.py", (
        "import time\n"
        "t0 = time.monotonic()  # lint: allow-clock\n"
        "# lint: allow-clock\n"
        "t1 = time.monotonic()\n"
        "t2 = time.monotonic()\n"))
    out = _diags(tmp_path, "clock")
    assert [d.line for d in out] == [5]       # only the unsuppressed one


# ------------------------------------------------------------------- RULE-OBS
_OBS_BAD = (
    "class G:\n"
    "    def step(self):\n"
    "        self.tracer.begin('step', 1)\n")

_OBS_GOOD = (
    "class G:\n"
    "    def step(self):\n"
    "        if self.obs:\n"
    "            self.tracer.begin('step', 1)\n"
    "    def emit(self):\n"
    "        if not self.obs:\n"
    "            return\n"
    "        self.h.observe(0.5)\n"
    "        self.audit.record('flip', v=2)\n"
    "    def reg(self):\n"
    "        if self.audit is not None:\n"
    "            self.audit.record('grant', t='free')\n")


def test_obs_flags_unguarded_record_sites(tmp_path):
    _write(tmp_path, "serving/fleet.py", _OBS_BAD)
    out = _diags(tmp_path, "obs")
    assert len(out) == 1 and out[0].line == 3


def test_obs_accepts_guard_styles(tmp_path):
    # enclosing if, early-out, and the optional-audit idiom all count
    _write(tmp_path, "serving/fleet.py", _OBS_GOOD)
    assert _diags(tmp_path, "obs") == []


def test_obs_exempts_instrument_implementations(tmp_path):
    _write(tmp_path, "serving/telemetry.py", _OBS_BAD)
    _write(tmp_path, "serving/tracing.py", _OBS_BAD)
    assert _diags(tmp_path, "obs") == []


# ------------------------------------------------------------ RULE-GUARDED-BY
def test_guarded_by_lock_discipline(tmp_path):
    _write(tmp_path, "serving/transport.py", (
        "class T:\n"
        "    def __init__(self):\n"
        "        self._counts = {}  # guarded-by: _lock\n"
        "    def good(self, op, n):\n"
        "        with self._lock:\n"
        "            self._counts[op] = 1\n"
        "            self._counts = {}\n"
        "    def bad(self):\n"
        "        self._counts = {}\n"))
    out = _diags(tmp_path, "guarded-by")
    assert [d.line for d in out] == [9]
    assert "_lock" in out[0].message


def test_guarded_by_owner_discipline(tmp_path):
    _write(tmp_path, "serving/updates.py", (
        "class S:\n"
        "    def __init__(self):\n"
        "        self._pos = (0, 0)  # guarded-by: owner(__init__, begin)\n"
        "    def begin(self):\n"
        "        self._pos = (1, 0)\n"
        "    def rogue(self):\n"
        "        self._pos = (9, 9)\n"))
    out = _diags(tmp_path, "guarded-by")
    assert [d.line for d in out] == [7]
    assert "rogue" in out[0].message


def test_guarded_by_tuple_assignment_target(tmp_path):
    # ``old, self._cursor = self._cursor, None`` is still a write
    _write(tmp_path, "serving/updates.py", (
        "class S:\n"
        "    def __init__(self):\n"
        "        self._cursor = None  # guarded-by: owner(__init__)\n"
        "    def swap(self):\n"
        "        old, self._cursor = self._cursor, None\n"
        "        return old\n"))
    out = _diags(tmp_path, "guarded-by")
    assert [d.line for d in out] == [5]


# -------------------------------------------------------------- RULE-HOT-PATH
def test_hot_path_flags_per_iteration_sync(tmp_path):
    _write(tmp_path, "serving/scheduler.py", (
        "import jax.numpy as jnp\n"
        "import numpy as np\n"
        "def step(xs):\n"
        "    acc = []\n"
        "    for x in xs:\n"
        "        acc.append(float(jnp.sum(x)))\n"
        "    outs = np.asarray(jnp.stack(acc))\n"   # boundary: legal
        "    return outs\n"))
    out = _diags(tmp_path, "hot-path")
    assert [d.line for d in out] == [6]


def test_hot_path_flags_explicit_fences(tmp_path):
    _write(tmp_path, "serving/engine.py", (
        "import jax\n"
        "def step(y):\n"
        "    y.block_until_ready()\n"
        "    return jax.device_get(y)\n"))
    out = _diags(tmp_path, "hot-path")
    assert [d.line for d in out] == [3, 4]


def test_hot_path_ignores_host_staging_and_benchmarks(tmp_path):
    _write(tmp_path, "serving/gateway.py", (
        "import jax.numpy as jnp\n"
        "def stage(rows):\n"
        "    for r in rows:\n"
        "        x = jnp.asarray(r)\n"      # host->device: not a sync
        "    return x\n"))
    _write(tmp_path, "bench/decode.py", (
        "def bench(y):\n"
        "    y.block_until_ready()\n"))     # benchmarks are out of scope
    assert _diags(tmp_path, "hot-path") == []


# ---------------------------------------------------------------- RULE-KERNEL
_KERNEL_GOOD = (
    "import jax\n"
    "from jax.experimental import pallas as pl\n"
    "def addone(x, interpret=False):\n"
    "    return pl.pallas_call(lambda r, o: None, out_shape=x,\n"
    "                          interpret=interpret)(x)\n")


def test_kernel_requires_interpret_and_oracle(tmp_path):
    _write(tmp_path, "kernels/bad.py", (
        "from jax.experimental import pallas as pl\n"
        "def mystery(x):\n"
        "    return pl.pallas_call(lambda r, o: None, out_shape=x)(x)\n"))
    out = _diags(tmp_path, "kernel")
    msgs = "\n".join(d.message for d in out)
    assert "interpret" in msgs
    assert "ref.py" in msgs


def test_kernel_clean_with_oracle_pair(tmp_path):
    _write(tmp_path, "kernels/addone.py", _KERNEL_GOOD)
    _write(tmp_path, "kernels/ref.py", "def addone(x):\n    return x + 1\n")
    assert _diags(tmp_path, "kernel") == []


def test_kernel_donate_requires_alias(tmp_path):
    _write(tmp_path, "kernels/donated.py", (
        "import jax\n"
        "from jax.experimental import pallas as pl\n"
        "def _call(x):\n"
        "    return pl.pallas_call(lambda r, o: None, out_shape=x,\n"
        "                          interpret=False)(x)\n"
        "@jax.jit(donate_argnums=(0,))\n"
        "def donated(x):\n"
        "    return _call(x)\n"))
    _write(tmp_path, "kernels/ref.py", "def donated(x):\n    return x\n")
    out = _diags(tmp_path, "kernel")
    assert len(out) == 1 and "donate_argnums" in out[0].message


def test_kernel_alias_keys_must_index_operands(tmp_path):
    _write(tmp_path, "kernels/aliased.py", (
        "from jax.experimental import pallas as pl\n"
        "def scatter(a, b):\n"
        "    return pl.pallas_call(lambda r, o: None, out_shape=a,\n"
        "                          input_output_aliases={5: 0},\n"
        "                          interpret=False)(a, b)\n"))
    _write(tmp_path, "kernels/ref.py", "def scatter(a, b):\n    return a\n")
    out = _diags(tmp_path, "kernel")
    assert len(out) == 1 and "exceeds" in out[0].message


# --------------------------------------------------------------- RULE-METRICS
_METRICS_DOC = (
    "# Observability\n"
    "| series | type |\n"
    "|---|---|\n"
    "| `serving_requests_{admitted,rejected}_total` | counter |\n"
    "| `serving_phantom_total` | counter |\n")

_METRICS_SRC = (
    "class M:\n"
    "    def reg(self, t):\n"
    "        t.counter('serving_requests_admitted_total')\n"
    "        t.counter('serving_requests_rejected_total')\n"
    "        t.counter('serving_undocumented_total')\n")


def test_metrics_cross_checks_code_and_docs(tmp_path):
    _write(tmp_path, "docs/OBSERVABILITY.md", _METRICS_DOC)
    _write(tmp_path, "serving/fleet.py", _METRICS_SRC)
    out = _diags(tmp_path, "metrics")
    msgs = {d.message.split("`")[1]: d for d in out}
    assert set(msgs) == {"serving_undocumented_total",
                         "serving_phantom_total"}
    assert "serving/fleet.py" in msgs["serving_undocumented_total"].path
    assert msgs["serving_phantom_total"].path.endswith("OBSERVABILITY.md")


def test_metrics_flags_duplicate_declared_keys(tmp_path):
    _write(tmp_path, "serving/telemetry.py", (
        "GATEWAY_METRICS_KEYS = (\n"
        "    'admitted', 'rejected', 'admitted',\n"
        ")\n"))
    out = _diags(tmp_path, "metrics")
    assert len(out) == 1 and "duplicate" in out[0].message


def test_metrics_export_table_keys_must_be_declared(tmp_path):
    _write(tmp_path, "serving/telemetry.py",
           "GATEWAY_METRICS_KEYS = ('admitted',)\n")
    _write(tmp_path, "serving/fleet.py", (
        "TABLE = [\n"
        "    ('admitted', 'serving_requests_admitted_total', 'ok'),\n"
        "    ('ghost', 'serving_ghosts_total', 'not declared'),\n"
        "]\n"))
    out = _diags(tmp_path, "metrics")
    assert len(out) == 1 and "ghost" in out[0].message


# ------------------------------------------------------------------ CLI / API
def test_cli_exit_codes(tmp_path, capsys):
    bad = _write(tmp_path, "serving/gateway.py",
                 "import time\nt = time.monotonic()\n")
    good = _write(tmp_path, "serving/clean.py", "x = 1\n")
    assert main([str(good)]) == 0
    assert main([str(bad)]) == 1
    rendered = capsys.readouterr().out
    assert "RULE-CLOCK" in rendered and ":2:" in rendered
    with pytest.raises(SystemExit) as exc:
        main([str(bad), "--rule", "no-such-rule"])
    assert exc.value.code == 2
    with pytest.raises(SystemExit):
        main([str(tmp_path / "no-such-tree")])


def test_cli_rule_filter(tmp_path):
    bad = _write(tmp_path, "serving/gateway.py",
                 "import time\nt = time.monotonic()\n")
    assert main([str(bad), "--rule", "obs"]) == 0      # clock finding masked
    assert main([str(bad), "--rule", "clock"]) == 1


def test_module_entrypoint_runs():
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", "--help"],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(REPO / "src"), "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0
    assert "docs/ANALYSIS.md" in out.stdout


# -------------------------------------------------------------- the real tree
def test_repo_serving_tree_is_clean():
    """The merged tree lints clean — and with zero suppressions under
    serving/ (the satellite contract)."""
    diags = run_paths([REPO / "src"])
    assert diags == [], "\n".join(d.render() for d in diags)
    for p in (REPO / "src" / "repro" / "serving").rglob("*.py"):
        assert "lint: allow-" not in p.read_text(), \
            f"suppression found in serving/: {p}"
