"""Licensing (paper §3.5, Algorithm 1) + compression (§3.2) behaviour."""
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; plain tests still run
    from _hypothesis_compat import given, settings, st

from repro.core import compression as comp
from repro.core.licensing import (
    FULL_TIER,
    LicenseTier,
    apply_license,
    calibrate_license,
    license_stats,
    mask_weight,
)


def mlp_params(seed=0):
    r = np.random.default_rng(seed)
    return {
        "layer1": {"kernel": r.standard_normal((32, 64)).astype(np.float32)},
        "layer2": {"kernel": r.standard_normal((64, 32)).astype(np.float32)},
        "out": {"kernel": r.standard_normal((32, 10)).astype(np.float32),
                 "norm": np.ones((10,), np.float32)},
    }


# ------------------------------------------------------------------- masking
def test_interval_mask_zeroes_only_the_band():
    w = jnp.asarray(np.linspace(-2, 2, 101), dtype=jnp.float32).reshape(1, -1)
    out = np.asarray(mask_weight(w, [(0.5, 0.8)]))
    mag = np.abs(np.asarray(w))
    assert (out[(mag >= 0.5) & (mag < 0.8)] == 0).all()
    keep = (mag < 0.5) | (mag >= 0.8)
    np.testing.assert_array_equal(out[keep], np.asarray(w)[keep])


def test_apply_license_full_tier_is_identity():
    p = mlp_params()
    out = apply_license(p, FULL_TIER)
    np.testing.assert_array_equal(out["layer1"]["kernel"], p["layer1"]["kernel"])


def test_apply_license_pattern_scoping():
    p = mlp_params()
    tier = LicenseTier(name="free", masks={"layer1": ((0.5, 0.8),)})
    out = apply_license(p, tier)
    w1 = np.asarray(out["layer1"]["kernel"])
    mag = np.abs(p["layer1"]["kernel"])
    assert (w1[(mag >= 0.5) & (mag < 0.8)] == 0).all()
    # other layers untouched
    np.testing.assert_array_equal(np.asarray(out["layer2"]["kernel"]), p["layer2"]["kernel"])


def test_apply_license_excludes_dynamics_params():
    p = mlp_params()
    tier = LicenseTier(name="free", masks={"*": ((0.0, 10.0),)})
    out = apply_license(p, tier)
    # norm params survive a mask that would zero everything
    np.testing.assert_array_equal(np.asarray(out["out"]["norm"]), p["out"]["norm"])
    assert (np.asarray(out["layer1"]["kernel"]) == 0).all()


def test_license_stats_counts_masked():
    p = mlp_params()
    tier = LicenseTier(name="free", masks={"layer1": ((0.0, 100.0),)})
    s = license_stats(p, tier)
    assert s["masked"] == 32 * 64
    assert 0 < s["masked_frac"] < 1


# --------------------------------------------------------------- Algorithm 1
def test_calibrate_license_hits_target():
    """Algorithm 1: eval = survival fraction; target 0.5 must be reachable."""
    p = mlp_params(3)

    def eval_fn(params):
        total = live = 0
        for layer in ("layer1", "layer2", "out"):
            k = np.asarray(params[layer]["kernel"])
            total += k.size
            live += int(np.count_nonzero(k))
        return live / total

    tier, trace = calibrate_license(p, eval_fn, target_accuracy=0.5, k_intervals=10)
    assert tier.accuracy is not None and tier.accuracy <= 0.52
    assert len(trace) >= 1
    assert tier.masks  # some interval was cut
    # applying the tier reproduces the calibration endpoint
    masked = apply_license(p, tier)
    assert abs(eval_fn(masked) - tier.accuracy) < 1e-6


def test_calibrate_trace_monotone_nonincreasing():
    p = mlp_params(4)

    def eval_fn(params):
        return float(np.mean([np.count_nonzero(np.asarray(params[l]["kernel"])) /
                              np.asarray(params[l]["kernel"]).size
                              for l in ("layer1", "layer2", "out")]))

    _, trace = calibrate_license(p, eval_fn, target_accuracy=0.3, k_intervals=8)
    accs = [s.accuracy for s in trace]
    assert all(a >= b - 1e-9 for a, b in zip(accs, accs[1:]))


# -------------------------------------------------------------- compression
def test_magnitude_prune_sparsity():
    r = np.random.default_rng(0)
    w = jnp.asarray(r.standard_normal((64, 64)), dtype=jnp.float32)
    pruned = comp.magnitude_prune(w, 0.8)
    sparsity = 1 - np.count_nonzero(np.asarray(pruned)) / w.size
    assert abs(sparsity - 0.8) < 0.02
    # surviving weights unchanged
    nz = np.asarray(pruned) != 0
    np.testing.assert_array_equal(np.asarray(pruned)[nz], np.asarray(w)[nz])


def test_quantize_dequantize_error_bounded():
    r = np.random.default_rng(1)
    w = jnp.asarray(r.standard_normal((32, 128)), dtype=jnp.float32)
    q = comp.quantize_int8(w)
    back = comp.dequantize(q)
    # max error is half a quantization step per channel
    step = np.asarray(q.scale).reshape(-1, 1)
    err = np.abs(np.asarray(back) - np.asarray(w))
    assert (err <= step * 0.5 + 1e-7).all()


def test_weight_share_reduces_alphabet():
    r = np.random.default_rng(2)
    w = jnp.asarray(r.standard_normal((64, 64)), dtype=jnp.float32)
    s = comp.weight_share(w, k=16)
    back = comp.unshare(s)
    assert len(np.unique(np.asarray(back))) <= 16
    assert np.abs(np.asarray(back) - np.asarray(w)).mean() < 0.2


def test_compress_pipeline_stats_ordering():
    p = mlp_params(5)
    pruned, quant, stats = comp.compress_pipeline(p, sparsity=0.8)
    # Table 1 ordering: full > pruned > quantized
    assert stats.full_bytes > stats.pruned_bytes > stats.quantized_bytes
    assert 0.7 < stats.sparsity < 0.9


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), lo=st.floats(0.0, 1.0), width=st.floats(0.01, 1.0))
def test_mask_idempotent_property(seed, lo, width):
    """Masking twice == masking once (idempotence of interval pruning)."""
    r = np.random.default_rng(seed)
    w = jnp.asarray(r.standard_normal((16, 16)), dtype=jnp.float32)
    ivs = [(lo, lo + width)]
    once = mask_weight(w, ivs)
    twice = mask_weight(once, ivs)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), sparsity=st.floats(0.1, 0.95))
def test_prune_then_store_roundtrip_property(seed, sparsity):
    """Pruned params survive a WeightStore round trip exactly."""
    from repro.core.weightstore import WeightStore

    r = np.random.default_rng(seed)
    p = {"k": r.standard_normal((16, 16)).astype(np.float32)}
    pruned = {"k": np.asarray(comp.magnitude_prune(jnp.asarray(p["k"]), sparsity))}
    s = WeightStore(":memory:")
    s.register_model("m", "t")
    v = s.commit("m", pruned)
    out = s.checkout("m", v)
    np.testing.assert_allclose(out["k"], pruned["k"], rtol=1e-6)
    s.close()


def test_calibrate_refinement_tightens_target():
    """Beyond paper: bisecting the final interval lands closer to target."""
    p = mlp_params(9)

    def eval_fn(params):
        total = live = 0
        for layer in ("layer1", "layer2", "out"):
            k = np.asarray(params[layer]["kernel"])
            total += k.size
            live += int(np.count_nonzero(k))
        return live / total

    target = 0.55
    coarse, _ = calibrate_license(p, eval_fn, target, k_intervals=6)
    fine, _ = calibrate_license(p, eval_fn, target, k_intervals=6, refine_steps=8)
    assert abs(fine.accuracy - target) <= abs(coarse.accuracy - target) + 1e-9
    assert abs(fine.accuracy - target) < 0.05
