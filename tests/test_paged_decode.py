"""Kernel-resident paged decode: logit equivalence vs the gather/scatter
path on mixed lengths + GQA (+ MLA, int8 KV), the block-indexed write
kernel vs its oracle, CoW-before-first-write under the resident path,
window/SSM auto-fallback, and the Pallas kernel route end to end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.core.licensing import LicenseTier
from repro.kernels import ref
from repro.kernels.paged_attention import paged_decode_write
from repro.models import init_params
from repro.serving import LicensedGateway, RequestState

MAX_PROMPT = 8
MAX_NEW = 8
BLOCK = 4


@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    tiers = {
        "free": LicenseTier(name="free", masks={"*": ((0.0, 0.004),)}),
        "pro": LicenseTier(name="pro", masks={"*": ((0.0, 0.002),)}),
    }
    return cfg, params, tiers


def _gateway(setup, **kw):
    cfg, params, tiers = setup
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_prompt", MAX_PROMPT)
    kw.setdefault("max_new_cap", MAX_NEW)
    kw.setdefault("block_size", BLOCK)
    return LicensedGateway(cfg, params, tiers=tiers, **kw)


def _prompt(seed, n=MAX_PROMPT):
    return np.random.default_rng(seed).integers(0, 500, n, dtype=np.int32)


def _drain(gw, prompts, **kw):
    reqs = [gw.submit(p, **kw) for p in prompts]
    gw.run()
    assert all(r.state == RequestState.DONE for r in reqs), \
        [r.error for r in reqs]
    return reqs


def _assert_streams_equal(streams, atol=1e-5):
    for a, b in zip(*streams):
        assert a.out_tokens == b.out_tokens
        assert len(a.logits_rows) == len(b.logits_rows)
        for ra, rb in zip(a.logits_rows, b.logits_rows):
            np.testing.assert_allclose(ra, rb, atol=atol, rtol=0)


# ------------------------------------------------------- logit equivalence
def test_resident_matches_gather_scatter_mixed_lengths(setup):
    """The acceptance bar: the same mixed-length, mixed-tier stream
    through the kernel-resident and the gather/scatter decode paths
    produces identical tokens and logits equal to 1e-5 — and the
    resident gateway really never ran a gather/scatter decode step."""
    streams, gws = [], []
    for kernel in (False, True):
        gw = _gateway(setup, kernel_decode=kernel, record_logits=True)
        reqs = [gw.submit(_prompt(i), license=lic,
                          max_new_tokens=2 + 2 * (i % 3))
                for i, lic in enumerate(["full", "free", "free", "pro",
                                         "full"])]
        gw.run()
        assert all(r.state == RequestState.DONE for r in reqs)
        streams.append(reqs)
        gws.append(gw)
    _assert_streams_equal(streams)
    base, resident = gws
    assert base.kernel_decode is False and base.stats[
        "resident_decode_steps"] == 0
    assert resident.kernel_decode is True
    assert resident.stats["resident_decode_steps"] == \
        resident.stats["decode_steps"] > 0


def test_resident_fused_sampling_matches_host(setup):
    """Fused on-device sampling through the resident step (greedy AND
    stochastic temperature/top-k lanes) returns the same tokens as the
    return-logits host path."""
    outs = []
    for fuse in (True, False):
        gw = _gateway(setup, fuse_sampling=fuse)
        assert gw.kernel_decode
        rs = [gw.submit(_prompt(3), license="free", max_new_tokens=4),
              gw.submit(_prompt(4), license="free", max_new_tokens=4,
                        temperature=0.8, top_k=5, seed=7)]
        gw.run()
        outs.append([r.out_tokens for r in rs])
    assert outs[0] == outs[1]


@pytest.mark.parametrize("arch,extra", [
    ("deepseek-v2-lite-16b", {}),            # MLA: compressed-KV blocks
    ("qwen2.5-3b", {"kv_cache_int8": True}),  # int8 KV codes + scales
])
def test_resident_matches_on_other_cache_layouts(arch, extra):
    cfg = smoke_variant(get_config(arch)).replace(**extra)
    params = init_params(jax.random.PRNGKey(1), cfg)
    streams = []
    for kernel in (False, True):
        gw = LicensedGateway(cfg, params, max_batch=2,
                             max_prompt=MAX_PROMPT, max_new_cap=4,
                             block_size=BLOCK, kernel_decode=kernel,
                             record_logits=True)
        assert gw.kernel_decode is kernel
        streams.append(_drain(gw, [_prompt(i) for i in range(3)],
                              max_new_tokens=3))
    _assert_streams_equal(streams)


def test_resident_pallas_interpret_route(setup):
    """decode_pallas="interpret" sends attention through the actual
    Pallas kernel (interpret mode) inside the resident step; tokens and
    logits must still match the gather/scatter baseline."""
    streams = []
    for kw in (dict(kernel_decode=False),
               dict(kernel_decode=True, decode_pallas="interpret")):
        gw = _gateway(setup, record_logits=True, **kw)
        streams.append(_drain(gw, [_prompt(9), _prompt(10)],
                              max_new_tokens=2))
    _assert_streams_equal(streams)


def test_resident_preemption_roundtrip(setup):
    """Preemption under block pressure still reproduces the uncontended
    tokens when decode never scatters (recompute restart re-prefills)."""
    want = [r.out_tokens for r in _drain(
        _gateway(setup, prefix_cache=False),
        [_prompt(i) for i in range(5)], max_new_tokens=5)]
    # num_blocks=8, not 9: chunked admission reserves prompt blocks per
    # request, so the looser pool now drains preemption-free
    gw = _gateway(setup, prefix_cache=False, max_lanes=4, num_blocks=8)
    assert gw.kernel_decode
    reqs = _drain(gw, [_prompt(i) for i in range(5)], max_new_tokens=5)
    assert gw.stats["preempted"] > 0
    assert [r.out_tokens for r in reqs] == want
    assert gw.pool.allocator.num_held == 0


# ------------------------------------------------------ write kernel/oracle
def test_paged_write_kernel_matches_oracle():
    r = np.random.default_rng(0)
    p, bs, kh, hd, b = 9, 4, 2, 64, 4
    kb = jnp.asarray(r.standard_normal((p, bs, kh, hd)), jnp.float32)
    vb = jnp.asarray(r.standard_normal((p, bs, kh, hd)), jnp.float32)
    nk = jnp.asarray(r.standard_normal((b, kh, hd)), jnp.float32)
    nv = jnp.asarray(r.standard_normal((b, kh, hd)), jnp.float32)
    blocks = jnp.asarray(r.permutation(p)[:b], jnp.int32)
    offs = jnp.asarray(r.integers(0, bs, b), jnp.int32)
    gk, gv = paged_decode_write(kb, vb, nk, nv, blocks, offs,
                                interpret=True)
    rk, rv = ref.paged_decode_write(kb, vb, nk, nv, blocks, offs)
    np.testing.assert_array_equal(np.asarray(gk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(gv), np.asarray(rv))


def test_paged_write_kernel_null_duplicates_inert():
    """Pad lanes all target the null block: duplicate write targets must
    corrupt nothing outside that one block (its content is garbage by
    contract)."""
    r = np.random.default_rng(1)
    p, bs, kh, hd = 6, 4, 1, 64
    kb = jnp.asarray(r.standard_normal((p, bs, kh, hd)), jnp.float32)
    vb = jnp.asarray(r.standard_normal((p, bs, kh, hd)), jnp.float32)
    nk = jnp.asarray(r.standard_normal((3, kh, hd)), jnp.float32)
    nv = jnp.asarray(r.standard_normal((3, kh, hd)), jnp.float32)
    null = p - 1
    blocks = jnp.asarray([2, null, null], jnp.int32)   # 2 pad lanes
    offs = jnp.asarray([1, 0, 0], jnp.int32)
    gk, gv = paged_decode_write(kb, vb, nk, nv, blocks, offs,
                                interpret=True)
    keep = np.ones((p, bs), bool)
    keep[2, 1] = keep[null, 0] = False
    np.testing.assert_array_equal(np.asarray(gk)[keep], np.asarray(kb)[keep])
    np.testing.assert_array_equal(np.asarray(gv)[keep], np.asarray(vb)[keep])
    np.testing.assert_array_equal(np.asarray(gk)[2, 1], np.asarray(nk)[0])


# ----------------------------------------------------- CoW under residency
def test_cow_before_first_write_still_holds(setup):
    """Shared prefix chains stay bit-stable under kernel-resident decode:
    the tail block is CoW'd before the step's block-indexed write, so a
    later wave re-adopting the chain reproduces the cold-run tokens
    exactly — and shared non-tail blocks are never write targets."""
    rng = np.random.default_rng(3)
    p = rng.integers(0, 500, 6, dtype=np.int32)     # non-aligned bucket
    prompts = [p.copy() for _ in range(6)]
    streams, gws = [], []
    for prefix in (False, True):
        gw = _gateway(setup, max_prompt=6, max_new_cap=6,
                      prefix_cache=prefix, record_logits=True)
        assert gw.kernel_decode
        reqs = []
        for wave in range(3):
            reqs += _drain(gw, prompts[2 * wave: 2 * wave + 2],
                           max_new_tokens=3)
        streams.append(reqs)
        gws.append(gw)
    _assert_streams_equal(streams)
    assert gws[1].stats["cow_copies"] > 0
    assert gws[0].stats["cow_copies"] == 0
    assert gws[1].stats["prefix_tokens_reused"] > 0


# -------------------------------------------------------- clean fallbacks
def test_window_model_falls_back_to_gather_scatter():
    """Sliding-window attention keeps ring caches as per-lane state; the
    resident path auto-disables (even when asked for) and serving stays
    correct through the gather/scatter decode."""
    cfg = smoke_variant(get_config("recurrentgemma-2b"))
    params = init_params(jax.random.PRNGKey(2), cfg)
    gw = LicensedGateway(cfg, params, max_batch=2, max_prompt=8,
                         max_new_cap=8, block_size=4, kernel_decode=True)
    assert gw.paged is True and gw.kernel_decode is False
    reqs = _drain(gw, [_prompt(i) for i in range(3)], max_new_tokens=3)
    assert gw.stats["resident_decode_steps"] == 0
    assert all(len(r.out_tokens) == 3 for r in reqs)


def test_pure_ssm_model_falls_back_to_contiguous():
    cfg = smoke_variant(get_config("mamba2-130m"))
    params = init_params(jax.random.PRNGKey(3), cfg)
    gw = LicensedGateway(cfg, params, max_batch=2, max_prompt=4,
                         max_new_cap=2, kernel_decode=True)
    assert gw.paged is False and gw.kernel_decode is False
    r = gw.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=2)
    gw.run()
    assert r.state == RequestState.DONE


def test_decode_pallas_validation(setup):
    with pytest.raises(ValueError):
        _gateway(setup, decode_pallas="bogus")
    m = _gateway(setup).metrics()
    assert m["decode_path"]["kernel_resident"] is True
    assert m["decode_path"]["pallas"] in ("off", "pallas")
