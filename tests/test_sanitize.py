"""Runtime sanitizer suite: seeded lifecycle violations against the
shadow block model, retrace-sentinel bound busting, and the sanitized
gateway end to end (the ``REPRO_SANITIZE=1`` CI lane runs the full
paging/decode/update suites under the same wiring)."""
from types import SimpleNamespace

import jax
import numpy as np
import pytest

from repro.analysis.sanitize import (RetraceSentinel, SanitizerError,
                                     ServingSanitizer, sanitize_from_env)
from repro.configs import get_config, smoke_variant
from repro.models import init_params
from repro.serving import LicensedGateway, RequestState
from repro.serving.paging import BlockAllocator


def _attached(num_blocks=8):
    alloc = BlockAllocator(num_blocks)
    san = ServingSanitizer()
    san.attach_allocator(alloc)
    return alloc, san


# ------------------------------------------------------------ shadow mirror
def test_shadow_mirrors_clean_lifecycle():
    alloc, san = _attached()
    a, b = alloc.alloc(2)
    assert san.shadow == {a: 1, b: 1}
    assert alloc.incref(a) == 2          # wrapper preserves the count
    assert alloc.decref(a) == 1
    assert alloc.decref(b) == 0
    alloc.free([a])
    assert san.shadow == {} and alloc.num_held == 0


def test_double_free_caught_at_the_op():
    alloc, san = _attached()
    (b,) = alloc.alloc(1)
    alloc.free([b])
    with pytest.raises(SanitizerError, match="double free"):
        alloc.free([b])
    with pytest.raises(SanitizerError, match="double free"):
        alloc.decref(b)


def test_incref_after_free_is_use_after_free():
    alloc, san = _attached()
    (b,) = alloc.alloc(1)
    alloc.decref(b)
    with pytest.raises(SanitizerError, match="use-after-free"):
        alloc.incref(b)


def test_free_of_shared_block_rejected():
    alloc, san = _attached()
    (b,) = alloc.alloc(1)
    alloc.incref(b)
    with pytest.raises(SanitizerError, match="shared"):
        alloc.free([b])


def test_free_list_corruption_on_realloc():
    alloc, san = _attached(num_blocks=2)
    got = alloc.alloc(2)
    alloc._free.append(got[0])           # seeded corruption: live id re-listed
    with pytest.raises(SanitizerError, match="free-list corruption"):
        alloc.alloc(1)


def test_shadow_divergence_detected():
    alloc, san = _attached()
    a, b = alloc.alloc(2)
    alloc._ref[a] += 1                   # mutation behind the wrappers' back
    with pytest.raises(SanitizerError, match="divergence"):
        alloc.decref(b)


def test_attach_requirements():
    alloc = BlockAllocator(4)
    alloc.alloc(1)
    with pytest.raises(SanitizerError, match="live blocks"):
        ServingSanitizer().attach_allocator(alloc)
    alloc2, san = _attached()
    with pytest.raises(SanitizerError, match="already attached"):
        san.attach_allocator(BlockAllocator(4))


# ------------------------------------------------------------ gateway hooks
def _req(rid, blocks, pos):
    return SimpleNamespace(rid=rid, blocks=blocks, pos=pos)


def test_decode_write_table_entry_to_freed_block():
    alloc, san = _attached()
    a, b = alloc.alloc(2)
    alloc.decref(b)                      # freed, but the table still holds it
    pool = SimpleNamespace(block_size=4)
    with pytest.raises(SanitizerError, match="freed block"):
        san.check_decode_writes([_req("r0", [a, b], pos=5)], pool)


def test_decode_write_to_shared_block_without_cow():
    alloc, san = _attached()
    a, b = alloc.alloc(2)
    alloc.incref(b)                      # tail shared (e.g. by the prefix tree)
    pool = SimpleNamespace(block_size=4)
    with pytest.raises(SanitizerError, match="without CoW"):
        san.check_decode_writes([_req("r0", [a, b], pos=5)], pool)
    # exclusively-owned tail (CoW done) passes
    alloc.decref(b)
    san.check_decode_writes([_req("r0", [a, b], pos=5)], pool)


def test_after_step_and_drain_leak_detection():
    alloc, san = _attached()
    a, b, c = alloc.alloc(3)
    req = _req("r0", [a], pos=0)
    gw = SimpleNamespace(
        scheduler=SimpleNamespace(running=[req], waiting=[]),
        prefix=SimpleNamespace(_by_block={b: object()}))
    san.after_step(gw)                   # all request blocks live: fine
    with pytest.raises(SanitizerError, match=rf"leak at drain.*{c}"):
        san.check_drained(gw)            # c: no request, no prefix node
    alloc.decref(c)
    san.check_drained(gw)                # prefix-retained b is NOT a leak
    alloc.decref(b)
    req.blocks = [a, b]                  # table entry outlived the block
    with pytest.raises(SanitizerError, match="holds freed block"):
        san.after_step(gw)


# --------------------------------------------------------- retrace sentinel
def test_retrace_sentinel_bounds_distinct_keys():
    rt = RetraceSentinel()
    rt.bound("decode_width", 2)
    rt.note("decode_width", 4)
    rt.note("decode_width", 4)           # repeat key: no new specialization
    rt.note("decode_width", 8)
    assert rt.stats() == {"decode_width": 2}
    with pytest.raises(SanitizerError, match="decode_width.*over its bound"):
        rt.note("decode_width", 16)
    rt.note("unbounded_family", "x")     # families without bounds only count


def test_sanitize_env_opt_in(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert sanitize_from_env() is False
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    assert sanitize_from_env() is False
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize_from_env() is True


# ------------------------------------------------------------- end to end
@pytest.fixture(scope="module")
def setup():
    cfg = smoke_variant(get_config("qwen2.5-3b"))
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_sanitized_gateway_serves_clean(setup):
    cfg, params = setup
    gw = LicensedGateway(cfg, params, sanitize=True, max_batch=2,
                         max_prompt=8, max_new_cap=8, block_size=4)
    assert gw.sanitizer is not None
    rng = np.random.default_rng(0)
    reqs = [gw.submit(rng.integers(0, 500, 8, dtype=np.int32),
                      max_new_tokens=6) for _ in range(3)]
    gw.run()
    assert all(r.state == RequestState.DONE for r in reqs), \
        [r.error for r in reqs]
    # the shadow tracked every mutation and agrees with the allocator
    assert gw.sanitizer.shadow == dict(gw.pool.allocator._ref)
    # the bucketed jit families actually specialized, within bounds
    stats = gw.sanitizer.retrace.stats()
    assert stats and all(v >= 1 for v in stats.values())


def test_env_opt_in_arms_the_gateway(setup, monkeypatch):
    cfg, params = setup
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    gw = LicensedGateway(cfg, params, max_batch=1, max_prompt=4,
                         max_new_cap=4, block_size=4)
    assert gw.sanitizer is not None
    monkeypatch.setenv("REPRO_SANITIZE", "0")
    gw2 = LicensedGateway(cfg, params, max_batch=1, max_prompt=4,
                          max_new_cap=4, block_size=4)
    assert gw2.sanitizer is None


def test_sanitized_gateway_catches_injected_double_free(setup):
    cfg, params = setup
    gw = LicensedGateway(cfg, params, sanitize=True, max_batch=1,
                         max_prompt=8, max_new_cap=4, block_size=4,
                         prefix_cache=False)
    alloc = gw.pool.allocator
    got = alloc.alloc(1)
    alloc.decref(got[0])
    with pytest.raises(SanitizerError, match="double free"):
        alloc.decref(got[0])
