"""Wire-seam unit tests: RetryPolicy, payload checksums, the chaos
transport's deterministic fault schedule, and cursor resume.

These exercise ``core/transport.py`` against a real in-memory
``LicenseServer`` but below the serving stack — the end-to-end
differential (tokens bit-identical under a seeded fault schedule) lives
in ``test_chaos.py``."""
import numpy as np
import pytest

from repro.core.protocol import EdgeClient, LicenseServer
from repro.core.transport import (ChaosTransport, DirectTransport,
                                  PayloadCorruption, RetryPolicy,
                                  TransportDisconnect, TransportError,
                                  TransportTimeout, as_transport,
                                  part_checksum, verify_parts)
from repro.core.weightstore import LayerDelta, WeightStore


def _noop_sleep(_s):
    pass


def _server(chunk_elems=4):
    store = WeightStore(":memory:", row_limit=8, chunk_elems=chunk_elems)
    store.register_model("m", "mlp")
    server = LicenseServer(store)
    rng = np.random.default_rng(0)
    p = {"big/kernel": rng.standard_normal((16, 4)).astype(np.float32),
         "small/kernel": rng.standard_normal((2, 3)).astype(np.float32)}
    v1 = server.publish("m", p)
    p2 = {k: v * 1.01 for k, v in p.items()}
    server.publish("m", p2, parent=v1)
    return server, p, p2


# ----------------------------------------------------------------- RetryPolicy
def test_retry_succeeds_after_transient_faults():
    calls = []
    retries = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransportTimeout("boom")
        return "ok"

    rp = RetryPolicy(max_attempts=5, base_delay_s=0.01, sleep=_noop_sleep)
    out = rp.run(flaky, on_retry=lambda a, e, d: retries.append((a, d)))
    assert out == "ok" and len(calls) == 3
    assert [a for a, _ in retries] == [1, 2]
    # exponential: second backoff larger than the first (jitter is +/-10%)
    assert retries[1][1] > retries[0][1]


def test_retry_exhausts_attempts_and_reraises():
    rp = RetryPolicy(max_attempts=3, base_delay_s=0.0, sleep=_noop_sleep)
    calls = []

    def always():
        calls.append(1)
        raise TransportDisconnect("down")

    with pytest.raises(TransportDisconnect):
        rp.run(always)
    assert len(calls) == 3


def test_retry_deadline_cuts_budget_short():
    now = [0.0]
    rp = RetryPolicy(max_attempts=100, base_delay_s=1.0, multiplier=1.0,
                     jitter=0.0, deadline_s=2.5, clock=lambda: now[0],
                     sleep=lambda s: now.__setitem__(0, now[0] + s))
    calls = []

    def always():
        calls.append(1)
        raise TransportTimeout("down")

    with pytest.raises(TransportTimeout):
        rp.run(always)
    # 2 sleeps of 1s fit under the 2.5s deadline, the third would not
    assert len(calls) == 3


def test_retry_jitter_is_deterministic_per_seed():
    a = RetryPolicy(seed=7)
    b = RetryPolicy(seed=7)
    c = RetryPolicy(seed=8)
    da = [a.delay(i) for i in range(1, 6)]
    assert da == [b.delay(i) for i in range(1, 6)]
    assert da != [c.delay(i) for i in range(1, 6)]


def test_retry_does_not_catch_non_retryable():
    rp = RetryPolicy(max_attempts=5, base_delay_s=0.0, sleep=_noop_sleep)
    calls = []

    def wrong():
        calls.append(1)
        raise KeyError("not a wire fault")

    with pytest.raises(KeyError):
        rp.run(wrong)
    assert len(calls) == 1


# ------------------------------------------------------------------- checksums
def test_part_checksum_detects_flipped_byte_rows_and_chunks():
    rows = LayerDelta(layer="l/kernel", shape=(4, 2), dtype="float32",
                      indices=np.array([0, 3], np.int64),
                      values=np.array([[1, 2], [3, 4]], np.float32))
    d = part_checksum(rows)
    bad_vals = rows.values.copy()
    bad_vals.view(np.uint8).reshape(-1)[3] ^= 0xFF
    bad = LayerDelta(layer=rows.layer, shape=rows.shape, dtype=rows.dtype,
                     indices=rows.indices, values=bad_vals)
    assert part_checksum(bad) != d
    with pytest.raises(PayloadCorruption, match="l/kernel"):
        verify_parts([bad], [d])
    verify_parts([rows], [d])                 # pristine passes

    page = np.arange(4, dtype=np.float32).tobytes()
    chunked = LayerDelta(layer="l/kernel", shape=(8, 1), dtype="float32",
                         indices=np.array([0], np.int64), chunks=[page],
                         chunk_elems=4, chunk_compressed=[False])
    dc = part_checksum(chunked)
    blob = bytearray(page)
    blob[5] ^= 0xFF
    bad_c = LayerDelta(layer="l/kernel", shape=(8, 1), dtype="float32",
                       indices=np.array([0], np.int64), chunks=[bytes(blob)],
                       chunk_elems=4, chunk_compressed=[False])
    assert part_checksum(bad_c) != dc


# ------------------------------------------------------------ chaos scheduling
def test_chaos_schedule_deterministic_per_seed():
    def drain(seed):
        server, _, _ = _server()
        tr = ChaosTransport(server, seed=seed, fault_rate=0.3,
                            sleep=_noop_sleep)
        rp = RetryPolicy(max_attempts=10, base_delay_s=0.0,
                         sleep=_noop_sleep)
        outcomes = []
        for _ in range(30):
            try:
                rp.run(lambda: tr.production_version("m"))
                outcomes.append("ok")
            except TransportError as e:
                outcomes.append(type(e).__name__)
        return outcomes, dict(tr.stats)

    o1, s1 = drain(3)
    o2, s2 = drain(3)
    o3, s3 = drain(4)
    assert o1 == o2 and s1 == s2
    assert s1 != s3
    assert s1["faults"] > 0


def test_chaos_timeout_vs_disconnect_server_state():
    """A timeout faults BEFORE the server sees the call (cursor does not
    move); a disconnect faults AFTER (cursor advanced past parts the
    client never received)."""
    server, _, _ = _server()
    cursor = server.open_update("m", 1, "full")

    tr = ChaosTransport(server, seed=0, fault_rate=1.0, disconnect_weight=0,
                        corrupt_weight=0, sleep=_noop_sleep)
    pos = cursor.tell()
    with pytest.raises(TransportTimeout):
        tr.fetch_update(cursor, 64)
    assert cursor.tell() == pos               # server never saw the call

    tr = ChaosTransport(server, seed=0, fault_rate=1.0, timeout_weight=0,
                        corrupt_weight=0, sleep=_noop_sleep)
    with pytest.raises(TransportDisconnect):
        tr.fetch_update(cursor, 64)
    assert cursor.tell() != pos               # parts were lost mid-stream


def test_chaos_corruption_caught_and_server_payload_untouched():
    server, _, _ = _server()
    cursor = server.open_update("m", 1, "full")
    tr = ChaosTransport(server, seed=1, fault_rate=1.0, timeout_weight=0,
                        disconnect_weight=0, sleep=_noop_sleep)
    with pytest.raises(PayloadCorruption):
        tr.fetch_update(cursor, 1 << 20)
    assert tr.stats["corruptions"] >= 1
    # the same rows re-fetched through a clean transport verify fine:
    # only the delivered copy was damaged, never the server's bytes
    cursor2 = server.open_update("m", 1, "full")
    clean = DirectTransport(server)
    parts = clean.fetch_update(cursor2, 1 << 20)
    assert parts and cursor2.done


def test_chaos_duplicate_delivery_does_not_advance_cursor():
    server, _, _ = _server()
    cursor = server.open_update("m", 1, "full")
    tr = ChaosTransport(server, seed=0, fault_rate=0.0, dup_rate=1.0,
                        sleep=_noop_sleep)
    first = tr.fetch_update(cursor, 64)
    pos = cursor.tell()
    dup = tr.fetch_update(cursor, 64)         # re-delivery of ``first``
    assert cursor.tell() == pos
    assert tr.stats["duplicates"] == 1
    assert [p.layer for p in dup] == [p.layer for p in first]
    for a, b in zip(first, dup):
        np.testing.assert_array_equal(np.asarray(a.indices),
                                      np.asarray(b.indices))


def test_chaos_fault_ops_filter():
    server, _, _ = _server()
    tr = ChaosTransport(server, seed=0, fault_rate=1.0,
                        fault_ops=("fetch_update",), sleep=_noop_sleep)
    # ops outside the filter never fault
    for _ in range(5):
        assert tr.production_version("m") == 2
    assert tr.stats["faults"] == 0


# ------------------------------------------------------------- cursor + resume
def test_cursor_tell_seek_resume_matches_uninterrupted_drain():
    server, _, p2 = _server()

    ref_cursor = server.open_update("m", 1, "full")
    ref_parts = []
    while not ref_cursor.done:
        ref_parts.extend(server.fetch_update(ref_cursor, 48))

    cursor = server.open_update("m", 1, "full")
    got = list(server.fetch_update(cursor, 48))
    pos = cursor.tell()
    server.fetch_update(cursor, 48)           # delivered but LOST on the wire
    resumed = server.open_update("m", 1, "full", resume=pos)
    assert resumed.tell() == pos
    while not resumed.done:
        got.extend(server.fetch_update(resumed, 48))

    assert [p.layer for p in got] == [p.layer for p in ref_parts]
    for a, b in zip(got, ref_parts):
        np.testing.assert_array_equal(np.asarray(a.indices),
                                      np.asarray(b.indices))
        assert part_checksum(a) == part_checksum(b)


def test_cursor_seek_rejects_bad_positions():
    server, _, _ = _server()
    cursor = server.open_update("m", 1, "full")
    with pytest.raises(ValueError):
        cursor.seek((99, 0))
    with pytest.raises(ValueError):
        cursor.seek((0, 10 ** 9))


# -------------------------------------------------------------- client-side use
def test_edge_client_pull_through_chaos_matches_direct():
    server, _, _ = _server()
    direct = EdgeClient("m", {"big/kernel": np.zeros((16, 4), np.float32),
                              "small/kernel": np.zeros((2, 3), np.float32)})
    direct.request_update(server)

    chaotic = EdgeClient("m", {"big/kernel": np.zeros((16, 4), np.float32),
                               "small/kernel": np.zeros((2, 3), np.float32)})
    tr = ChaosTransport(server, seed=5, fault_rate=0.4, sleep=_noop_sleep)
    rp = RetryPolicy(max_attempts=10, base_delay_s=0.0, sleep=_noop_sleep)
    chaotic.request_update(tr, retry=rp)

    assert chaotic.version == direct.version
    for k in direct.params:
        np.testing.assert_array_equal(chaotic.params[k], direct.params[k])


def test_as_transport_passthrough():
    server, _, _ = _server()
    tr = DirectTransport(server)
    assert as_transport(tr) is tr
    assert as_transport(server).server is server
