"""Paged-attention decode kernel vs the gather-then-softmax oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip; plain tests still run
    from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.paged_attention import paged_attention


def mk(seed, b, h, hd, p, bs, t, kh=None, max_len=None):
    """Random decode case: disjoint per-sequence block tables + ragged lens."""
    r = np.random.default_rng(seed)
    kh = kh or h
    assert p >= b * t, "need enough physical blocks for disjoint tables"
    q = jnp.asarray(r.standard_normal((b, h, hd)), jnp.float32)
    kb = jnp.asarray(r.standard_normal((p, bs, kh, hd)), jnp.float32)
    vb = jnp.asarray(r.standard_normal((p, bs, kh, hd)), jnp.float32)
    tables = jnp.asarray(r.permutation(p)[: b * t].reshape(b, t), jnp.int32)
    lens = jnp.asarray(r.integers(1, (max_len or t * bs) + 1, b), jnp.int32)
    return q, kb, vb, tables, lens


@pytest.mark.parametrize("b,h,hd,bs,t", [(3, 4, 32, 8, 4), (1, 2, 16, 4, 6),
                                         (4, 8, 64, 16, 2)])
def test_paged_attention_matches_ref(b, h, hd, bs, t):
    q, kb, vb, tables, lens = mk(b * 31 + t, b, h, hd, b * t + 3, bs, t)
    got = paged_attention(q, kb, vb, tables, lens, interpret=True)
    want = ref.paged_attention(q, kb, vb, tables, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_paged_attention_gqa_groups():
    """8 q heads share 2 kv heads through the in-kernel group reshape."""
    q, kb, vb, tables, lens = mk(5, 3, 8, 32, 16, 8, 4, kh=2)
    got = paged_attention(q, kb, vb, tables, lens, interpret=True)
    want = ref.paged_attention(q, kb, vb, tables, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_pad_table_entries_are_inert():
    """Entries past ceil(len/bs) may point at ANY block — the context-len
    mask must keep them out of the softmax (this is exactly how the
    pool's null-padded tables arrive)."""
    q, kb, vb, tables, lens = mk(9, 2, 4, 32, 12, 8, 4)
    lens = jnp.asarray([5, 11], jnp.int32)         # 1 and 2 live blocks
    got = paged_attention(q, kb, vb, tables, lens, interpret=True)
    # scramble every dead table entry
    tab = np.asarray(tables).copy()
    tab[0, 1:] = 0
    tab[1, 2:] = 3
    got2 = paged_attention(q, kb, vb, jnp.asarray(tab), lens, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(got2),
                               rtol=1e-6, atol=1e-6)


def test_block_boundary_lens():
    """Context lengths on exact block boundaries (incl. full capacity)."""
    b, bs, t = 3, 8, 3
    q, kb, vb, tables, _ = mk(13, b, 4, 16, b * t, bs, t)
    lens = jnp.asarray([bs, 2 * bs, t * bs], jnp.int32)
    got = paged_attention(q, kb, vb, tables, lens, interpret=True)
    want = ref.paged_attention(q, kb, vb, tables, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_matches_flash_oracle_on_contiguous_layout():
    """With an identity block table, paged attention IS decode attention:
    check against the flash oracle's decode path (q_offset = len - 1)."""
    r = np.random.default_rng(21)
    b, h, hd, bs, t = 2, 4, 32, 8, 4
    s = t * bs
    k = jnp.asarray(r.standard_normal((b, s, h, hd)), jnp.float32)
    v = jnp.asarray(r.standard_normal((b, s, h, hd)), jnp.float32)
    q = jnp.asarray(r.standard_normal((b, h, hd)), jnp.float32)
    lens = jnp.asarray([s, s], jnp.int32)
    kb = k.reshape(b * t, bs, h, hd)
    vb = v.reshape(b * t, bs, h, hd)
    tables = jnp.arange(b * t, dtype=jnp.int32).reshape(b, t)
    got = paged_attention(q, kb, vb, tables, lens, interpret=True)
    want = ref.flash_attention(
        q.reshape(b * h, 1, hd),
        k.transpose(0, 2, 1, 3).reshape(b * h, s, hd),
        v.transpose(0, 2, 1, 3).reshape(b * h, s, hd),
        causal=True, q_offset=s - 1,
    ).reshape(b, h, hd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(min_value=1, max_value=4),
    bs=st.sampled_from([4, 8, 16]),
    t=st.integers(min_value=1, max_value=4),
    kh_pick=st.sampled_from([(4, 4), (8, 2), (6, 3)]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_paged_attention_property(b, bs, t, kh_pick, seed):
    """Property: kernel == oracle over random geometry + ragged lens."""
    h, kh = kh_pick
    q, kb, vb, tables, lens = mk(seed, b, h, 32, b * t + 2, bs, t, kh=kh)
    got = paged_attention(q, kb, vb, tables, lens, interpret=True)
    want = ref.paged_attention(q, kb, vb, tables, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
